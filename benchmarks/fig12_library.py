"""Figure 12: library-extension mode (Jackson analog) — Spark->Giraph JSON
(dataframe -> graphstore via jsonlib AJsonGenerator).

Rungs: IORedirect only -> +binary values -> +metadata removal (keys +
delimiters) -> full (column pivot)."""

from __future__ import annotations

import threading

from repro.core import PipeConfig, PipeEnabledEngine, adapter_for
from repro.core.directory import WorkerDirectory, set_directory
from repro.core.ioredirect import PipeOpenContext
from repro.engines import make_engine, make_paper_block

from .common import DEFAULT_ROWS, emit, timed

RUNGS = [
    ("ioredirect", PipeConfig(mode="text", text_format="json")),
    ("binary", PipeConfig(mode="parts", text_format="json")),
    ("metadata_removed", PipeConfig(mode="arrowrow", text_format="json")),
    ("pipegen_full", PipeConfig(mode="arrowcol", text_format="json")),
]


def _json_file_transfer(n_rows: int) -> float:
    import os
    import tempfile

    src, dst = make_engine("dataframe"), make_engine("graphstore")
    src.put_block("t", make_paper_block(n_rows, seed=1))

    def run():
        with tempfile.TemporaryDirectory() as td:
            path = os.path.join(td, "x.json")
            src.export_json("t", path)
            dst.import_json("t2", path)

    return timed(run)


def _json_pipe_transfer(n_rows: int, cfg: PipeConfig) -> float:
    set_directory(WorkerDirectory())
    src, dst = make_engine("dataframe"), make_engine("graphstore")
    src.put_block("t", make_paper_block(n_rows, seed=1))
    gs, gd = adapter_for(src), adapter_for(dst)
    counter = [0]

    def run():
        counter[0] += 1
        name = f"db://fig12?query=q{counter[0]}"
        errs = []

        def imp():
            try:
                with PipeEnabledEngine(gd), PipeOpenContext(cfg):
                    dst.import_json("t2", name)
            except BaseException as e:  # noqa: BLE001
                errs.append(e)

        def exp():
            try:
                with PipeEnabledEngine(gs), PipeOpenContext(cfg):
                    src.export_json("t", name)
            except BaseException as e:  # noqa: BLE001
                errs.append(e)

        ti = threading.Thread(target=imp)
        te = threading.Thread(target=exp)
        ti.start(); te.start(); ti.join(120); te.join(120)
        if errs:
            raise errs[0]
        assert len(dst.get_block("t2")) == n_rows

    return timed(run)


def main(n_rows: int = DEFAULT_ROWS // 2) -> dict:
    out = {}
    tf = _json_file_transfer(n_rows)
    out["file"] = tf
    emit("fig12.file_json", tf)
    for name, cfg in RUNGS:
        tp = _json_pipe_transfer(n_rows, cfg)
        out[name] = tp
        emit(f"fig12.{name}", tp, f"speedup={tf / tp:.2f}x")
    return out


if __name__ == "__main__":
    main()
