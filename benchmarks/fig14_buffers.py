"""Figure 14: Arrow buffer (row-block) size sweep, Myria->Giraph analog.

Paper conclusion: as long as the buffer is not too small, size barely
matters.  With the pooled zero-copy path the sweep also reports buffer-pool
efficiency per block size: smaller blocks mean more frames, which is where
pooled reuse (hit rate) and the pipelined sender earn their keep.  The
decode-side twin sweeps the arena hit rate (reader allocations recycled
instead of reallocated), and a transport sweep compares the same blocks
over socket vs channel vs shm ring.
"""

from __future__ import annotations

import threading
import time

from repro.core import PipeConfig
from repro.core.datapipe import DataPipeInput, DataPipeOutput
from repro.core.directory import WorkerDirectory, set_directory
from repro.core.iobuf import BufferPool, DecodeArena
from repro.engines import make_paper_block

from .common import DEFAULT_ROWS, emit, pipe_transfer

SIZES = [64, 256, 1024, 4096, 16384, 65536]


def _stream_decode(n_rows: int, block_rows: int, arena: DecodeArena) -> float:
    """Streaming importer profile: blocks are dropped as consumed, which is
    the lifecycle the decode arena accelerates (a bulk engine import holds
    every block until the final merge, so its stores cannot recycle until
    the stream ends — by design, not by accident)."""
    set_directory(WorkerDirectory())
    name = f"db://fig14-decode-{block_rows}?query=1"
    block = make_paper_block(n_rows, seed=1)
    rows = []

    def imp():
        pipe = DataPipeInput(name, arena=arena)
        rows.append(sum(len(b) for b in pipe.blocks()))
        pipe.close()

    t = threading.Thread(target=imp, daemon=True)
    t.start()
    t0 = time.perf_counter()
    out = DataPipeOutput(name, config=PipeConfig(mode="arrowcol",
                                                 block_rows=block_rows))
    out.write_block(block)
    out.close()
    t.join(120)
    assert rows and rows[0] == n_rows
    return time.perf_counter() - t0


def main(n_rows: int = DEFAULT_ROWS) -> dict:
    out = {}
    # paper-faithful sweep: numeric paper block, Myria->Giraph analog
    for rows in SIZES:
        t = pipe_transfer("colstore", "graphstore", n_rows,
                          PipeConfig(mode="arrowcol", block_rows=rows))
        out[rows] = t
        emit(f"fig14.block_rows_{rows}", t)
    # pooled-buffer efficiency: string columns exercise the pooled offsets
    # path every block, so the hit rate shows reuse vs. block size
    for rows in SIZES:
        pool = BufferPool()
        t = pipe_transfer("colstore", "dataframe", n_rows,
                          PipeConfig(mode="arrowcol", block_rows=rows,
                                     pool=pool), strings=True)
        out[f"strings_{rows}"] = t
        s = pool.stats
        total = s.hits + s.misses
        rate = (s.hits / total) if total else 0.0
        emit(f"fig14.strings_block_rows_{rows}", t,
             f"pool_hit_rate={rate:.2f} acquires={total}")
    # decode-arena efficiency: the importer-side mirror of the sweep above,
    # measured on a streaming consumer (the arena's target lifecycle)
    for rows in SIZES:
        arena = DecodeArena(BufferPool())
        # use the function's own transfer-only timing (it excludes block
        # construction and thread spin-up), best of two like timed()
        t = min(_stream_decode(n_rows, rows, arena) for _ in range(2))
        out[f"decode_{rows}"] = t
        total = arena.hits + arena.misses
        rate = (arena.hits / total) if total else 0.0
        emit(f"fig14.decode_block_rows_{rows}", t,
             f"decode_hit_rate={rate:.2f} acquires={total}")
    # transport sweep at a frame-heavy block size: socket pays the kernel
    # round trip, channel one queue materialization, shm neither
    for transport in ("socket", "channel", "shm"):
        t = pipe_transfer("colstore", "graphstore", n_rows,
                          PipeConfig(mode="arrowcol", block_rows=2048,
                                     transport=transport))
        out[f"transport_{transport}"] = t
        emit(f"fig14.transport_{transport}", t)
    return out


if __name__ == "__main__":
    main()
