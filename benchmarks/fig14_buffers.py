"""Figure 14: Arrow buffer (row-block) size sweep, Myria->Giraph analog.

Paper conclusion: as long as the buffer is not too small, size barely
matters."""

from __future__ import annotations

from repro.core import PipeConfig

from .common import DEFAULT_ROWS, emit, pipe_transfer

SIZES = [64, 256, 1024, 4096, 16384, 65536]


def main(n_rows: int = DEFAULT_ROWS) -> dict:
    out = {}
    for rows in SIZES:
        t = pipe_transfer("colstore", "graphstore", n_rows,
                          PipeConfig(mode="arrowcol", block_rows=rows))
        out[rows] = t
        emit(f"fig14.block_rows_{rows}", t)
    return out


if __name__ == "__main__":
    main()
