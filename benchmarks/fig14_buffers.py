"""Figure 14: Arrow buffer (row-block) size sweep, Myria->Giraph analog.

Paper conclusion: as long as the buffer is not too small, size barely
matters.  With the pooled zero-copy path the sweep also reports buffer-pool
efficiency per block size: smaller blocks mean more frames, which is where
pooled reuse (hit rate) and the pipelined sender earn their keep.
"""

from __future__ import annotations

from repro.core import PipeConfig
from repro.core.iobuf import BufferPool

from .common import DEFAULT_ROWS, emit, pipe_transfer

SIZES = [64, 256, 1024, 4096, 16384, 65536]


def main(n_rows: int = DEFAULT_ROWS) -> dict:
    out = {}
    # paper-faithful sweep: numeric paper block, Myria->Giraph analog
    for rows in SIZES:
        t = pipe_transfer("colstore", "graphstore", n_rows,
                          PipeConfig(mode="arrowcol", block_rows=rows))
        out[rows] = t
        emit(f"fig14.block_rows_{rows}", t)
    # pooled-buffer efficiency: string columns exercise the pooled offsets
    # path every block, so the hit rate shows reuse vs. block size
    for rows in SIZES:
        pool = BufferPool()
        t = pipe_transfer("colstore", "dataframe", n_rows,
                          PipeConfig(mode="arrowcol", block_rows=rows,
                                     pool=pool), strings=True)
        out[f"strings_{rows}"] = t
        s = pool.stats
        total = s.hits + s.misses
        rate = (s.hits / total) if total else 0.0
        emit(f"fig14.strings_block_rows_{rows}", t,
             f"pool_hit_rate={rate:.2f} acquires={total}")
    return out


if __name__ == "__main__":
    main()
