"""Plan-API sweep: single edge vs chained A→B→C vs fan-out A→{B,C}.

The planner's promise is that composition is free of kwarg plumbing *and*
of serialization overhead: a chained plan pays two hops, a fan-out plan
overlaps its edges in one stage.  Emitted rungs:

    plan.single_edge     one-edge plan (the transfer() shim path)
    plan.chain_3engine   A→B→C through an intermediate engine
    plan.fanout_1to2     A→{B,C}, both edges concurrent in one stage
    plan.fanout_vs_2seq  fan-out minus two sequential transfers (overlap)
"""

from __future__ import annotations

from repro.core import PipeConfig, plan, transfer
from repro.engines import make_engine, make_paper_block

from .common import DEFAULT_ROWS, REPEATS, emit, fresh, timed

_BLOCK_ROWS = 4096


def _cfg() -> PipeConfig:
    return PipeConfig(mode="arrowcol", block_rows=_BLOCK_ROWS)


def _single(n_rows: int) -> float:
    def run():
        fresh()
        a, b = make_engine("colstore"), make_engine("dataframe")
        a.put_block("t", make_paper_block(n_rows, seed=1))
        res = (plan(negotiate=False)
               .move(a, "t", b, "t2", config=_cfg(), timeout=300)
               .execute())
        assert res.single().rows == n_rows

    return timed(run, repeats=REPEATS)


def _chain(n_rows: int) -> float:
    def run():
        fresh()
        a = make_engine("colstore")
        b = make_engine("dataframe")
        c = make_engine("colstore")
        a.put_block("t", make_paper_block(n_rows, seed=1))
        res = (plan(negotiate=False)
               .move(a, "t", b, "t2", config=_cfg(), timeout=300)
               .then(b, "t2", c, "t3", config=_cfg(), timeout=300)
               .execute())
        assert res.results["e1"].rows == n_rows

    return timed(run, repeats=REPEATS)


def _fanout(n_rows: int) -> float:
    def run():
        fresh()
        a = make_engine("colstore")
        b = make_engine("dataframe")
        c = make_engine("rowstore")
        a.put_block("t", make_paper_block(n_rows, seed=1))
        res = (plan(negotiate=False)
               .move(a, "t", b, "t2", config=_cfg(), timeout=300)
               .move(a, "t", c, "t3", config=_cfg(), timeout=300)
               .execute())
        assert res.rows == 2 * n_rows

    return timed(run, repeats=REPEATS)


def _two_sequential(n_rows: int) -> float:
    def run():
        fresh()
        a = make_engine("colstore")
        b = make_engine("dataframe")
        c = make_engine("rowstore")
        a.put_block("t", make_paper_block(n_rows, seed=1))
        transfer(a, "t", b, "t2", config=_cfg(), timeout=300)
        transfer(a, "t", c, "t3", config=_cfg(), timeout=300)

    return timed(run, repeats=REPEATS)


def main(n_rows: int = DEFAULT_ROWS) -> dict:
    out = {}
    out["single"] = _single(n_rows)
    emit("plan.single_edge", out["single"])
    out["chain"] = _chain(n_rows)
    emit("plan.chain_3engine", out["chain"],
         f"per_hop={out['chain'] / 2:.4f}s")
    out["fanout"] = _fanout(n_rows)
    emit("plan.fanout_1to2", out["fanout"])
    out["seq2"] = _two_sequential(n_rows)
    emit("plan.fanout_vs_2seq", out["seq2"] - out["fanout"],
         f"overlap={out['seq2'] / out['fanout']:.2f}x")
    return out


if __name__ == "__main__":
    main()
