"""Table 1: speedup vs worker count, Myria->Spark analog
(colstore -> dataframe).  Paper: ~3.1-3.7x across 1/4/8/16 workers."""

from __future__ import annotations

from repro.core import PipeConfig

from .common import DEFAULT_ROWS, emit, file_transfer, pipe_transfer

WORKERS = [1, 2, 4]


def main(n_rows: int = DEFAULT_ROWS) -> dict:
    out = {}
    for w in WORKERS:
        tf = file_transfer("colstore", "dataframe", n_rows, workers=w)
        tp = pipe_transfer("colstore", "dataframe", n_rows,
                           PipeConfig(mode="arrowcol"), workers=w)
        sp = tf / tp
        out[w] = sp
        emit(f"table1.workers_{w}.file", tf)
        emit(f"table1.workers_{w}.pipe", tp, f"speedup={sp:.2f}x")
    return out


if __name__ == "__main__":
    main()
