"""Table 2: per-engine modification statistics from the compile loop
(classes touched, LOC emitted, modification time)."""

from __future__ import annotations

import os
import tempfile

from repro.core import generate_pipe_adapter
from repro.core.directory import WorkerDirectory, set_directory
from repro.engines import ENGINES, make_engine

from .common import emit


def main() -> dict:
    set_directory(WorkerDirectory())
    out = {}
    with tempfile.TemporaryDirectory() as td:
        for name in ENGINES:
            eng = make_engine(name)
            gp = generate_pipe_adapter(
                name, eng.unit_export_test, eng.unit_import_test,
                os.path.join(td, f"{name}.csv"),
                mode="string-decoration",
                formopt_replacements=len(gp_formopt_sites(eng)),
            )
            s = gp.stats
            out[name] = s
            emit(f"table2.{name}", s.modification_time_s,
                 f"io_classes={s.ioredirect_classes} io_loc={s.ioredirect_loc} "
                 f"fo_classes={s.formopt_classes} fo_loc={s.formopt_loc}")
        # library-extension mode (jsonlib on the Spark analog)
        eng = make_engine("dataframe")
        gp = generate_pipe_adapter(
            "dataframe", eng.unit_export_test, eng.unit_import_test,
            os.path.join(td, "df.csv"), mode="library-extension",
            formopt_replacements=2,
        )
        s = gp.stats
        out["dataframe-libext"] = s
        emit("table2.dataframe.libext", s.modification_time_s,
             f"io_classes={s.ioredirect_classes} io_loc={s.ioredirect_loc} "
             f"fo_classes={s.formopt_classes} fo_loc={s.formopt_loc}")
    return out


def gp_formopt_sites(eng) -> list:
    """Count decoration substitution sites (the _s/_lit/_parse hooks the
    string-decoration pass rewrites) from the engine's source."""
    import inspect

    src = inspect.getsource(type(eng))
    base_src = ""
    for klass in type(eng).__mro__[1:]:
        if klass.__name__ == "Engine":
            base_src = inspect.getsource(klass)
    hooks = ("self._s(", "self._lit(", "self._sep(", "self._nl(",
             "self._parse_int(", "self._parse_float(", "self._parse_bool(")
    return [h for text in (src, base_src) for h in hooks if h in text]


if __name__ == "__main__":
    main()
