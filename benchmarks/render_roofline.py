"""Render the §Roofline table from dry-run artifacts into EXPERIMENTS.md
(replaces the <!-- ROOFLINE_TABLE --> marker block)."""

from __future__ import annotations

import json
from pathlib import Path

ARTIFACTS = Path("artifacts/dryrun")
TARGET = Path("EXPERIMENTS.md")
MARK = "<!-- ROOFLINE_TABLE -->"
HBM_GB = 16.0


def fmt(v: float) -> str:
    if v >= 100:
        return f"{v:.0f}"
    if v >= 1:
        return f"{v:.2f}"
    return f"{v:.3g}"


def render() -> str:
    rows = []
    for f in sorted(ARTIFACTS.glob("*__pod16x16.json")):
        r = json.loads(f.read_text())
        if r["status"] == "SKIP":
            rows.append((r["arch"], r["shape"], "SKIP", "", "", "", "", "",
                         "by design"))
            continue
        if r["status"] != "OK":
            rows.append((r["arch"], r["shape"], "FAIL", "", "", "", "", "",
                         r.get("error", "")[:40]))
            continue
        t = r["roofline"]
        dom = max(t, key=t.get)
        mem = r["memory"]
        state_gb = (mem.get("argument_size") or 0) / 1e9
        temp_gb = (mem.get("temp_size") or 0) / 1e9
        fits = "yes" if (state_gb / 2 + temp_gb) < HBM_GB else "NO"
        note = f"{state_gb:.0f}+{temp_gb:.0f}GB"
        rows.append((
            r["arch"], r["shape"], r["kind"],
            fmt(t["compute_s"]), fmt(t["memory_s"]), fmt(t["collective_s"]),
            dom.replace("_s", ""),
            f"{(r.get('useful_ratio') or 0):.2f}",
            f"fit={fits} ({note})",
        ))
    head = ("| arch | shape | kind | compute_s | memory_s | collective_s "
            "| dominant | useful | memory fit (args/2+temp vs 16GB) |\n"
            "|---|---|---|---|---|---|---|---|---|")
    body = "\n".join(
        "| " + " | ".join(str(c) for c in row) + " |" for row in rows)

    # multi-pod summary
    mp = list(ARTIFACTS.glob("*__pod2x16x16.json"))
    n_ok = sum(json.loads(f.read_text())["status"] == "OK" for f in mp)
    n_skip = sum(json.loads(f.read_text())["status"] == "SKIP" for f in mp)
    tail = (f"\n\nMulti-pod (2x16x16) pass: {n_ok} OK / {n_skip} SKIP "
            f"/ {len(mp) - n_ok - n_skip} FAIL out of {len(mp)} cells "
            "(full records in artifacts/dryrun/*pod2x16x16.json).\n"
            "Terms are per-device-step seconds against per-chip peaks; "
            "dominant-term changes from the hillclimb are in §Perf.")
    return head + "\n" + body + tail


def main() -> None:
    table = render()
    text = TARGET.read_text()
    if MARK in text:
        # replace marker (and anything until the next blank-line-#) once
        text = text.replace(MARK, table, 1)
        TARGET.write_text(text)
        print(f"[render_roofline] wrote {len(table.splitlines())} table lines")
    else:
        print(table)


if __name__ == "__main__":
    main()
