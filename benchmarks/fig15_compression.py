"""Figure 15: compression x link latency, Myria->Giraph analog.

(a) colocated workers: compression should LOSE (overhead, no win);
    shared-memory (in-process channel) bounds the socket path.
(b) 40 ms simulated link: dictionary (zip/zstd) should WIN.

The 40 ms link is LinkSim on the pipe transport — the same knob the paper
turned with tc."""

from __future__ import annotations

from repro.core import PipeConfig
from repro.core.compression import CODECS as _AVAILABLE
from repro.core.transport import LinkSim

from .common import DEFAULT_ROWS, emit, pipe_transfer

CODECS = [c for c in ("none", "rle", "zip", "zstd") if c in _AVAILABLE]


def main(n_rows: int = DEFAULT_ROWS // 2) -> dict:
    out = {}
    for codec in CODECS:
        t = pipe_transfer("colstore", "graphstore", n_rows,
                          PipeConfig(mode="arrowcol", codec=codec))
        out[f"colocated.{codec}"] = t
        emit(f"fig15.colocated.{codec}", t)
    # 40 ms RTT + WAN-class bandwidth: the volume term must matter for the
    # compression trade to be visible at this payload size (the paper's
    # cluster link carried 1e9-row payloads; we scale bandwidth instead)
    link = LinkSim(latency_s=0.04, bandwidth_bps=1.5e8)
    for codec in CODECS:
        t = pipe_transfer("colstore", "graphstore", n_rows,
                          PipeConfig(mode="arrowcol", codec=codec,
                                     link=link, block_rows=16384))
        out[f"latency40ms.{codec}"] = t
        emit(f"fig15.latency40ms.{codec}", t)
    best_far = min((c for c in CODECS),
                   key=lambda c: out[f"latency40ms.{c}"])
    emit("fig15.summary", 0.0,
         f"best_at_40ms={best_far} paper=dictionary(zip)")
    return out


if __name__ == "__main__":
    main()
