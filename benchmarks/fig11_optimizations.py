"""Figure 11: optimization breakdown, Myria->Giraph analog
(colstore -> graphstore).

Rungs: file baseline -> IORedirect only (text) -> +binary primitives
(parts) -> +delimiter removal (binary_rows) -> full PipeGen (arrowcol,
column pivot).  A manually-optimized pipe (hand-written socket transfer of
the typed columns, no PipeGen machinery) bounds what generation could hope
to reach.

Beyond the ladder, the stream-fabric rungs: a streams sweep (one pipe
striped across N member connections; ``run.py --transport/--streams``
override the swept sets) measured both raw and under a per-link
bandwidth cap (the multi-NIC scenario striping exists for), and an
N=2→M=3 hash-partitioned shuffle probe."""

from __future__ import annotations

import pickle
import socket
import threading

from repro.core import LinkSim, PipeConfig, transfer
from repro.core.directory import WorkerDirectory, set_directory
from repro.engines import make_engine, make_paper_block

from .common import (
    DEFAULT_ROWS,
    REPEATS,
    emit,
    file_transfer,
    fresh,
    pipe_transfer,
    timed,
)

RUNGS = [
    ("ioredirect", PipeConfig(mode="text")),
    ("binary", PipeConfig(mode="parts")),
    ("delim_removed", PipeConfig(mode="binary_rows")),
    # the pre-zero-copy transfer path: per-row text serialization into the
    # assembler, concatenated single-buffer frames, strictly serial send
    ("pipegen_seedpath", PipeConfig(mode="arrowcol", pipelined=False,
                                    scatter_gather=False, block_export=False)),
    # full PipeGen: typed block export, pooled zero-copy scatter-gather
    # encode, vectored send, double-buffered pipelined sender
    ("pipegen_full", PipeConfig(mode="arrowcol")),
    # same data plane over the in-process channel (one materialization at
    # the queue boundary) and over the shared-memory ring (in-place spans,
    # zero intermediate copies, works across OS processes)
    ("pipegen_channel", PipeConfig(mode="arrowcol", transport="channel")),
    ("pipegen_shm", PipeConfig(mode="arrowcol", transport="shm")),
]


def _manual_pipe(n_rows: int) -> float:
    """Hand-optimized: typed columns pickled straight over a socket."""
    src = make_engine("colstore")
    dst = make_engine("graphstore")
    src.put_block("t", make_paper_block(n_rows, seed=1))

    def run():
        lsock = socket.socket()
        lsock.bind(("127.0.0.1", 0))
        lsock.listen(1)
        port = lsock.getsockname()[1]

        def serve():
            conn, _ = lsock.accept()
            blk = src.get_block("t")
            payload = pickle.dumps((blk.schema.to_dict(),
                                    [list(map(float, c)) if not hasattr(c, "dtype")
                                     else c for c in blk.columns]))
            conn.sendall(len(payload).to_bytes(8, "little") + payload)
            conn.close()

        t = threading.Thread(target=serve)
        t.start()
        s = socket.create_connection(("127.0.0.1", port))
        ln = int.from_bytes(_recv_exact(s, 8), "little")
        schema_doc, cols = pickle.loads(_recv_exact(s, ln))
        s.close()
        t.join()
        from repro.core.types import ColumnBlock, Schema

        dst.put_block("t2", ColumnBlock(Schema.from_dict(schema_doc), cols))

    return timed(run)


def _recv_exact(sock, n):
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise IOError("eof")
        buf += chunk
    return buf


#: streams-sweep defaults (overridable via ``run.py --transport/--streams``)
SWEEP_TRANSPORTS = ("socket",)
SWEEP_STREAMS = (1, 4)
#: per-link bandwidth cap for the link-limited sweep: striping across N
#: members models N NICs, so the capped rung shows the N-fold pipe (tight
#: enough to bind even at --quick row counts)
_SWEEP_LINK_BPS = 100e6
_SWEEP_BLOCK_ROWS = 2048  # many frames even at --quick row counts


def _streams_sweep(n_rows: int, transports, streams_list) -> dict:
    out = {}
    for t in transports:
        for s in streams_list:
            cfg = PipeConfig(mode="arrowcol", transport=t, streams=s,
                             block_rows=_SWEEP_BLOCK_ROWS,
                             shm_capacity=1 << 22)
            sec = pipe_transfer("colstore", "graphstore", n_rows, cfg)
            out[(t, s)] = sec
            emit(f"fig11.streams_{t}_x{s}", sec)
        base = out.get((t, 1))
        best = min(s for s in streams_list)
        top = max(s for s in streams_list)
        if base and (t, top) in out and top != best:
            emit(f"fig11.streams{top}_vs_streams1_{t}",
                 base - out[(t, top)],
                 f"speedup={base / out[(t, top)]:.2f}x")
    # link-limited: same sweep under a per-connection bandwidth cap — the
    # multi-NIC case where striping buys aggregate bandwidth outright
    for s in sorted({min(streams_list), max(streams_list)}):
        cfg = PipeConfig(mode="arrowcol", streams=s,
                         block_rows=_SWEEP_BLOCK_ROWS,
                         link=LinkSim(bandwidth_bps=_SWEEP_LINK_BPS,
                                      min_sleep_s=0.0005))
        sec = pipe_transfer("colstore", "graphstore", n_rows, cfg)
        out[("link", s)] = sec
        emit(f"fig11.streams_link_x{s}", sec)
    lo, hi = min(streams_list), max(streams_list)
    if lo != hi:
        emit("fig11.streams_link_speedup",
             out[("link", lo)] - out[("link", hi)],
             f"speedup={out[('link', lo)] / out[('link', hi)]:.2f}x")
    return out


def _shuffle_probe(n_rows: int, streams: int = 1) -> float:
    """N=2→M=3 hash-partitioned repartitioning transfer (colstore both
    sides: the graphstore analog cannot hold arbitrary relations).  With
    ``streams`` > 1 every shuffle member pipe is itself striped — the
    composition path (slotted rendezvous)."""

    def run():
        fresh()
        src = make_engine("colstore")
        dst = make_engine("colstore")
        src.put_block("t", make_paper_block(n_rows, seed=1))
        transfer(src, "t", dst, "t2",
                 config=PipeConfig(mode="arrowcol",
                                   block_rows=_SWEEP_BLOCK_ROWS),
                 workers=2, import_workers=3, partition="hash",
                 streams=streams if streams > 1 else None, timeout=300)
        assert len(dst.get_block("t2")) == n_rows

    return timed(run, repeats=REPEATS)


def main(n_rows: int = DEFAULT_ROWS, transports=None, streams_sweep=None) -> dict:
    out = {}
    tf = file_transfer("colstore", "graphstore", n_rows)
    out["file"] = tf
    emit("fig11.file_baseline", tf)
    for name, cfg in RUNGS:
        tp = pipe_transfer("colstore", "graphstore", n_rows, cfg)
        out[name] = tp
        emit(f"fig11.{name}", tp, f"speedup={tf / tp:.2f}x")
    # the zero-copy + pipelined win, measured (not asserted): full PipeGen
    # vs. the seed transfer path on the same machine/block
    emit("fig11.pipegen_vs_seedpath", out["pipegen_seedpath"] - out["pipegen_full"],
         f"speedup={out['pipegen_seedpath'] / out['pipegen_full']:.2f}x")
    # acceptance probe: the cross-process-capable shm ring should at least
    # match the in-process channel on colocated transfers.  Single samples
    # swing +-30% on small CI boxes, so refine both with two more
    # best-of-N samples before comparing.
    rungs = dict(RUNGS)
    for name in ("pipegen_channel", "pipegen_shm"):
        for _ in range(2):
            out[name] = min(out[name], pipe_transfer(
                "colstore", "graphstore", n_rows, rungs[name]))
        # re-emit so the CSV rows the ratio is computed from are in the CSV
        emit(f"fig11.{name}_best3", out[name], f"speedup={tf / out[name]:.2f}x")
    emit("fig11.shm_vs_channel", out["pipegen_channel"] - out["pipegen_shm"],
         f"ratio={out['pipegen_channel'] / out['pipegen_shm']:.2f}x")
    # stream-fabric rungs: striping sweep + N→M shuffle
    out["streams"] = _streams_sweep(
        n_rows,
        transports or SWEEP_TRANSPORTS,
        streams_sweep or SWEEP_STREAMS,
    )
    ts = _shuffle_probe(n_rows)
    out["shuffle_2x3"] = ts
    emit("fig11.shuffle_2x3", ts, f"vs_file={tf / ts:.2f}x")
    # the streams×partition composition: the same 2→3 shuffle with every
    # member pipe striped across 2 connections (hash partition, slotted
    # rendezvous) — benchmarked from day one so regressions surface here
    tss = _shuffle_probe(n_rows, streams=2)
    out["striped_shuffle_2x3_s2"] = tss
    emit("fig11.striped_shuffle_2x3_s2", tss, f"vs_unstriped={ts / tss:.2f}x")
    set_directory(WorkerDirectory())
    tm = _manual_pipe(n_rows)
    out["manual"] = tm
    emit("fig11.manual_pipe", tm, f"speedup={tf / tm:.2f}x")
    return out


if __name__ == "__main__":
    main()
