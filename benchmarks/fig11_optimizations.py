"""Figure 11: optimization breakdown, Myria->Giraph analog
(colstore -> graphstore).

Rungs: file baseline -> IORedirect only (text) -> +binary primitives
(parts) -> +delimiter removal (binary_rows) -> full PipeGen (arrowcol,
column pivot).  A manually-optimized pipe (hand-written socket transfer of
the typed columns, no PipeGen machinery) bounds what generation could hope
to reach.

Beyond the ladder, the stream-fabric rungs: a streams sweep (one pipe
striped across N member connections; ``run.py --transport/--streams``
override the swept sets) measured both raw and under a per-link
bandwidth cap (the multi-NIC scenario striping exists for), and an
N=2→M=3 hash-partitioned shuffle probe."""

from __future__ import annotations

import pickle
import socket
import threading
import time

from repro.core import LinkSim, PipeConfig, transfer
from repro.core.directory import WorkerDirectory, set_directory
from repro.engines import make_engine, make_paper_block

from .common import (
    DEFAULT_ROWS,
    REPEATS,
    emit,
    file_transfer,
    fresh,
    pipe_transfer,
    timed,
)

RUNGS = [
    ("ioredirect", PipeConfig(mode="text")),
    ("binary", PipeConfig(mode="parts")),
    ("delim_removed", PipeConfig(mode="binary_rows")),
    # the pre-zero-copy transfer path: per-row text serialization into the
    # assembler, concatenated single-buffer frames, strictly serial send
    ("pipegen_seedpath", PipeConfig(mode="arrowcol", pipelined=False,
                                    scatter_gather=False, block_export=False)),
    # full PipeGen: typed block export, pooled zero-copy scatter-gather
    # encode, vectored send, double-buffered pipelined sender
    ("pipegen_full", PipeConfig(mode="arrowcol")),
    # same data plane over the in-process channel (one materialization at
    # the queue boundary) and over the shared-memory ring (in-place spans,
    # zero intermediate copies, works across OS processes).  The shm rung
    # is pinned to the backoff-POLL wait path so it doubles as the
    # baseline the event-driven doorbell rung is measured against.
    ("pipegen_channel", PipeConfig(mode="arrowcol", transport="channel")),
    ("pipegen_shm", PipeConfig(mode="arrowcol", transport="shm",
                               shm_doorbell=False)),
]


def _manual_pipe(n_rows: int) -> float:
    """Hand-optimized: typed columns pickled straight over a socket."""
    src = make_engine("colstore")
    dst = make_engine("graphstore")
    src.put_block("t", make_paper_block(n_rows, seed=1))

    def run():
        lsock = socket.socket()
        lsock.bind(("127.0.0.1", 0))
        lsock.listen(1)
        port = lsock.getsockname()[1]

        def serve():
            conn, _ = lsock.accept()
            blk = src.get_block("t")
            payload = pickle.dumps((blk.schema.to_dict(),
                                    [list(map(float, c)) if not hasattr(c, "dtype")
                                     else c for c in blk.columns]))
            conn.sendall(len(payload).to_bytes(8, "little") + payload)
            conn.close()

        t = threading.Thread(target=serve)
        t.start()
        s = socket.create_connection(("127.0.0.1", port))
        ln = int.from_bytes(_recv_exact(s, 8), "little")
        schema_doc, cols = pickle.loads(_recv_exact(s, ln))
        s.close()
        t.join()
        from repro.core.types import ColumnBlock, Schema

        dst.put_block("t2", ColumnBlock(Schema.from_dict(schema_doc), cols))

    return timed(run)


def _recv_exact(sock, n):
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise IOError("eof")
        buf += chunk
    return buf


#: streams-sweep defaults (overridable via ``run.py --transport/--streams``)
SWEEP_TRANSPORTS = ("socket",)
SWEEP_STREAMS = (1, 4)
#: per-link bandwidth cap for the link-limited sweep: striping across N
#: members models N NICs, so the capped rung shows the N-fold pipe (tight
#: enough to bind even at --quick row counts)
_SWEEP_LINK_BPS = 100e6
_SWEEP_BLOCK_ROWS = 2048  # many frames even at --quick row counts


def _streams_sweep(n_rows: int, transports, streams_list) -> dict:
    out = {}
    for t in transports:
        for s in streams_list:
            cfg = PipeConfig(mode="arrowcol", transport=t, streams=s,
                             block_rows=_SWEEP_BLOCK_ROWS,
                             shm_capacity=1 << 22)
            sec = pipe_transfer("colstore", "graphstore", n_rows, cfg)
            out[(t, s)] = sec
            emit(f"fig11.streams_{t}_x{s}", sec)
        base = out.get((t, 1))
        best = min(s for s in streams_list)
        top = max(s for s in streams_list)
        if base and (t, top) in out and top != best:
            emit(f"fig11.streams{top}_vs_streams1_{t}",
                 base - out[(t, top)],
                 f"speedup={base / out[(t, top)]:.2f}x")
    # link-limited: same sweep under a per-connection bandwidth cap — the
    # multi-NIC case where striping buys aggregate bandwidth outright
    for s in sorted({min(streams_list), max(streams_list)}):
        cfg = PipeConfig(mode="arrowcol", streams=s,
                         block_rows=_SWEEP_BLOCK_ROWS,
                         link=LinkSim(bandwidth_bps=_SWEEP_LINK_BPS,
                                      min_sleep_s=0.0005))
        sec = pipe_transfer("colstore", "graphstore", n_rows, cfg)
        out[("link", s)] = sec
        emit(f"fig11.streams_link_x{s}", sec)
    lo, hi = min(streams_list), max(streams_list)
    if lo != hi:
        emit("fig11.streams_link_speedup",
             out[("link", lo)] - out[("link", hi)],
             f"speedup={out[('link', lo)] / out[('link', hi)]:.2f}x")
    return out


def _doorbell_probe(n_rows: int) -> dict:
    """Event-driven doorbell vs backoff polling, measured as what the
    doorbell actually changes: the **latency of a small transfer that
    arrives while the reader is parked idle**.  Each round sends one
    timestamp-stamped frame after an idle gap and measures publication →
    delivery.  A polled reader has backed off to the 2 ms idle cap by
    then, so its wake is late by up to a whole sleep quantum (medians
    1-2 ms with a fat tail); the doorbell is rung on commit and wakes in
    the sub-millisecond range, every time.  (End-to-end *throughput* of
    warm transfers is deliberately not the metric here: polling's
    overshoot is bounded by the cap, so bulk wall-clock ties — the
    pipegen_shm rung above covers that regime.)"""
    import statistics
    import struct

    from repro.core.shm_ring import ShmRing, ShmRingTransport
    from repro.core.transport import FRAME_EOF as _EOF, FRAME_TEXT as _TXT

    def wake_lats(doorbell: bool, rounds: int = 21,
                  idle_s: float = 0.012) -> list:
        ring = ShmRing.create(capacity=1 << 20, role="reader",
                              doorbell=doorbell)
        tx, rx = ShmRingTransport(ring), ShmRingTransport(ring)

        def send():
            for _ in range(rounds):
                time.sleep(idle_s)  # the reader reaches its deep-idle wait
                tx.send_frames(_TXT,
                               [struct.pack("<d", time.perf_counter())])
            tx.send_frames(_EOF, [b""])

        th = threading.Thread(target=send, daemon=True)
        th.start()
        lats = []
        while True:
            kind, payload = rx.recv_frame()
            if kind == _EOF:
                break
            sent = struct.unpack("<d", bytes(payload))[0]
            lats.append(time.perf_counter() - sent)
        th.join()
        ring.close()
        return sorted(lats)

    out = {}
    for name, db in (("shm_polled_wake", False), ("shm_doorbell", True)):
        lats = wake_lats(db)
        out[name] = statistics.median(lats)
        out[name + "_p90"] = lats[(len(lats) * 9) // 10]
    emit("fig11.shm_polled_wake", out["shm_polled_wake"],
         f"idle-wake latency p90={out['shm_polled_wake_p90'] * 1e3:.2f}ms")
    emit("fig11.shm_doorbell", out["shm_doorbell"],
         f"idle-wake latency p90={out['shm_doorbell_p90'] * 1e3:.2f}ms "
         f"speedup_vs_polled="
         f"{out['shm_polled_wake'] / out['shm_doorbell']:.2f}x")
    return out


def _broadcast_probe(n_rows: int) -> dict:
    """Plan fan-out A→{B,C,D} over shm: three independent SPSC edges
    (three encodes of the same relation) vs the planner's broadcast group
    (ONE encode into a ring with three reader cursors)."""
    from repro.core import plan

    def run(use_broadcast: bool) -> None:
        fresh()
        src = make_engine("colstore")
        dsts = [make_engine("colstore") for _ in range(3)]
        src.put_block("t", make_paper_block(n_rows, seed=1))
        p = plan(negotiate=False)
        for i, d in enumerate(dsts):
            # 2 MiB rings: broadcast segments are single-use (never
            # pooled), so an oversized capacity taxes every run with
            # ~3 ms/MiB of first-touch faults the pooled SPSC side
            # never pays
            p.move(src, "t", d, "t2", transport="shm",
                   broadcast=use_broadcast,
                   config=PipeConfig(mode="arrowcol",
                                     block_rows=_SWEEP_BLOCK_ROWS,
                                     shm_capacity=1 << 21))
        p.compile().execute()
        assert all(len(d.get_block("t2")) == n_rows for d in dsts)

    def sample(use_broadcast: bool) -> float:
        t0 = time.perf_counter()
        run(use_broadcast)
        return time.perf_counter() - t0

    run(False)  # warm the adapters, ring pool, and engine code paths
    run(True)
    # interleaved best-of-8 pairs: these are *throughput* samples, where
    # scheduling noise is strictly additive, so min() is the honest
    # noise-robust estimator (the timeit convention — unlike the
    # latency-tail probe above, where min() would hide exactly the tail
    # being measured); pairing makes box-state drift hit both equally
    samples: dict = {False: [], True: []}
    for _ in range(8):
        for use_broadcast in (False, True):
            samples[use_broadcast].append(sample(use_broadcast))
    out = {
        "spsc_fanout_1x3": min(samples[False]),
        "broadcast_1x3": min(samples[True]),
    }
    emit("fig11.spsc_fanout_1x3", out["spsc_fanout_1x3"])
    emit("fig11.broadcast_1x3", out["broadcast_1x3"],
         f"speedup_vs_3xspsc="
         f"{out['spsc_fanout_1x3'] / out['broadcast_1x3']:.2f}x")
    return out


def _recovery_probe(n_rows: int) -> dict:
    """Mid-stream failure recovery: kill the importer after ~85% of the
    data frames crossed a bandwidth-capped socket edge, retry once, and
    compare a *resumed* retry (exporter restarts at the acked watermark)
    against a full re-run (``resume=False``).  The capped link makes
    elapsed time track bytes moved, so the resume win is the re-send
    bound made visible: ~1.15x of one clean pass vs ~1.85x."""
    from repro.core import faults
    from repro.core.plan import plan

    n_blocks = 16
    block_rows = max(64, n_rows // n_blocks)  # always >= n_blocks frames
    # recv #15 = schema + RESUME hello + 13 data frames on the resumed
    # leg (schema + 14 data frames on the rerun leg): ~85% either way
    kill_at = 15
    link = LinkSim(bandwidth_bps=_SWEEP_LINK_BPS, min_sleep_s=0.0005)

    def run(resume: bool) -> float:
        fresh()
        src = make_engine("colstore")
        dst = make_engine("colstore")
        src.put_block("t", make_paper_block(n_rows, seed=1))
        fp = faults.FaultPlan(42).kill("transport.recv", at=kill_at,
                                       count=1)
        t0 = time.perf_counter()
        with faults.use(fp):
            res = (plan(negotiate=False)
                   .move(src, "t", dst, "t2",
                         config=PipeConfig(mode="arrowcol",
                                           block_rows=block_rows,
                                           link=link),
                         timeout=300)
                   .options(retries=1, backoff=0.001, failover=False,
                            resume=resume)
                   .compile()
                   .execute(raise_on_error=False))
        sec = time.perf_counter() - t0
        assert not res.exceptions and len(dst.get_block("t2")) == n_rows
        assert len(res.single().attempts) == 2
        return sec

    out = {"recovery_resume": float("inf"), "recovery_rerun": float("inf")}
    for _ in range(REPEATS):  # interleaved best-of-N pairs
        out["recovery_rerun"] = min(out["recovery_rerun"], run(False))
        out["recovery_resume"] = min(out["recovery_resume"], run(True))
    emit("fig11.recovery_midstream", out["recovery_resume"],
         f"resume_vs_rerun="
         f"{out['recovery_rerun'] / out['recovery_resume']:.2f}x")
    return out


def _broker_probe(n_rows: int) -> dict:
    """Broker stress rung: 200 concurrent small plans through one
    resident :class:`~repro.core.broker.PipeBroker` (shared directory,
    one doorbell-hub thread, admission capped at 16 rings) vs the
    per-transfer-directory sequential baseline (a fresh
    ``WorkerDirectory`` per plan — the pre-broker lifecycle).  The
    per-plan latency is the figure; the note carries the speedup and
    the peak process fd count, which stays bounded because parked
    idle rings share the hub instead of each holding a poller."""
    from repro.core.broker import PipeBroker, process_fd_count
    from repro.core.plan import plan

    rows = 256
    cfg = PipeConfig(mode="arrowcol", block_rows=64, transport="shm")

    def one_plan(i: int) -> None:
        src = make_engine("colstore")
        dst = make_engine("colstore")
        src.put_block("t", make_paper_block(rows, seed=i))
        res = (plan(negotiate=False)
               .move(src, "t", dst, "t2", config=cfg,
                     dataset=f"bk{i}", timeout=120)
               .compile()
               .execute(raise_on_error=False))
        assert not res.exceptions, res.exceptions
        assert len(dst.get_block("t2")) == rows

    # baseline: one directory per transfer, strictly sequential.  One
    # untimed plan first so the adapter cache is warm on both legs —
    # otherwise the baseline eats the one-off codegen cost and the
    # broker leg looks faster than it is.
    fresh()
    one_plan(0)
    n_base = 20
    t0 = time.perf_counter()
    for i in range(n_base):
        fresh()
        one_plan(i)
    base_per = (time.perf_counter() - t0) / n_base

    # broker leg: one control plane, 200 plans racing through admission
    n_plans = 200
    broker = PipeBroker(max_rings=16, admit_timeout=120.0)
    broker.install()
    errors: list = []
    fd_base = process_fd_count()
    peak = [fd_base]
    stop_sampling = threading.Event()

    def sample():
        while not stop_sampling.wait(0.002):
            peak[0] = max(peak[0], process_fd_count())

    sampler = threading.Thread(target=sample, daemon=True)
    sampler.start()
    try:
        threads = [threading.Thread(target=lambda i=i: one_plan(i), daemon=True)
                   for i in range(n_plans)]
        t0 = time.perf_counter()
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=180)
            if th.is_alive():
                errors.append(f"{th.name} still running")
        wall = time.perf_counter() - t0
        st = broker.stats()
    finally:
        stop_sampling.set()
        sampler.join(timeout=2)
        broker.stop()
        fresh()
    assert not errors, errors
    broker_per = wall / n_plans
    emit("fig11.broker_seq_baseline", base_per,
         f"n={n_base} sequential, fresh directory per plan")
    emit("fig11.broker_stress", broker_per,
         f"n={n_plans} concurrent, vs_sequential={base_per / broker_per:.2f}x"
         f" per-plan, admitted={st['admitted']}, queued={st['queued']},"
         f" peak_fds={peak[0]} (base={fd_base})")
    return {"broker_seq_baseline": base_per, "broker_stress": broker_per,
            "peak_fds": peak[0]}


def _serve_failover_broker(port: int, journal: str, recover: bool) -> None:
    """Spawn-child body for the failover rung: a served broker that
    journals to disk and lives until SIGKILLed."""
    from repro.core.broker import PipeBroker

    b = PipeBroker(serve=True, host="127.0.0.1", port=port, hub=False,
                   journal_path=journal, max_rings=16, lease_ttl=10.0,
                   sweep_every=1.0, admit_timeout=120.0)
    b.start(recover=recover)
    while True:
        time.sleep(3600.0)


def _failover_probe(n_rows: int) -> dict:
    """Broker failover rung: the same 200-plan stress as the broker
    rung, but through a SERVED broker (its own OS process, journal on
    disk) that is SIGKILLed mid-run and restarted from the journal —
    measured against the identical stress left uninterrupted.  The
    figure is the interrupted wall clock; the gate is the ratio: one
    kill+recover may cost at most 1.5x the uninterrupted run, i.e. the
    client ladder (bounded retry -> degraded rendezvous -> re-attach)
    must keep the fleet draining while the control plane is down."""
    import multiprocessing
    import os
    import shutil
    import signal
    import tempfile

    from repro.core.broker import BrokerClient
    from repro.core.plan import plan

    mp = multiprocessing.get_context("spawn")
    rows = 32
    n_plans = 200
    # connect_timeout bounds how long an attempt wedged at rendezvous
    # (its exporter died with the broker) can hold the retry hostage —
    # the knob IS part of the recovery story, so the rung pins it tight
    cfg = PipeConfig(mode="arrowcol", block_rows=32, transport="shm",
                     shm_capacity=1 << 16, connect_timeout=1.0)

    def wait_port(port: int, timeout: float = 15.0) -> None:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            try:
                socket.create_connection(("127.0.0.1", port),
                                         timeout=1.0).close()
                return
            except OSError:
                time.sleep(0.05)
        raise TimeoutError(f"broker child never listened on {port}")

    dead = [0.0]  # measured broker-down window of the killed run

    def run(kill: bool) -> float:
        fresh()
        s = socket.socket()
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
        s.close()
        tmp = tempfile.mkdtemp(prefix="pipegen-failover-")
        journal = os.path.join(tmp, "broker.journal")
        child = mp.Process(target=_serve_failover_broker,
                           args=(port, journal, False), daemon=True)
        child.start()
        wait_port(port)
        client = BrokerClient("127.0.0.1", port, admit_timeout=120.0)
        client.directory.probe_every = 0.2
        client.install()
        child2 = None
        src = make_engine("colstore")
        dst = make_engine("colstore")
        for i in range(n_plans):
            src.put_block(f"t{i}", make_paper_block(rows, seed=i))
        errors: list = []

        def one(i: int) -> None:
            try:
                res = (plan(negotiate=False)
                       .move(src, f"t{i}", dst, f"d{i}", config=cfg,
                             dataset=f"fo{i}", timeout=10)
                       .options(retries=3, backoff=0.1)
                       .compile()
                       .execute())
                assert res.ok, res.errors
            except Exception as e:  # noqa: BLE001 - aggregated below
                errors.append((i, repr(e)))

        threads = [threading.Thread(target=one, args=(i,), daemon=True)
                   for i in range(n_plans)]
        try:
            t0 = time.perf_counter()
            for th in threads:
                th.start()
            if kill:
                time.sleep(0.4)  # grants out, queue deep, plans live
                t_kill = time.perf_counter()
                os.kill(child.pid, signal.SIGKILL)
                child.join(10.0)
                time.sleep(0.3)
                child2 = mp.Process(target=_serve_failover_broker,
                                    args=(port, journal, True), daemon=True)
                child2.start()
                wait_port(port)
                dead[0] = time.perf_counter() - t_kill
            for th in threads:
                th.join(timeout=180)
            wall = time.perf_counter() - t0
            assert not errors, errors[:5]
            assert all(len(dst.get_block(f"d{i}")) == rows
                       for i in range(n_plans))
        finally:
            client.stop()
            for p in (child, child2):
                if p is not None and p.is_alive():
                    p.terminate()
                    p.join(5.0)
            shutil.rmtree(tmp, ignore_errors=True)
            fresh()
        return wall

    # one untimed pass so adapter codegen and spawn machinery are paid
    # before either leg — otherwise the first-run tax dwarfs the outage.
    # Interleaved best-of-2 pairs, like the other contended rungs: a
    # 200-thread stress swings hard on small CI boxes.  The injected
    # outage (kill -> new incarnation listening) is a test parameter,
    # not recovery overhead, so the gate is on the wall clock BEYOND
    # the dead window vs the clean run.
    run(kill=False)
    base, excess, hit, outage = float("inf"), float("inf"), 0.0, 0.0
    for _ in range(2):
        base = min(base, run(kill=False))
        h = run(kill=True)
        if h - dead[0] < excess:
            excess, hit, outage = h - dead[0], h, dead[0]
    ratio = max(excess, 0.0) / base
    emit("fig11.broker_failover", hit,
         f"n={n_plans} plans, uninterrupted={base:.3f}s, "
         f"outage={outage:.3f}s, "
         f"recover_ratio_excl_outage={ratio:.2f}x (gate <=1.5x)")
    assert ratio <= 1.5, f"broker failover cost {ratio:.2f}x > 1.5x gate"
    return {"broker_failover": hit, "broker_failover_base": base,
            "outage": outage, "ratio": ratio}


def _incremental_probe(n_rows: int) -> dict:
    """Continuous pipes: N epochs of small deltas (5% of the relation
    each) delivered through ONE long-lived subscription vs re-exporting
    the whole growing relation every epoch.  The subscription pays one
    rendezvous + one snapshot and then moves only the delta bytes; the
    re-export baseline pays a full transfer (rendezvous, encode, copy of
    every row) per refresh — the gap is the entire reason the
    subscription layer exists, so it is benchmarked, not asserted."""
    from repro.core.subscribe import apply_to_engine, publish, subscribe

    n_epochs = 20
    delta_rows = max(1, n_rows // 20)  # 5% delta rate
    base = make_paper_block(n_rows, seed=1)
    deltas = [make_paper_block(delta_rows, seed=100 + e)
              for e in range(n_epochs)]
    total = n_rows + n_epochs * delta_rows

    def run_reexport() -> float:
        fresh()
        src = make_engine("colstore")
        dst = make_engine("colstore")
        src.put_block("t", base)
        cfg = PipeConfig(mode="arrowcol", transport="shm")
        t0 = time.perf_counter()
        for d in deltas:
            src.append("t", d)
            dst.drop("t2")
            transfer(src, "t", dst, "t2", config=cfg, timeout=300)
        sec = time.perf_counter() - t0
        assert len(dst.get_block("t2")) == total
        return sec

    def run_subscription() -> float:
        d = WorkerDirectory()
        dst = make_engine("colstore")
        t0 = time.perf_counter()
        pub = publish("bench.inc", initial=base, directory=d)
        sub = subscribe("bench.inc", directory=d, transport="shm",
                        apply=apply_to_engine(dst, "t2"))
        for blk in deltas:
            pub.append(blk)
        deadline = time.monotonic() + 300
        while sub.watermark < n_epochs + 1 and time.monotonic() < deadline:
            sub.poll(timeout=0.2)
        sec = time.perf_counter() - t0
        assert len(dst.get_block("t2")) == total
        sub.close()
        pub.close()
        return sec

    run_reexport()  # warm adapters / ring pool / engine code paths
    run_subscription()
    out = {"reexport": float("inf"), "incremental": float("inf")}
    for _ in range(REPEATS):  # interleaved best-of-N pairs
        out["reexport"] = min(out["reexport"], run_reexport())
        out["incremental"] = min(out["incremental"], run_subscription())
    emit("fig11.reexport_x20", out["reexport"])
    emit("fig11.incremental_vs_reexport", out["incremental"],
         f"speedup_vs_reexport="
         f"{out['reexport'] / out['incremental']:.2f}x")
    return out


def _telemetry_probe(n_rows: int, baseline: float = 0.0) -> dict:
    """Observability tax rung: the arrowcol shm transfer (polled wait
    path, same shape as the ``pipegen_shm`` rung) with telemetry left
    OFF vs fully ON (span tracer enabled, ``trace=True`` pipes).  The
    disabled path is the contract — ``span()`` must collapse to one
    module-attribute load and a null context manager — so the figure is
    the disabled run's wall-clock delta against the plain ``pipegen_shm``
    rung (``baseline``, <2% is the acceptance bar), with the traced
    delta in the note.  Interleaved best-of-N, like the other
    throughput rungs."""
    from repro.core import disable_tracing, enable_tracing
    from repro.core.telemetry import tracer

    cfg = PipeConfig(mode="arrowcol", transport="shm", shm_doorbell=False)
    tcfg = PipeConfig(mode="arrowcol", transport="shm", shm_doorbell=False,
                      trace=True)

    pipe_transfer("colstore", "graphstore", n_rows, cfg)  # warm
    out = {"telemetry_off": float("inf"), "telemetry_on": float("inf")}
    n_spans = 0
    for _ in range(max(3, REPEATS)):
        disable_tracing()
        out["telemetry_off"] = min(
            out["telemetry_off"],
            pipe_transfer("colstore", "graphstore", n_rows, cfg))
        enable_tracing()
        try:
            out["telemetry_on"] = min(
                out["telemetry_on"],
                pipe_transfer("colstore", "graphstore", n_rows, tcfg))
            n_spans = max(n_spans, len(tracer().spans()))
        finally:
            disable_tracing()
    off_delta = (out["telemetry_off"] / baseline - 1.0) if baseline else 0.0
    on_delta = out["telemetry_on"] / out["telemetry_off"] - 1.0
    emit("fig11.telemetry_overhead", out["telemetry_off"],
         f"disabled_delta_vs_plain={off_delta * 100:+.1f}% "
         f"traced={out['telemetry_on']:.4f}s "
         f"traced_delta={on_delta * 100:+.1f}% spans={n_spans}")
    return out


def _shuffle_probe(n_rows: int, streams: int = 1) -> float:
    """N=2→M=3 hash-partitioned repartitioning transfer (colstore both
    sides: the graphstore analog cannot hold arbitrary relations).  With
    ``streams`` > 1 every shuffle member pipe is itself striped — the
    composition path (slotted rendezvous)."""

    def run():
        fresh()
        src = make_engine("colstore")
        dst = make_engine("colstore")
        src.put_block("t", make_paper_block(n_rows, seed=1))
        transfer(src, "t", dst, "t2",
                 config=PipeConfig(mode="arrowcol",
                                   block_rows=_SWEEP_BLOCK_ROWS),
                 workers=2, import_workers=3, partition="hash",
                 streams=streams if streams > 1 else None, timeout=300)
        assert len(dst.get_block("t2")) == n_rows

    return timed(run, repeats=REPEATS)


def main(n_rows: int = DEFAULT_ROWS, transports=None, streams_sweep=None) -> dict:
    out = {}
    tf = file_transfer("colstore", "graphstore", n_rows)
    out["file"] = tf
    emit("fig11.file_baseline", tf)
    for name, cfg in RUNGS:
        tp = pipe_transfer("colstore", "graphstore", n_rows, cfg)
        out[name] = tp
        emit(f"fig11.{name}", tp, f"speedup={tf / tp:.2f}x")
    # the zero-copy + pipelined win, measured (not asserted): full PipeGen
    # vs. the seed transfer path on the same machine/block
    emit("fig11.pipegen_vs_seedpath", out["pipegen_seedpath"] - out["pipegen_full"],
         f"speedup={out['pipegen_seedpath'] / out['pipegen_full']:.2f}x")
    # acceptance probe: the cross-process-capable shm ring should at least
    # match the in-process channel on colocated transfers.  Single samples
    # swing +-30% on small CI boxes, so refine both with two more
    # best-of-N samples before comparing.
    rungs = dict(RUNGS)
    for name in ("pipegen_channel", "pipegen_shm"):
        for _ in range(2):
            out[name] = min(out[name], pipe_transfer(
                "colstore", "graphstore", n_rows, rungs[name]))
        # re-emit so the CSV rows the ratio is computed from are in the CSV
        emit(f"fig11.{name}_best3", out[name], f"speedup={tf / out[name]:.2f}x")
    emit("fig11.shm_vs_channel", out["pipegen_channel"] - out["pipegen_shm"],
         f"ratio={out['pipegen_channel'] / out['pipegen_shm']:.2f}x")
    # event-driven wakeups vs polling (latency-bound small transfer) and
    # the fan-out broadcast ring (one encode feeding three importers)
    out["doorbell"] = _doorbell_probe(n_rows)
    out["broadcast"] = _broadcast_probe(n_rows)
    # self-healing transfers: resumed retry vs full re-run after a
    # mid-stream importer death on a bandwidth-capped edge
    out["recovery"] = _recovery_probe(n_rows)
    # broker stress: 200 concurrent plans through one resident broker
    # vs the per-transfer-directory sequential baseline
    out["broker"] = _broker_probe(n_rows)
    # broker failover: the same stress through a served broker with a
    # mid-run SIGKILL + journal recovery, gated at <=1.5x uninterrupted
    out["failover"] = _failover_probe(n_rows)
    # continuous pipes: one subscription moving 20 small deltas vs 20
    # full re-exports of the growing relation
    out["incremental"] = _incremental_probe(n_rows)
    # observability tax: tracing disabled (the near-free contract) vs on
    out["telemetry"] = _telemetry_probe(n_rows, baseline=out["pipegen_shm"])
    # stream-fabric rungs: striping sweep + N→M shuffle
    out["streams"] = _streams_sweep(
        n_rows,
        transports or SWEEP_TRANSPORTS,
        streams_sweep or SWEEP_STREAMS,
    )
    ts = _shuffle_probe(n_rows)
    out["shuffle_2x3"] = ts
    emit("fig11.shuffle_2x3", ts, f"vs_file={tf / ts:.2f}x")
    # the streams×partition composition: the same 2→3 shuffle with every
    # member pipe striped across 2 connections (hash partition, slotted
    # rendezvous) — benchmarked from day one so regressions surface here
    tss = _shuffle_probe(n_rows, streams=2)
    out["striped_shuffle_2x3_s2"] = tss
    emit("fig11.striped_shuffle_2x3_s2", tss, f"vs_unstriped={ts / tss:.2f}x")
    set_directory(WorkerDirectory())
    tm = _manual_pipe(n_rows)
    out["manual"] = tm
    emit("fig11.manual_pipe", tm, f"speedup={tf / tm:.2f}x")
    return out


if __name__ == "__main__":
    main()
