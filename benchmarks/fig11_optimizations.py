"""Figure 11: optimization breakdown, Myria->Giraph analog
(colstore -> graphstore).

Rungs: file baseline -> IORedirect only (text) -> +binary primitives
(parts) -> +delimiter removal (binary_rows) -> full PipeGen (arrowcol,
column pivot).  A manually-optimized pipe (hand-written socket transfer of
the typed columns, no PipeGen machinery) bounds what generation could hope
to reach."""

from __future__ import annotations

import pickle
import socket
import threading

from repro.core import PipeConfig
from repro.core.directory import WorkerDirectory, set_directory
from repro.engines import make_engine, make_paper_block

from .common import DEFAULT_ROWS, emit, file_transfer, pipe_transfer, timed

RUNGS = [
    ("ioredirect", PipeConfig(mode="text")),
    ("binary", PipeConfig(mode="parts")),
    ("delim_removed", PipeConfig(mode="binary_rows")),
    # the pre-zero-copy transfer path: per-row text serialization into the
    # assembler, concatenated single-buffer frames, strictly serial send
    ("pipegen_seedpath", PipeConfig(mode="arrowcol", pipelined=False,
                                    scatter_gather=False, block_export=False)),
    # full PipeGen: typed block export, pooled zero-copy scatter-gather
    # encode, vectored send, double-buffered pipelined sender
    ("pipegen_full", PipeConfig(mode="arrowcol")),
    # same data plane over the in-process channel (one materialization at
    # the queue boundary) and over the shared-memory ring (in-place spans,
    # zero intermediate copies, works across OS processes)
    ("pipegen_channel", PipeConfig(mode="arrowcol", transport="channel")),
    ("pipegen_shm", PipeConfig(mode="arrowcol", transport="shm")),
]


def _manual_pipe(n_rows: int) -> float:
    """Hand-optimized: typed columns pickled straight over a socket."""
    src = make_engine("colstore")
    dst = make_engine("graphstore")
    src.put_block("t", make_paper_block(n_rows, seed=1))

    def run():
        lsock = socket.socket()
        lsock.bind(("127.0.0.1", 0))
        lsock.listen(1)
        port = lsock.getsockname()[1]

        def serve():
            conn, _ = lsock.accept()
            blk = src.get_block("t")
            payload = pickle.dumps((blk.schema.to_dict(),
                                    [list(map(float, c)) if not hasattr(c, "dtype")
                                     else c for c in blk.columns]))
            conn.sendall(len(payload).to_bytes(8, "little") + payload)
            conn.close()

        t = threading.Thread(target=serve)
        t.start()
        s = socket.create_connection(("127.0.0.1", port))
        ln = int.from_bytes(_recv_exact(s, 8), "little")
        schema_doc, cols = pickle.loads(_recv_exact(s, ln))
        s.close()
        t.join()
        from repro.core.types import ColumnBlock, Schema

        dst.put_block("t2", ColumnBlock(Schema.from_dict(schema_doc), cols))

    return timed(run)


def _recv_exact(sock, n):
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise IOError("eof")
        buf += chunk
    return buf


def main(n_rows: int = DEFAULT_ROWS) -> dict:
    out = {}
    tf = file_transfer("colstore", "graphstore", n_rows)
    out["file"] = tf
    emit("fig11.file_baseline", tf)
    for name, cfg in RUNGS:
        tp = pipe_transfer("colstore", "graphstore", n_rows, cfg)
        out[name] = tp
        emit(f"fig11.{name}", tp, f"speedup={tf / tp:.2f}x")
    # the zero-copy + pipelined win, measured (not asserted): full PipeGen
    # vs. the seed transfer path on the same machine/block
    emit("fig11.pipegen_vs_seedpath", out["pipegen_seedpath"] - out["pipegen_full"],
         f"speedup={out['pipegen_seedpath'] / out['pipegen_full']:.2f}x")
    # acceptance probe: the cross-process-capable shm ring should at least
    # match the in-process channel on colocated transfers.  Single samples
    # swing +-30% on small CI boxes, so refine both with two more
    # best-of-N samples before comparing.
    rungs = dict(RUNGS)
    for name in ("pipegen_channel", "pipegen_shm"):
        for _ in range(2):
            out[name] = min(out[name], pipe_transfer(
                "colstore", "graphstore", n_rows, rungs[name]))
        # re-emit so the CSV rows the ratio is computed from are in the CSV
        emit(f"fig11.{name}_best3", out[name], f"speedup={tf / out[name]:.2f}x")
    emit("fig11.shm_vs_channel", out["pipegen_channel"] - out["pipegen_shm"],
         f"ratio={out['pipegen_channel'] / out['pipegen_shm']:.2f}x")
    set_directory(WorkerDirectory())
    tm = _manual_pipe(n_rows)
    out["manual"] = tm
    emit("fig11.manual_pipe", tm, f"speedup={tf / tm:.2f}x")
    return out


if __name__ == "__main__":
    main()
