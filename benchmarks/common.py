"""Shared benchmark harness for the paper-figure reproductions.

Sizes are scaled to a single-core CI box (the paper used 16-node EC2); the
*ratios* (speedups) are the reproduction target, not absolute times.  Each
module prints ``name,us_per_call,derived`` CSV rows via :func:`emit`.
"""

from __future__ import annotations

import sys
import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

from repro.core import PipeConfig, transfer, transfer_via_files
from repro.core.directory import WorkerDirectory, set_directory
from repro.engines import make_engine, make_paper_block

DEFAULT_ROWS = 20_000
REPEATS = 2


def fresh() -> None:
    set_directory(WorkerDirectory())


def timed(fn: Callable[[], Any], repeats: int = REPEATS) -> float:
    """Best-of-N wall time in seconds."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def pipe_transfer(src_name: str, dst_name: str, n_rows: int,
                  config: Optional[PipeConfig] = None, workers: int = 1,
                  strings: bool = False, block=None) -> float:
    fresh()
    src = make_engine(src_name, workers=workers)
    dst = make_engine(dst_name, workers=workers)
    src.put_block("t", block if block is not None
                  else make_paper_block(n_rows, seed=1, strings=strings))

    def run():
        dst.drop("t2")
        transfer(src, "t", dst, "t2", config=config, workers=workers,
                 timeout=300)

    return timed(run)


def file_transfer(src_name: str, dst_name: str, n_rows: int,
                  workers: int = 1, strings: bool = False,
                  block=None) -> float:
    fresh()
    src = make_engine(src_name, workers=workers)
    dst = make_engine(dst_name, workers=workers)
    src.put_block("t", block if block is not None
                  else make_paper_block(n_rows, seed=1, strings=strings))

    def run():
        dst.drop("t2")
        transfer_via_files(src, "t", dst, "t2", workers=workers)

    return timed(run)


#: every :func:`emit` also lands here, so ``run.py --json`` can dump the
#: whole sweep as one structured artifact (name -> seconds/derived)
RESULTS: Dict[str, dict] = {}


def emit(name: str, seconds: float, derived: str = "") -> None:
    RESULTS[name] = {"seconds": seconds, "derived": derived}
    print(f"{name},{seconds * 1e6:.0f},{derived}")
    sys.stdout.flush()
