"""Figure 10: speedup by data type (fixed-width primitives benefit most;
strings still win by skipping the file system)."""

from __future__ import annotations

import numpy as np

from repro.core import PipeConfig
from repro.core.types import ColType, ColumnBlock, Field, Schema

from .common import DEFAULT_ROWS, emit, file_transfer, pipe_transfer


def _block(kind: str, n: int) -> ColumnBlock:
    rng = np.random.default_rng(0)
    if kind == "int":
        cols = [rng.integers(0, 1 << 40, n) for _ in range(4)]
        fields = [Field(f"c{i}", ColType.INT64) for i in range(4)]
    elif kind == "float":
        cols = [rng.standard_normal(n) for _ in range(4)]
        fields = [Field(f"c{i}", ColType.FLOAT64) for i in range(4)]
    elif kind == "bool":
        cols = [rng.integers(0, 2, n).astype(bool) for _ in range(4)]
        fields = [Field(f"c{i}", ColType.BOOL) for i in range(4)]
    else:  # string
        cols = [[f"s{x:012d}" for x in rng.integers(0, 1 << 40, n)]
                for _ in range(4)]
        fields = [Field(f"c{i}", ColType.STRING) for i in range(4)]
    return ColumnBlock(Schema(fields), cols)


def main(n_rows: int = DEFAULT_ROWS) -> dict:
    out = {}
    for kind in ("int", "float", "bool", "string"):
        blk = _block(kind, n_rows)
        tf = file_transfer("colstore", "dataframe", n_rows, block=blk)
        tp = pipe_transfer("colstore", "dataframe", n_rows,
                           PipeConfig(mode="arrowcol"), block=blk)
        sp = tf / tp
        out[kind] = sp
        emit(f"fig10.{kind}.file", tf)
        emit(f"fig10.{kind}.pipe", tp, f"speedup={sp:.2f}x")
    return out


if __name__ == "__main__":
    main()
