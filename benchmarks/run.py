"""Benchmark runner: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Usage:

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig09,...] \
        [--transport socket,shm] [--streams 1,2,4] [--plan] [--json PATH]

``--transport``/``--streams`` widen the fig11 stream-fabric sweep (which
transports to stripe over and which stream counts to compare; defaults:
socket, 1 vs 4).  ``--plan`` adds the plan-API sweep (single edge vs
chained A→B→C vs fan-out A→{B,C}; ``benchmarks/plan_sweep.py``).
``--json PATH`` additionally writes every emitted rung (plus per-module
elapsed times and errors) as one structured JSON document — the artifact
CI uploads so the perf trajectory is machine-readable, not stdout-only.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from . import common
from . import (
    fig09_pairwise,
    fig10_datatypes,
    fig11_optimizations,
    fig12_library,
    fig13_formats,
    fig14_buffers,
    fig15_compression,
    plan_sweep,
    roofline,
    table1_workers,
    table2_modifications,
)

MODULES = {
    "fig09": fig09_pairwise,
    "fig10": fig10_datatypes,
    "fig11": fig11_optimizations,
    "fig12": fig12_library,
    "fig13": fig13_formats,
    "fig14": fig14_buffers,
    "fig15": fig15_compression,
    "table1": table1_workers,
    "table2": table2_modifications,
    "roofline": roofline,
    "plan": plan_sweep,
}


def provenance(argv, quick: bool) -> dict:
    """Everything needed to interpret a sweep after the fact: what code
    ran, where, on which toolchain, with which transports available.
    Every probe is individually best-effort — a missing git binary or a
    CPU-only jax must not fail the run."""
    import platform
    import socket as socketmod
    import subprocess

    prov = {
        "argv": list(argv) if argv is not None else sys.argv[1:],
        "quick": quick,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "hostname": socketmod.gethostname(),
        "platform": platform.platform(),
        "python": platform.python_version(),
    }
    try:
        prov["git_sha"] = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10).stdout.strip() or None
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], capture_output=True,
            text=True, timeout=10).stdout.strip()
        prov["git_dirty"] = bool(dirty)
    except Exception:  # noqa: BLE001 - no git / not a checkout
        prov["git_sha"] = None
    for modname in ("numpy", "jax"):
        try:
            prov[modname] = __import__(modname).__version__
        except Exception:  # noqa: BLE001 - optional dep absent
            prov[modname] = None
    try:
        from repro.core.shm_ring import doorbell_supported

        prov["transports"] = {
            "socket": True, "channel": True, "shm": True,
            "shm_doorbell": bool(doorbell_supported()),
        }
    except Exception:  # noqa: BLE001
        prov["transports"] = None
    return prov


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller row counts (CI mode)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmarks")
    ap.add_argument("--transport", default=None,
                    help="comma-separated transports for the fig11 streams "
                         "sweep (socket,channel,shm)")
    ap.add_argument("--streams", default=None,
                    help="comma-separated stream counts for the fig11 "
                         "streams sweep (e.g. 1,2,4)")
    ap.add_argument("--plan", action="store_true",
                    help="include the plan-API sweep (chain vs fan-out "
                         "vs single edge)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write all emitted rungs as one structured "
                         "JSON file (name -> seconds/derived)")
    args = ap.parse_args(argv)

    if not args.only:
        names = [n for n in MODULES if n != "plan" or args.plan]
    else:
        names = args.only.split(",")
        if args.plan and "plan" not in names:
            names.append("plan")
    unknown = [n for n in names if n not in MODULES]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; have {sorted(MODULES)}")
    streams_sweep = None
    if args.streams:
        try:
            streams_sweep = [int(s) for s in args.streams.split(",")]
        except ValueError:
            ap.error(f"--streams must be comma-separated ints, got "
                     f"{args.streams!r}")
        if any(s < 1 for s in streams_sweep):
            ap.error("--streams values must be >= 1")
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        mod = MODULES[name]
        kwargs = {}
        if name == "fig11":
            if args.transport:
                kwargs["transports"] = args.transport.split(",")
            if streams_sweep:
                kwargs["streams_sweep"] = streams_sweep
        t0 = time.time()
        try:
            if args.quick and name.startswith(("fig", "table1", "plan")):
                mod.main(4000, **kwargs)
            else:
                mod.main(**kwargs)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}.ERROR,0,{type(e).__name__}: {e}")
            common.RESULTS[f"{name}.ERROR"] = {
                "seconds": 0.0, "derived": f"{type(e).__name__}: {e}"}
        elapsed = time.time() - t0
        print(f"{name}.elapsed,{elapsed * 1e6:.0f},")
        common.RESULTS[f"{name}.elapsed"] = {"seconds": elapsed,
                                             "derived": ""}
        sys.stdout.flush()
    if args.json:
        doc = {"provenance": provenance(argv, args.quick),
               "results": common.RESULTS}
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
