"""Figure 13: intermediate wire format comparison, Hadoop->Spark analog
(mapreduce -> dataframe).

Formats: custom binary (binary_rows), protobuf-analog static + dynamic
templates (tagged), Arrow-analog row (arrowrow) and columnar (arrowcol)."""

from __future__ import annotations

from repro.core import PipeConfig

from .common import DEFAULT_ROWS, emit, pipe_transfer

FORMATS = [
    ("custom_binary", PipeConfig(mode="binary_rows")),
    ("proto_static", PipeConfig(mode="tagged")),
    ("proto_dynamic", PipeConfig(mode="tagged", text_format="csv",
                                 delimiter="\t")),
    ("arrow_row", PipeConfig(mode="arrowrow")),
    ("arrow_col", PipeConfig(mode="arrowcol")),
]


def main(n_rows: int = DEFAULT_ROWS) -> dict:
    out = {}
    for name, cfg in FORMATS:
        t = pipe_transfer("mapreduce", "dataframe", n_rows, cfg)
        out[name] = t
        emit(f"fig13.{name}", t)
    best = min(out, key=out.get)
    emit("fig13.summary", 0.0, f"best={best} paper_best=arrow_col")
    return out


if __name__ == "__main__":
    main()
