"""Figure 9: total speedup, file system vs PipeGen, for every engine pair.

Paper: 1e9 elements, 16 workers, CSV; avg 3.2x, max 3.8x.  Here: scaled
rows, same 20-pair matrix, speedup = file_time / pipe_time.
"""

from __future__ import annotations

from repro.core import PipeConfig
from repro.engines import ENGINES

from .common import DEFAULT_ROWS, emit, file_transfer, pipe_transfer


def main(n_rows: int = DEFAULT_ROWS) -> dict:
    speedups = {}
    for s in ENGINES:
        for d in ENGINES:
            if s == d:
                continue
            tf = file_transfer(s, d, n_rows)
            tp = pipe_transfer(s, d, n_rows, PipeConfig(mode="arrowcol"))
            sp = tf / tp
            speedups[(s, d)] = sp
            emit(f"fig09.{s}->{d}.file", tf)
            emit(f"fig09.{s}->{d}.pipe", tp, f"speedup={sp:.2f}x")
    avg = sum(speedups.values()) / len(speedups)
    mx = max(speedups.values())
    emit("fig09.summary", 0.0,
         f"avg={avg:.2f}x max={mx:.2f}x paper_avg=3.2x paper_max=3.8x")
    return {"avg": avg, "max": mx, "speedups": speedups}


if __name__ == "__main__":
    main()
