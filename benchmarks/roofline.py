"""Roofline report: read the dry-run artifacts and print the per-cell
three-term table (compute / memory / collective seconds, dominant term,
useful-flops ratio).  The dry-run itself must run as its own process
(``python -m repro.launch.dryrun --all --both-meshes``)."""

from __future__ import annotations

import json
import sys
from pathlib import Path

from .common import emit

ARTIFACTS = Path("artifacts/dryrun")


def load_records(mesh: str = "pod16x16"):
    recs = []
    if not ARTIFACTS.exists():
        return recs
    for f in sorted(ARTIFACTS.glob(f"*__{mesh}.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def main() -> dict:
    out = {}
    for mesh in ("pod16x16", "pod2x16x16"):
        recs = load_records(mesh)
        n_ok = sum(r["status"] == "OK" for r in recs)
        n_skip = sum(r["status"] == "SKIP" for r in recs)
        n_fail = sum(r["status"] == "FAIL" for r in recs)
        emit(f"roofline.{mesh}.cells", 0.0,
             f"ok={n_ok} skip={n_skip} fail={n_fail}")
        for r in recs:
            key = f"{r['arch']}x{r['shape']}"
            if r["status"] != "OK":
                out[(mesh, key)] = r["status"]
                continue
            t = r["roofline"]
            dom = max(t, key=t.get)
            out[(mesh, key)] = dom
            emit(
                f"roofline.{mesh}.{key}", t[dom],
                f"compute={t['compute_s']:.4g}s memory={t['memory_s']:.4g}s "
                f"collective={t['collective_s']:.4g}s dominant={dom} "
                f"useful={r.get('useful_ratio') or 0:.2f}",
            )
    return out


if __name__ == "__main__":
    main()
