"""Decoder-only LM assembly for the dense / moe / vlm / ssm / hybrid families.

Everything is scan-over-layers (stacked [L, ...] params) so the lowered HLO
stays compact for the 512-device dry-run, and functional:

    params = init(rng, cfg)
    logits = forward(params, cfg, batch, mesh)          # train / prefill
    loss, metrics = loss_fn(params, cfg, batch, mesh)
    cache  = init_cache(cfg, batch_size, seq_len)
    logits, cache = decode_step(params, cfg, cache, tok, mesh)  # serving
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    Params,
    attention,
    attention_decode,
    attention_init,
    dtype_of,
    embed,
    embedding_init,
    mlp,
    mlp_init,
    moe,
    moe_init,
    rmsnorm,
    rmsnorm_init,
    unembed,
)
from .mamba2 import (
    CONV_K,
    NGROUPS,
    _dims as _mamba_dims,
    mamba2_block,
    mamba2_block_init,
    mamba2_init_state,
)
from .rwkv6 import rwkv6_block, rwkv6_block_init, rwkv6_init_state


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #

def _layer_init(key, cfg: ModelConfig) -> Params:
    if cfg.family == "ssm":
        return rwkv6_block_init(key, cfg)
    if cfg.family == "hybrid":
        return mamba2_block_init(key, cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln_attn": rmsnorm_init(cfg),
        "attn": attention_init(k1, cfg),
        "ln_mlp": rmsnorm_init(cfg),
    }
    if cfg.moe_experts:
        p["moe"] = moe_init(k2, cfg)
    else:
        p["mlp"] = mlp_init(k2, cfg)
    return p


def init(rng, cfg: ModelConfig) -> Params:
    k_emb, k_layers, k_shared, k_ln = jax.random.split(rng, 4)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params: Params = {
        "embedding": embedding_init(k_emb, cfg),
        "layers": jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys),
        "ln_final": rmsnorm_init(cfg),
    }
    if cfg.family == "hybrid":
        params["shared_attn"] = {
            "ln": rmsnorm_init(cfg),
            "attn": attention_init(k_shared, cfg),
        }
    return params


# --------------------------------------------------------------------------- #
# forward (train / prefill)
# --------------------------------------------------------------------------- #

def _attn_block(lp: Params, cfg: ModelConfig, x, pos, mesh):
    h = rmsnorm(lp["ln_attn"], x, cfg.norm_eps)
    x = x + attention(lp["attn"], cfg, h, pos, mesh=mesh)
    h = rmsnorm(lp["ln_mlp"], x, cfg.norm_eps)
    if cfg.moe_experts:
        x = x + moe(lp["moe"], cfg, h, mesh)
    else:
        x = x + mlp(lp["mlp"], h)
    return x


def _hidden_forward(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                    pos: jnp.ndarray, mesh) -> jnp.ndarray:
    """Run the layer stack over embedded inputs x: [B,S,d]."""
    B, S, _ = x.shape
    if cfg.family == "ssm":
        state0 = rwkv6_init_state(cfg, B)

        def body(carry, lp):
            h, st = rwkv6_block(lp, cfg, carry, state0, mesh=mesh)
            return h, None

        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["layers"],
                            unroll=cfg.scan_unroll)
    elif cfg.family == "hybrid":
        st0 = mamba2_init_state(cfg, B)
        shared = params["shared_attn"]

        def body(carry, inp):
            lp, idx = inp
            h, _ = mamba2_block(lp, cfg, carry, st0)

            def with_attn(hh):
                a = rmsnorm(shared["ln"], hh, cfg.norm_eps)
                return hh + attention(shared["attn"], cfg, a, pos, mesh=mesh)

            h = jax.lax.cond(idx % cfg.shared_attn_every == 0,
                             with_attn, lambda hh: hh, h)
            return h, None

        idxs = jnp.arange(cfg.n_layers)
        x, _ = jax.lax.scan(jax.checkpoint(body), x, (params["layers"], idxs),
                            unroll=cfg.scan_unroll)
    else:
        def body(carry, lp):
            return _attn_block(lp, cfg, carry, pos, mesh), None

        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["layers"],
                            unroll=cfg.scan_unroll)
    return rmsnorm(params["ln_final"], x, cfg.norm_eps)


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            mesh=None) -> jnp.ndarray:
    """Returns logits [B,S,V]."""
    if cfg.family == "vlm":
        x = batch["embeds"].astype(dtype_of(cfg))
        pos = batch["positions"]                       # [3,B,S] (M-RoPE ids)
        B, S = x.shape[0], x.shape[1]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed(params["embedding"], tokens)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = _hidden_forward(params, cfg, x, pos, mesh)
    return unembed(params["embedding"], x)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            mesh=None) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits = forward(params, cfg, batch, mesh).astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    loss = jnp.mean(nll)
    return loss, {"loss": loss, "ppl_proxy": jnp.exp(jnp.minimum(loss, 20.0))}


# --------------------------------------------------------------------------- #
# serving: KV / state caches + single-token decode
# --------------------------------------------------------------------------- #

def n_shared_apps(cfg: ModelConfig) -> int:
    k = cfg.shared_attn_every
    return (cfg.n_layers + k - 1) // k if k else 0


def init_cache(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """Decode-time cache sized for a context of ``seq`` tokens."""
    dt = dtype_of(cfg)
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    if cfg.family == "ssm":
        xa, xf, wkv = rwkv6_init_state(cfg, batch)
        stack = lambda t: jnp.broadcast_to(t, (L,) + t.shape)
        return {"xp_att": stack(xa), "xp_ffn": stack(xf),
                "wkv": stack(wkv), "index": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        conv, ssm = mamba2_init_state(cfg, batch)
        stack = lambda t: jnp.broadcast_to(t, (L,) + t.shape)
        apps = n_shared_apps(cfg)
        return {
            "conv": stack(conv), "ssm": stack(ssm),
            "shared_k": jnp.zeros((apps, batch, seq, KV, hd), dt),
            "shared_v": jnp.zeros((apps, batch, seq, KV, hd), dt),
            "index": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((L, batch, seq, KV, hd), dt),
        "v": jnp.zeros((L, batch, seq, KV, hd), dt),
        "index": jnp.zeros((), jnp.int32),
    }


def decode_step(params: Params, cfg: ModelConfig, cache: Dict[str, Any],
                batch: Dict[str, jnp.ndarray], mesh=None):
    """One new token against the cache.  batch: {"token": [B,1]} (vlm:
    {"embed": [B,1,d]}).  Returns (logits [B,1,V], new cache)."""
    if cfg.family == "vlm":
        x = batch["embed"].astype(dtype_of(cfg))
    else:
        x = embed(params["embedding"], batch["token"])
    index = cache["index"]

    if cfg.family == "ssm":
        def body(carry, inp):
            h = carry
            lp, xa, xf, wkv = inp
            h, (xa, xf, wkv) = rwkv6_block(lp, cfg, h, (xa, xf, wkv),
                                           mesh=mesh)
            return h, (xa, xf, wkv)

        x, (xa, xf, wkv) = jax.lax.scan(
            body, x, (params["layers"], cache["xp_att"], cache["xp_ffn"],
                      cache["wkv"]))
        new_cache = dict(cache, xp_att=xa, xp_ffn=xf, wkv=wkv, index=index + 1)
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        apps = n_shared_apps(cfg)

        def body(carry, inp):
            h, sk, sv = carry
            lp, conv, ssm, idx = inp
            h, (conv, ssm) = mamba2_block(lp, cfg, h, (conv, ssm))
            app = idx // cfg.shared_attn_every

            def with_attn(op):
                hh, sk, sv = op
                a = rmsnorm(shared["ln"], hh, cfg.norm_eps)
                o, k_l, v_l = attention_decode(
                    shared["attn"], cfg, a, sk[app], sv[app], index)
                sk = jax.lax.dynamic_update_index_in_dim(sk, k_l, app, 0)
                sv = jax.lax.dynamic_update_index_in_dim(sv, v_l, app, 0)
                return hh + o, sk, sv

            h, sk, sv = jax.lax.cond(
                idx % cfg.shared_attn_every == 0, with_attn,
                lambda op: op, (h, sk, sv))
            return (h, sk, sv), (conv, ssm)

        idxs = jnp.arange(cfg.n_layers)
        (x, sk, sv), (conv, ssm) = jax.lax.scan(
            body, (x, cache["shared_k"], cache["shared_v"]),
            (params["layers"], cache["conv"], cache["ssm"], idxs))
        new_cache = dict(cache, conv=conv, ssm=ssm, shared_k=sk, shared_v=sv,
                         index=index + 1)
    else:
        def body(carry, inp):
            h = carry
            lp, k_l, v_l = inp
            a = rmsnorm(lp["ln_attn"], h, cfg.norm_eps)
            o, k_l, v_l = attention_decode(lp["attn"], cfg, a, k_l, v_l, index)
            h = h + o
            a = rmsnorm(lp["ln_mlp"], h, cfg.norm_eps)
            if cfg.moe_experts:
                h = h + moe(lp["moe"], cfg, a, mesh)
            else:
                h = h + mlp(lp["mlp"], a)
            return h, (k_l, v_l)

        x, (k, v) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                           cache["v"]))
        new_cache = dict(cache, k=k, v=v, index=index + 1)

    x = rmsnorm(params["ln_final"], x, cfg.norm_eps)
    return unembed(params["embedding"], x), new_cache
