"""Mamba-2 (SSD) block — the zamba2 backbone layer.

Pure-JAX reference: selective state-space recurrence as ``lax.scan`` over
time (the Pallas chunked kernel in ``repro.kernels.mamba2_ssd`` implements
the chunk-parallel SSD form for TPU).

State per layer (decode): (conv_state [B, K-1, d_conv_in], ssm_state
[B, nheads, hd, N]).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _dense_init, dtype_of, rmsnorm, rmsnorm_init

Params = Dict[str, Any]

CONV_K = 4   # depthwise causal conv window
NGROUPS = 1  # B/C groups


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_in = cfg.expand * cfg.d_model
    hd = cfg.ssm_head_dim
    nheads = d_in // hd
    N = cfg.ssm_state
    return d_in, hd, nheads, N


def mamba2_block_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_in, hd, nheads, N = _dims(cfg)
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 6)
    conv_dim = d_in + 2 * NGROUPS * N
    return {
        "ln": rmsnorm_init(cfg),
        # in_proj: x -> [z (d_in), xBC (conv_dim), dt (nheads)]
        "w_in": _dense_init(ks[0], (d, 2 * d_in + 2 * NGROUPS * N + nheads), dt),
        "conv_w": _dense_init(ks[1], (CONV_K, conv_dim), dt, scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.zeros((nheads,), jnp.float32),       # A = -exp(A_log)
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.full((nheads,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "ln_out": rmsnorm_init(cfg, d_in),
        "w_out": _dense_init(ks[2], (d_in, d), dt),
    }


def _split_in(cfg: ModelConfig, proj: jnp.ndarray):
    d_in, hd, nheads, N = _dims(cfg)
    z = proj[..., :d_in]
    xBC = proj[..., d_in: 2 * d_in + 2 * NGROUPS * N]
    dt = proj[..., 2 * d_in + 2 * NGROUPS * N:]
    return z, xBC, dt


def _causal_conv(xBC: jnp.ndarray, conv_state: jnp.ndarray,
                 w: jnp.ndarray, b: jnp.ndarray):
    """Depthwise causal conv (window CONV_K) via shifted adds.

    xBC: [B,S,C]; conv_state: [B,K-1,C] (inputs before position 0).
    Returns (out [B,S,C], new_conv_state [B,K-1,C])."""
    full = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
    S = xBC.shape[1]
    out = b
    for i in range(CONV_K):
        out = out + full[:, i: i + S, :] * w[i]
    new_state = full[:, S:, :]  # last K-1 inputs
    return jax.nn.silu(out.astype(jnp.float32)).astype(xBC.dtype), new_state


def _ssd_scan(x, dt, A, B, C, D, state):
    """Selective scan.

    x: [B,S,H,hd]; dt: [B,S,H] (post-softplus); A: [H] (negative);
    B,C: [B,S,N] (ngroups=1, shared across heads); D: [H];
    state: [B,H,hd,N].  Returns (y [B,S,H,hd], new state).
    """
    def step(s, inp):
        xt, dtt, Bt, Ct = inp          # [B,H,hd], [B,H], [B,N], [B,N]
        da = jnp.exp(dtt * A)          # [B,H]
        dBx = jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, Bt)
        s = da[..., None, None] * s + dBx
        yt = jnp.einsum("bhpn,bn->bhp", s, Ct) + D[None, :, None] * xt
        return s, yt

    xs = jnp.moveaxis(x, 1, 0)
    dts = jnp.moveaxis(dt, 1, 0)
    Bs = jnp.moveaxis(B, 1, 0)
    Cs = jnp.moveaxis(C, 1, 0)
    state, ys = jax.lax.scan(step, state, (xs, dts, Bs, Cs))
    return jnp.moveaxis(ys, 0, 1), state


def mamba2_block(p: Params, cfg: ModelConfig, x: jnp.ndarray, state: Tuple):
    """x: [B,S,d]; state: (conv_state, ssm_state)."""
    conv_state, ssm_state = state
    B_, S, d = x.shape
    d_in, hd, nheads, N = _dims(cfg)
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", h, p["w_in"])
    z, xBC, dt_raw = _split_in(cfg, proj)
    xBC, conv_state = _causal_conv(xBC, conv_state, p["conv_w"], p["conv_b"])
    xin = xBC[..., :d_in].reshape(B_, S, nheads, hd)
    Bmat = xBC[..., d_in: d_in + NGROUPS * N].astype(jnp.float32)
    Cmat = xBC[..., d_in + NGROUPS * N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    A = -jnp.exp(p["A_log"])
    y, ssm_state = _ssd_scan(
        xin.astype(jnp.float32), dt, A, Bmat, Cmat, p["D"], ssm_state
    )
    y = y.reshape(B_, S, d_in).astype(x.dtype)
    y = rmsnorm(p["ln_out"], y, cfg.norm_eps)
    y = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return x + out, (conv_state, ssm_state)


def mamba2_init_state(cfg: ModelConfig, batch: int, dtype=None):
    d_in, hd, nheads, N = _dims(cfg)
    conv_dim = d_in + 2 * NGROUPS * N
    dt = dtype or jnp.dtype(cfg.dtype)
    return (
        jnp.zeros((batch, CONV_K - 1, conv_dim), dt),
        jnp.zeros((batch, nheads, hd, N), jnp.float32),
    )
