"""Shared model layers (pure-JAX, functional): norms, rotary embeddings,
GQA attention with KV cache, SwiGLU MLP, and capacity-based MoE.

Conventions
-----------
* Params are nested dicts of ``jnp.ndarray``; per-layer params are stacked on
  a leading ``L`` axis by the model assemblers and consumed via
  ``jax.lax.scan`` (compact HLO — essential for the 512-device dry-run).
* Activations flow in ``cfg.dtype`` (bf16 by default); norms/softmax/router
  run in f32.
* The MoE block is expert-parallel via ``shard_map`` over the ``model`` mesh
  axis: activations are replicated over that axis between blocks (standard
  TP layout), so each shard simply *selects* the tokens routed to its local
  experts and the combine is the same ``psum`` a TP FFN needs anyway — no
  explicit all-to-all, balanced compute, capacity-factor drop policy.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig

Params = Dict[str, Any]


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------- #
# initializers
# --------------------------------------------------------------------------- #

def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------------- #

def rmsnorm_init(cfg: ModelConfig, width: Optional[int] = None) -> Params:
    return {"scale": jnp.ones((width or cfg.d_model,), jnp.float32)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


def layernorm_init(cfg: ModelConfig, width: Optional[int] = None) -> Params:
    w = width or cfg.d_model
    return {"scale": jnp.ones((w,), jnp.float32), "bias": jnp.zeros((w,), jnp.float32)}


def layernorm(params: Params, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# rotary embeddings (RoPE and M-RoPE)
# --------------------------------------------------------------------------- #

def _rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, hd]; pos: [B, S] (int)."""
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)                       # [hd/2]
    ang = pos[..., None].astype(jnp.float32) * freqs     # [B, S, hd/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, pos: jnp.ndarray, theta: float,
                sections: Tuple[int, int, int] = (1, 1, 2)) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL): the head dim is split into
    temporal/height/width sections, each rotated by its own position id.

    x: [B, S, H, hd]; pos: [3, B, S] (t/h/w ids; for pure text all equal).
    ``sections`` are relative weights over the hd/2 frequency slots.
    """
    hd = x.shape[-1]
    half = hd // 2
    total = sum(sections)
    bounds = []
    acc = 0
    for s in sections[:-1]:
        acc += (half * s) // total
        bounds.append(acc)
    freqs = _rope_freqs(hd, theta)                       # [half]
    # per-frequency-slot section id: 0,1,2
    slot = jnp.zeros((half,), jnp.int32)
    slot = jnp.where(jnp.arange(half) >= bounds[0], 1, slot)
    slot = jnp.where(jnp.arange(half) >= bounds[1], 2, slot)
    # gather per-slot positions: pos_sel [B, S, half]
    pos_f = pos.astype(jnp.float32)                      # [3, B, S]
    pos_sel = jnp.take(pos_f, slot, axis=0)              # [half, B, S]
    pos_sel = jnp.moveaxis(pos_sel, 0, -1)               # [B, S, half]
    ang = pos_sel * freqs                                # [B, S, half]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# attention (GQA, optional QKV bias, KV cache)
# --------------------------------------------------------------------------- #

def attention_init(key, cfg: ModelConfig, cross: bool = False) -> Params:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = dtype_of(cfg)
    k = jax.random.split(key, 4)
    p: Params = {
        "wq": _dense_init(k[0], (d, H, hd), dt),
        "wk": _dense_init(k[1], (d, KV, hd), dt),
        "wv": _dense_init(k[2], (d, KV, hd), dt),
        "wo": _dense_init(k[3], (H, hd, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((KV, hd), dt)
        p["bv"] = jnp.zeros((KV, hd), dt)
    return p


def _project_qkv(params: Params, xq: jnp.ndarray, xkv: jnp.ndarray):
    q = jnp.einsum("bsd,dhk->bshk", xq, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xkv, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return q, k, v


def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """q: [B,Sq,H,hd], k: [B,Sk,KV,hd] -> scores [B,H,Sq,Sk] (f32)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(B, Sq, KV, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32))
    return s.reshape(B, KV * g, Sq, k.shape[1]) / math.sqrt(hd)


def _gqa_out(w: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """w: [B,H,Sq,Sk] (f32), v: [B,Sk,KV,hd] -> [B,Sq,H,hd]."""
    B, H, Sq, Sk = w.shape
    KV = v.shape[2]
    g = H // KV
    wg = w.reshape(B, KV, g, Sq, Sk)
    o = jnp.einsum("bkgqs,bskh->bqkgh", wg, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, v.shape[3])


def _constrain_seq(t: jnp.ndarray, mesh, seq_dim: int) -> jnp.ndarray:
    """Context-parallel constraint: shard a sequence dim over `model` (head-
    count independent — works for 15/28/40-head models on a 16-way axis)."""
    if mesh is None or "model" not in mesh.axis_names:
        return t
    if t.shape[seq_dim] % mesh.shape["model"] != 0:
        return t
    baxes = tuple(a for a in mesh.axis_names if a != "model")
    dims: list = [None] * t.ndim
    dims[0] = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    dims[seq_dim] = "model"
    return jax.lax.with_sharding_constraint(
        t, jax.sharding.NamedSharding(mesh, P(*dims)))


def attention(params: Params, cfg: ModelConfig, x: jnp.ndarray,
              pos: jnp.ndarray, *, causal: bool = True,
              x_kv: Optional[jnp.ndarray] = None, mesh=None) -> jnp.ndarray:
    """Full-sequence attention (train / prefill). pos: [B,S] or [3,B,S].

    With a mesh, the query sequence dim is sharded over `model` (context
    parallelism): score/softmax compute and memory scale 1/|model| for any
    head count; K/V stay gathered (they are KV-head sized, GQA-small)."""
    xkv = x if x_kv is None else x_kv
    q, k, v = _project_qkv(params, x, xkv)
    if cfg.rope == "mrope":
        q, k = apply_mrope(q, pos, cfg.rope_theta), apply_mrope(k, pos, cfg.rope_theta)
    elif cfg.rope == "rope":
        q, k = apply_rope(q, pos, cfg.rope_theta), apply_rope(k, pos, cfg.rope_theta)
    q = _constrain_seq(q, mesh, 1)
    scores = _gqa_scores(q, k)
    scores = _constrain_seq(scores, mesh, 2)
    if causal and x_kv is None:
        Sq, Sk = scores.shape[-2], scores.shape[-1]
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), Sk - Sq)
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o = _gqa_out(w, v)
    o = _constrain_seq(o, mesh, 1)
    return jnp.einsum("bshk,hkd->bsd", o.astype(x.dtype), params["wo"])


def attention_decode(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                     cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                     index: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode over a KV cache.

    x: [B, 1, d]; cache_k/v: [B, S, KV, hd]; index: [] current position.
    Returns (out [B,1,d], new_cache_k, new_cache_v).
    """
    B, S, KV, hd = cache_k.shape
    q, k, v = _project_qkv(params, x, x)
    pos = jnp.full((B, 1), index, jnp.int32)
    if cfg.rope == "mrope":
        pos3 = jnp.broadcast_to(pos, (3,) + pos.shape)
        q, k = apply_mrope(q, pos3, cfg.rope_theta), apply_mrope(k, pos3, cfg.rope_theta)
    elif cfg.rope == "rope":
        q, k = apply_rope(q, pos, cfg.rope_theta), apply_rope(k, pos, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                           (0, index, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                           (0, index, 0, 0))
    scores = _gqa_scores(q, cache_k)                     # [B,H,1,S]
    valid = (jnp.arange(S) <= index)[None, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o = _gqa_out(w, cache_v)
    out = jnp.einsum("bshk,hkd->bsd", o.astype(x.dtype), params["wo"])
    return out, cache_k, cache_v


def cross_attention_decode(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                           enc_k: jnp.ndarray, enc_v: jnp.ndarray) -> jnp.ndarray:
    """Decode-side cross attention over precomputed encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    scores = _gqa_scores(q, enc_k)
    w = jax.nn.softmax(scores, axis=-1)
    o = _gqa_out(w, enc_v)
    return jnp.einsum("bshk,hkd->bsd", o.astype(x.dtype), params["wo"])


# --------------------------------------------------------------------------- #
# MLP (SwiGLU) and MoE
# --------------------------------------------------------------------------- #

def mlp_init(key, cfg: ModelConfig) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    dt = dtype_of(cfg)
    k = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(k[0], (d, f), dt),
        "w_up": _dense_init(k[1], (d, f), dt),
        "w_down": _dense_init(k[2], (f, d), dt),
    }


def mlp(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, params["w_gate"]).astype(jnp.float32))
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    return jnp.einsum("bsf,fd->bsd", (g.astype(x.dtype) * u), params["w_down"])


def moe_init(key, cfg: ModelConfig) -> Params:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe_experts
    dt = dtype_of(cfg)
    k = jax.random.split(key, 4)
    return {
        "router": _dense_init(k[0], (d, E), jnp.float32),
        "w_gate": _dense_init(k[1], (E, d, f), dt),
        "w_up": _dense_init(k[2], (E, d, f), dt),
        "w_down": _dense_init(k[3], (E, f, d), dt),
    }


def _moe_local(x: jnp.ndarray, router: jnp.ndarray, w_gate: jnp.ndarray,
               w_up: jnp.ndarray, w_down: jnp.ndarray, *,
               cfg: ModelConfig, n_shards: int, shard_index: jnp.ndarray,
               fparts: int = 1):
    """Per-shard MoE body (runs under shard_map over the `model` axis).

    x: [B_loc, S, d] (replicated over the model axis);
    w_*: [E_loc, ...] local expert slices.  Each shard routes all tokens,
    keeps those destined to its local experts (fixed capacity), computes
    them, scatters results back, and the caller psums over the model axis.

    When the mesh axis is larger than the expert count (e.g. grok-1: 8
    experts on a 16-way model axis), each expert is split over ``fparts``
    consecutive shards along d_ff (EPxTP): those shards process the *same*
    dispatched tokens on complementary d_ff slices and the final psum sums
    the partial FFN outputs — the same combine that merges experts.
    """
    E, k_top = cfg.moe_experts, cfg.moe_top_k
    E_loc = w_gate.shape[0]
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = (xt.astype(jnp.float32) @ router)          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k_top)          # [T, k]
    # capacity per *local* expert; never below all-tokens at tiny T (decode
    # batches must not drop tokens)
    cap = int(math.ceil(T * k_top / E * cfg.capacity_factor))
    cap = min(T, max(cap, 8))
    lo = (shard_index // fparts) * E_loc
    y = jnp.zeros((T, d), jnp.float32)
    for slot in range(k_top):
        e_glob = top_e[:, slot]                          # [T]
        gate = top_p[:, slot]                            # [T]
        e_loc = e_glob - lo
        mine = (e_loc >= 0) & (e_loc < E_loc)
        e_loc = jnp.where(mine, e_loc, 0)
        # position of each token within its expert's capacity buffer
        onehot = jax.nn.one_hot(e_loc, E_loc, dtype=jnp.int32) * mine[:, None]
        pos = jnp.cumsum(onehot, axis=0) - 1             # [T, E_loc]
        pos_t = jnp.take_along_axis(pos, e_loc[:, None], axis=1)[:, 0]
        keep = mine & (pos_t < cap)
        slot_idx = jnp.where(keep, e_loc * cap + pos_t, E_loc * cap)  # drop bin
        # dispatch: [E_loc*cap+1, d]
        buf = jnp.zeros((E_loc * cap + 1, d), xt.dtype)
        buf = buf.at[slot_idx].add(jnp.where(keep[:, None], xt, 0))
        h = buf[:-1].reshape(E_loc, cap, d)
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, w_gate).astype(jnp.float32))
        u = jnp.einsum("ecd,edf->ecf", h, w_up)
        o = jnp.einsum("ecf,efd->ecd", g.astype(h.dtype) * u, w_down)
        o = o.reshape(E_loc * cap, d)
        got = jnp.where(keep[:, None], o[jnp.where(keep, slot_idx, 0)], 0)
        y = y + got.astype(jnp.float32) * (gate * keep)[:, None]
    return y.reshape(B, S, d)


def moe(params: Params, cfg: ModelConfig, x: jnp.ndarray,
        mesh: Optional[jax.sharding.Mesh] = None,
        model_axis: str = "model") -> jnp.ndarray:
    """Expert-parallel MoE FFN.

    With a mesh: shard_map over the `model` axis — experts sharded
    (E >= axis) or expert-split over d_ff (E < axis, EPxTP), tokens
    replicated over the axis, psum combine.  Without a mesh (CPU smoke
    tests): single local shard.
    """
    E, f = cfg.moe_experts, cfg.d_ff
    usable = (
        mesh is not None
        and model_axis in mesh.axis_names
        and (E % mesh.shape[model_axis] == 0
             or (mesh.shape[model_axis] % E == 0
                 and f % (mesh.shape[model_axis] // E) == 0))
    )
    if not usable:
        y = _moe_local(
            x, params["router"], params["w_gate"], params["w_up"],
            params["w_down"], cfg=cfg, n_shards=1,
            shard_index=jnp.array(0, jnp.int32),
        )
        return y.astype(x.dtype)

    M = mesh.shape[model_axis]
    fparts = 1 if E % M == 0 else M // E
    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    if fparts > 1:
        fpf = f // fparts
        # slot s = e*fparts + j  <->  expert e, d_ff slice j
        wg = jnp.moveaxis(wg.reshape(E, cfg.d_model, fparts, fpf), 2, 1)
        wg = wg.reshape(E * fparts, cfg.d_model, fpf)
        wu = jnp.moveaxis(wu.reshape(E, cfg.d_model, fparts, fpf), 2, 1)
        wu = wu.reshape(E * fparts, cfg.d_model, fpf)
        wd = wd.reshape(E, fparts, fpf, cfg.d_model).reshape(E * fparts, fpf, cfg.d_model)

    other = tuple(a for a in mesh.axis_names if a != model_axis)
    # batch sharded over the non-model axes, replicated over model
    xspec = P(other if other else None, None, None)

    def body(xl, router, wgl, wul, wdl):
        idx = jax.lax.axis_index(model_axis)
        y = _moe_local(xl, router, wgl, wul, wdl, cfg=cfg,
                       n_shards=M, shard_index=idx, fparts=fparts)
        return jax.lax.psum(y, model_axis).astype(xl.dtype)

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(xspec, P(), P(model_axis, None, None),
                  P(model_axis, None, None), P(model_axis, None, None)),
        out_specs=xspec,
        check_vma=False,
    )(x, params["router"], wg, wu, wd)


# --------------------------------------------------------------------------- #
# embeddings / head
# --------------------------------------------------------------------------- #

def embedding_init(key, cfg: ModelConfig) -> Params:
    dt = dtype_of(cfg)
    k = jax.random.split(key, 2)
    return {
        "embed": _dense_init(k[0], (cfg.vocab, cfg.d_model), dt, scale=0.02),
        "unembed": _dense_init(k[1], (cfg.d_model, cfg.vocab), dt),
    }


def embed(params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["embed"], tokens, axis=0)


def unembed(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("bsd,dv->bsv", x, params["unembed"])
