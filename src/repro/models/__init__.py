"""Model zoo facade: ``build_model(cfg)`` -> family-dispatched functions."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

from . import encdec, lm
from .config import ARCHS, SHAPES, ModelConfig, ShapeSpec, get_config, get_shape

__all__ = [
    "ARCHS", "SHAPES", "ModelConfig", "ShapeSpec", "get_config", "get_shape",
    "Model", "build_model",
]


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    forward: Callable
    loss_fn: Callable
    init_cache: Callable
    decode_step: Callable
    precompute_cross: Optional[Callable] = None


def build_model(cfg: ModelConfig) -> Model:
    if cfg.is_encdec:
        return Model(
            cfg=cfg,
            init=lambda rng: encdec.init(rng, cfg),
            forward=lambda p, batch, mesh=None: encdec.forward(p, cfg, batch, mesh),
            loss_fn=lambda p, batch, mesh=None: encdec.loss_fn(p, cfg, batch, mesh),
            init_cache=lambda batch, seq, **kw: encdec.init_cache(cfg, batch, seq, **kw),
            decode_step=lambda p, cache, batch, mesh=None: encdec.decode_step(p, cfg, cache, batch, mesh),
            precompute_cross=lambda p, enc, cache: encdec.precompute_cross(p, cfg, enc, cache),
        )
    return Model(
        cfg=cfg,
        init=lambda rng: lm.init(rng, cfg),
        forward=lambda p, batch, mesh=None: lm.forward(p, cfg, batch, mesh),
        loss_fn=lambda p, batch, mesh=None: lm.loss_fn(p, cfg, batch, mesh),
        init_cache=lambda batch, seq: lm.init_cache(cfg, batch, seq),
        decode_step=lambda p, cache, batch, mesh=None: lm.decode_step(p, cfg, cache, batch, mesh),
    )
