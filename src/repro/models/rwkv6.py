"""RWKV-6 ("Finch", arXiv:2404.05892) block: attention-free time mixing with
data-dependent decay, plus the squared-ReLU channel mix.

Pure-JAX reference implementation; the recurrence runs as ``lax.scan`` over
time (vectorized over batch/heads).  The Pallas chunked-scan kernel in
``repro.kernels.rwkv6_scan`` accelerates the same math on TPU.

State per layer (decode): (x_prev_att [B,d], x_prev_ffn [B,d],
wkv_state [B,H,hd,hd]).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _dense_init, dtype_of, rmsnorm, rmsnorm_init

Params = Dict[str, Any]

LORA_RANK = 32


def rwkv6_block_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    f = cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 16)
    r = LORA_RANK
    return {
        "ln_att": rmsnorm_init(cfg),
        "ln_ffn": rmsnorm_init(cfg),
        # data-dependent token-shift mix (5 targets: w,k,v,r,g)
        "mu_x": jnp.zeros((5, d), jnp.float32),
        "lora_A": _dense_init(ks[0], (d, 5 * r), dt),
        "lora_B": _dense_init(ks[1], (5, r, d), dt),
        # projections.  wv/wg/wo carry explicit [H, hd] structure so the
        # value-channel dim can be sharded over `model` (hd divides the
        # axis even when H does not — see distrib.sharding rwkv rules)
        "wr": _dense_init(ks[2], (d, d), dt),
        "wk": _dense_init(ks[3], (d, d), dt),
        "wv": _dense_init(ks[4], (d, H, hd), dt),
        "wg": _dense_init(ks[5], (d, H, hd), dt),
        "wo": _dense_init(ks[6], (H, hd, d), dt),
        # decay: w = exp(-exp(w0 + tanh(xw A_w) B_w))
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "wA": _dense_init(ks[7], (d, r), dt),
        "wB": _dense_init(ks[8], (r, d), dt),
        # per-head bonus u
        "u": jnp.zeros((H, hd), jnp.float32),
        "ln_head": rmsnorm_init(cfg, hd),
        # channel mix
        "ck": _dense_init(ks[9], (d, f), dt),
        "cv": _dense_init(ks[10], (f, d), dt),
        "cr": _dense_init(ks[11], (d, d), dt),
        "mu_ck": jnp.zeros((d,), jnp.float32),
        "mu_cr": jnp.zeros((d,), jnp.float32),
    }


def _shift(x: jnp.ndarray, x_prev: jnp.ndarray) -> jnp.ndarray:
    """Token shift: previous token's activation (x_prev for position 0)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _time_mix_inputs(p: Params, x: jnp.ndarray, shifted: jnp.ndarray):
    """Data-dependent lerp producing the 5 mixed inputs (w,k,v,r,g)."""
    xx = (shifted - x).astype(jnp.float32)
    base = x.astype(jnp.float32) + xx * p["mu_x"][:, None, None, :]  # [5,B,S,d]
    # one shared lora trunk, 5 heads
    trunk = jnp.tanh(jnp.einsum("bsd,dk->bsk", x, p["lora_A"]))
    trunk = trunk.reshape(x.shape[0], x.shape[1], 5, LORA_RANK)
    adj = jnp.einsum("bskr,krd->kbsd", trunk, p["lora_B"])  # [5,B,S,d]
    mixed = base + adj.astype(jnp.float32)
    return mixed.astype(x.dtype)  # [5, B, S, d] -> w,k,v,r,g


def _wkv_scan(r, k, v, w, u, state):
    """The WKV recurrence over time.

    r,k,v: [B,S,H,hd] (any float dtype; upcast per step so the TP gathers
    feeding the scan move bf16, not f32 — SS:Perf); w: [B,S,H,hd] decay in
    (0,1) f32; u: [H,hd]; state: [B,H,hd,hd] f32 (key-major).
    Returns (y [B,S,H,hd] f32, new state).
    """
    def step(s, inp):
        rt, kt, vt, wt = inp  # [B,H,hd] each
        rt = rt.astype(jnp.float32)
        kt = kt.astype(jnp.float32)
        vt = vt.astype(jnp.float32)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)          # outer product
        yt = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, yt

    rs, ks, vs, ws = (jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, (rs, ks, vs, ws))
    return jnp.moveaxis(ys, 0, 1), state


def _pin(t: jnp.ndarray, mesh, vdim: Optional[int] = None) -> jnp.ndarray:
    """Anchor a tensor batch-sharded on the data axes and (optionally)
    value-channel-sharded over `model` on dim ``vdim``.

    The WKV recurrence is independent per value channel, so v/g/y and the
    scan state shard on hd even when the head count does not divide the
    axis; anchoring the *carry* too is essential — a replicated zero-init
    carry otherwise flips the entire scan to batch-replicated execution."""
    if mesh is None or "model" not in mesh.axis_names:
        return t
    from jax.sharding import NamedSharding, PartitionSpec as P

    baxes = tuple(a for a in mesh.axis_names if a != "model")
    dims: list = [None] * t.ndim
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]
    if baxes and t.shape[0] % bsize == 0:
        dims[0] = baxes if len(baxes) > 1 else baxes[0]
    if vdim is not None and t.shape[vdim] % mesh.shape["model"] == 0:
        dims[vdim] = "model"
    return jax.lax.with_sharding_constraint(
        t, NamedSharding(mesh, P(*dims)))


def rwkv6_time_mix(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                   x_prev: jnp.ndarray, state: jnp.ndarray, mesh=None):
    """x: [B,S,d] -> (out [B,S,d], new_x_prev [B,d], new_state).

    Distribution (SS:Perf): column-parallel projections; all cross-`model`
    gathers move bf16 tensors (the f32 upcasts happen inside the scan step
    and after gating products), and the rank-32 decay lora is computed
    replicated so the decay tensor needs no collective at all."""
    B, S, d = x.shape
    hd = cfg.rwkv_head_dim
    H = d // hd
    shifted = _shift(x, x_prev)
    xw, xk, xv, xr, xg = _time_mix_inputs(p, x, shifted)
    r = jnp.einsum("bsd,de->bse", xr, p["wr"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"]).reshape(B, S, H, hd)
    v = jnp.einsum("bsd,dhe->bshe", xv, p["wv"])
    g = jnp.einsum("bsd,dhe->bshe", xg, p["wg"])
    g = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    # data-dependent decay: rank-32 lora, replicated compute, no collective
    dw = jnp.einsum("bsr,rd->bsd", jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, p["wA"])), p["wB"])
    w = jnp.exp(-jnp.exp(p["w0"] + dw.astype(jnp.float32)))  # (0,1)
    w = w.reshape(B, S, H, hd)
    y, state = _wkv_scan(r, k, v, w, p["u"], state)
    y = rmsnorm(p["ln_head"], y, cfg.norm_eps).astype(x.dtype)
    y = y * g
    out = jnp.einsum("bshe,hed->bsd", y, p["wo"])
    return out, x[:, -1, :], state


def rwkv6_channel_mix(p: Params, x: jnp.ndarray, x_prev: jnp.ndarray,
                      mesh=None):
    shifted = _shift(x, x_prev)
    xx = (shifted - x).astype(jnp.float32)
    xk = (x.astype(jnp.float32) + xx * p["mu_ck"]).astype(x.dtype)
    xr = (x.astype(jnp.float32) + xx * p["mu_cr"]).astype(x.dtype)
    k = jnp.einsum("bsd,df->bsf", xk, p["ck"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    v = jnp.einsum("bsf,fd->bsd", k, p["cv"])
    rg = jnp.einsum("bsd,de->bse", xr, p["cr"])
    r = jax.nn.sigmoid(rg.astype(jnp.float32)).astype(x.dtype)
    return r * v, x[:, -1, :]


def rwkv6_block(p: Params, cfg: ModelConfig, x: jnp.ndarray, state: Tuple,
                mesh=None):
    """Full block. state: (x_prev_att, x_prev_ffn, wkv_state)."""
    xp_att, xp_ffn, wkv = state
    h = rmsnorm(p["ln_att"], x, cfg.norm_eps)
    att, xp_att, wkv = rwkv6_time_mix(p, cfg, h, xp_att, wkv, mesh=mesh)
    x = x + att
    h = rmsnorm(p["ln_ffn"], x, cfg.norm_eps)
    ffn, xp_ffn = rwkv6_channel_mix(p, h, xp_ffn, mesh=mesh)
    x = x + ffn
    return x, (xp_att, xp_ffn, wkv)


def rwkv6_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    return (
        jnp.zeros((batch, d), dtype),
        jnp.zeros((batch, d), dtype),
        jnp.zeros((batch, H, hd, hd), jnp.float32),
    )
