"""Model configuration for the assigned architectures.

One :class:`ModelConfig` per architecture; exact dimensions from the public
sources cited in the assignment.  ``reduced()`` produces the CPU-smoke-test
configuration of the same family (same block wiring, tiny dims).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "ARCHS", "get_config", "get_shape"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | vlm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None       # default d_model // n_heads
    # -- MoE ------------------------------------------------------------------
    moe_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    # -- attention flavor -------------------------------------------------------
    qkv_bias: bool = False
    rope: str = "rope"                   # rope | mrope | none
    rope_theta: float = 10_000.0
    # -- SSM / linear-attention ---------------------------------------------------
    ssm_state: int = 0                   # mamba2 state size (hybrid)
    ssm_head_dim: int = 64
    rwkv_head_dim: int = 64
    expand: int = 2                      # mamba2 inner expansion
    # -- hybrid (zamba2): one shared attention block applied every k layers ------
    shared_attn_every: int = 0
    # -- encoder-decoder (whisper) -----------------------------------------------
    encoder_layers: int = 0
    # -- numerics -----------------------------------------------------------------
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # -- lowering knobs (dry-run probes unroll the layer scan so XLA's
    #    trip-count-blind cost analysis sees every layer) ------------------------
    scan_unroll: bool = False
    # -- bookkeeping ----------------------------------------------------------------
    source: str = ""
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this architecture hold 500k context state without a quadratic
        full-attention prefill / full-layer KV cache?"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        emb = v * d * 2  # embed + unembed (untied)
        if self.family == "ssm":
            # rwkv6: r,k,v,g,o projections + decay/lora + ffn(k,r,v)
            att = 5 * d * d + 3 * d * self.rwkv_head_dim
            ffn = 2 * d * f + d * d
            return emb + L * (att + ffn)
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        attn = d * H * hd + 2 * d * KV * hd + H * hd * d
        if self.family == "hybrid":
            d_in = self.expand * d
            nh = d_in // self.ssm_head_dim
            mamba = d * (2 * d_in + 2 * nh * self.ssm_state + nh) + d_in * d
            per_layer = mamba + 2 * (d * f) + f * d  # swiglu sized f
            shared = attn * (L // max(self.shared_attn_every, 1) and 1)
            return emb + L * per_layer + attn  # one shared attention block
        if self.moe_experts:
            ffn = self.moe_experts * 3 * d * f + d * self.moe_experts
        else:
            ffn = 3 * d * f
        total_layers = L + self.encoder_layers
        cross = attn if self.is_encdec else 0
        return emb + total_layers * (attn + ffn + cross)

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top-k experts only)."""
        if not self.moe_experts:
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        dense = self.param_count() - L * self.moe_experts * 3 * d * f
        return dense + L * self.moe_top_k * 3 * d * f

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        return replace(
            self,
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab=256,
            moe_experts=4 if self.moe_experts else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.moe_experts else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16,
            rwkv_head_dim=16,
            shared_attn_every=2 if self.shared_attn_every else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            dtype="float32",
        )


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


ARCHS: Dict[str, ModelConfig] = {}


def _register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


_register(ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202_048, moe_experts=128, moe_top_k=1,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (unverified)",
    notes="MoE 128e top-1; early-fusion frontend out of scope (text backbone)",
))
_register(ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab=131_072, moe_experts=8, moe_top_k=2,
    source="hf:xai-org/grok-1 (unverified)",
))
_register(ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,  # 2560/64 wkv heads
    d_ff=8960, vocab=65_536, rope="none", rwkv_head_dim=64,
    source="arXiv:2404.05892; hf",
    notes="Finch: attention-free, data-dependent decay",
))
_register(ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab=152_064, qkv_bias=True, rope="mrope",
    source="arXiv:2409.12191; hf",
    notes="M-RoPE backbone; vision frontend stubbed (patch embeddings input)",
))
_register(ModelConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=160,
    d_ff=13824, vocab=100_352,
    source="hf:stabilityai/stablelm-2-1_6b family",
))
_register(ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, head_dim=64,
    d_ff=2560, vocab=49_152,
    source="hf:HuggingFaceTB/SmolLM-135M family",
))
_register(ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=13824, vocab=152_064, qkv_bias=True,
    source="hf:Qwen/Qwen2.5 family",
))
_register(ModelConfig(
    name="qwen2-1.5b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab=151_936, qkv_bias=True,
    source="arXiv:2407.10671; hf",
))
_register(ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab=51_866, rope="none", encoder_layers=32,
    source="arXiv:2212.04356 (unverified)",
    notes="enc-dec; conv frontend stubbed (precomputed frame embeddings)",
))
_register(ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32_000, ssm_state=64, ssm_head_dim=64,
    shared_attn_every=6,
    source="arXiv:2411.15242 (unverified)",
    notes="Mamba2 backbone + one shared attention block applied every 6 layers",
))


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; have {sorted(ARCHS)}") from None


def get_shape(name: str) -> ShapeSpec:
    try:
        return SHAPES[name]
    except KeyError:
        raise ValueError(f"unknown shape {name!r}; have {sorted(SHAPES)}") from None
