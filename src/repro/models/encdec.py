"""Whisper-style encoder-decoder (audio family).

The conv frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings [B, S_enc, d].  The transformer backbone is
faithful: pre-LN blocks, full (non-causal) encoder self-attention, decoder
with causal self-attention + cross-attention, GELU MLPs.  Positions are
sinusoidal on both sides so parameter shapes stay context-length-agnostic
(the real model uses learned decoder positions up to 448; noted in
DESIGN.md).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    Params,
    _dense_init,
    attention,
    attention_decode,
    attention_init,
    cross_attention_decode,
    dtype_of,
    embed,
    embedding_init,
    layernorm,
    layernorm_init,
    unembed,
)


def _sinusoid(S: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _gelu_mlp_init(key, cfg: ModelConfig) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    dt = dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    return {
        "w1": _dense_init(k1, (d, f), dt), "b1": jnp.zeros((f,), dt),
        "w2": _dense_init(k2, (f, d), dt), "b2": jnp.zeros((d,), dt),
    }


def _gelu_mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jnp.einsum("bsd,df->bsf", x, p["w1"]) + p["b1"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, p["w2"]) + p["b2"]


def _enc_layer_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln_attn": layernorm_init(cfg),
        "attn": attention_init(k1, cfg),
        "ln_mlp": layernorm_init(cfg),
        "mlp": _gelu_mlp_init(k2, cfg),
    }


def _dec_layer_init(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln_self": layernorm_init(cfg),
        "self_attn": attention_init(k1, cfg),
        "ln_cross": layernorm_init(cfg),
        "cross_attn": attention_init(k2, cfg),
        "ln_mlp": layernorm_init(cfg),
        "mlp": _gelu_mlp_init(k3, cfg),
    }


def init(rng, cfg: ModelConfig) -> Params:
    ke, kd, kemb = jax.random.split(rng, 3)
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embedding": embedding_init(kemb, cfg),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
        "ln_enc": layernorm_init(cfg),
        "ln_dec": layernorm_init(cfg),
    }


def encode(params: Params, cfg: ModelConfig, frames: jnp.ndarray, mesh=None) -> jnp.ndarray:
    """frames: [B, S_enc, d] (post-frontend stub) -> encoder states."""
    B, S, d = frames.shape
    x = frames.astype(dtype_of(cfg)) + _sinusoid(S, d).astype(dtype_of(cfg))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(carry, lp):
        h = layernorm(lp["ln_attn"], carry, cfg.norm_eps)
        carry = carry + attention(lp["attn"], cfg, h, pos, causal=False, mesh=mesh)
        h = layernorm(lp["ln_mlp"], carry, cfg.norm_eps)
        return carry + _gelu_mlp(lp["mlp"], h), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_layers"],
                        unroll=cfg.scan_unroll)
    return layernorm(params["ln_enc"], x, cfg.norm_eps)


def decode_train(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                 enc: jnp.ndarray, mesh=None) -> jnp.ndarray:
    B, S = tokens.shape
    d = cfg.d_model
    x = embed(params["embedding"], tokens)
    x = x + _sinusoid(S, d).astype(x.dtype)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(carry, lp):
        h = layernorm(lp["ln_self"], carry, cfg.norm_eps)
        carry = carry + attention(lp["self_attn"], cfg, h, pos, mesh=mesh)
        h = layernorm(lp["ln_cross"], carry, cfg.norm_eps)
        carry = carry + attention(lp["cross_attn"], cfg, h, pos,
                                  causal=False, x_kv=enc, mesh=mesh)
        h = layernorm(lp["ln_mlp"], carry, cfg.norm_eps)
        return carry + _gelu_mlp(lp["mlp"], h), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec_layers"],
                        unroll=cfg.scan_unroll)
    return layernorm(params["ln_dec"], x, cfg.norm_eps)


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            mesh=None) -> jnp.ndarray:
    enc = encode(params, cfg, batch["frames"], mesh)
    x = decode_train(params, cfg, batch["tokens"], enc, mesh)
    return unembed(params["embedding"], x)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            mesh=None):
    logits = forward(params, cfg, batch, mesh).astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(logz - gold)
    return loss, {"loss": loss}


# --------------------------------------------------------------------------- #
# serving
# --------------------------------------------------------------------------- #

def init_cache(cfg: ModelConfig, batch: int, seq: int,
               enc_len: int = 1500) -> Dict[str, Any]:
    """Decoder KV cache (+ space for precomputed cross K/V)."""
    dt = dtype_of(cfg)
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((L, batch, seq, KV, hd), dt),
        "v": jnp.zeros((L, batch, seq, KV, hd), dt),
        "cross_k": jnp.zeros((L, batch, enc_len, KV, hd), dt),
        "cross_v": jnp.zeros((L, batch, enc_len, KV, hd), dt),
        "index": jnp.zeros((), jnp.int32),
    }


def precompute_cross(params: Params, cfg: ModelConfig, enc: jnp.ndarray,
                     cache: Dict[str, Any]) -> Dict[str, Any]:
    """Fill the cross-attention K/V from encoder states (once per request)."""
    def body(_, lp):
        ca = lp["cross_attn"]
        k = jnp.einsum("bsd,dhk->bshk", enc, ca["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc, ca["wv"])
        if "bk" in ca:
            k, v = k + ca["bk"], v + ca["bv"]
        return None, (k, v)

    _, (ck, cv) = jax.lax.scan(body, None, params["dec_layers"])
    return dict(cache, cross_k=ck.astype(cache["cross_k"].dtype),
                cross_v=cv.astype(cache["cross_v"].dtype))


def decode_step(params: Params, cfg: ModelConfig, cache: Dict[str, Any],
                batch: Dict[str, jnp.ndarray], mesh=None):
    x = embed(params["embedding"], batch["token"])
    index = cache["index"]
    d = cfg.d_model
    # sinusoidal position of the current step
    posvec = _sinusoid(1, d)[0]
    ang_scale = jnp.ones(())  # static shape; recompute per index:
    pos_t = jnp.where(jnp.arange(d // 2) >= 0, index.astype(jnp.float32), 0.0)
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos_t / jnp.power(10_000.0, 2 * dim / d)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])
    x = x + pe.astype(x.dtype)

    def body(carry, inp):
        h = carry
        lp, k_l, v_l, ck, cv = inp
        a = layernorm(lp["ln_self"], h, cfg.norm_eps)
        o, k_l, v_l = attention_decode(lp["self_attn"], cfg, a, k_l, v_l, index)
        h = h + o
        a = layernorm(lp["ln_cross"], h, cfg.norm_eps)
        h = h + cross_attention_decode(lp["cross_attn"], cfg, a, ck, cv)
        a = layernorm(lp["ln_mlp"], h, cfg.norm_eps)
        return h + _gelu_mlp(lp["mlp"], a), (k_l, v_l)

    x, (k, v) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    new_cache = dict(cache, k=k, v=v, index=index + 1)
    x = layernorm(params["ln_dec"], x, cfg.norm_eps)
    return unembed(params["embedding"], x), new_cache
