"""Distribution: mesh axes, sharding rules, compressed collectives."""

from .sharding import (
    batch_axes,
    batch_spec,
    cache_specs,
    named_sharding,
    param_specs,
    spec_for_leaf,
)
from .compress import compressed_psum, quantize_q8, dequantize_q8

__all__ = [
    "batch_axes", "batch_spec", "cache_specs", "named_sharding",
    "param_specs", "spec_for_leaf",
    "compressed_psum", "quantize_q8", "dequantize_q8",
]
