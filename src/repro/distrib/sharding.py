"""Sharding rule engine: map every param / cache / batch leaf to a
PartitionSpec on the production mesh.

Rules are *preference lists*: the first candidate whose named axes all
divide the corresponding tensor dims wins; otherwise fall through, ending at
full replication.  That makes every architecture lowerable on a fixed mesh
(40 heads on a 16-way model axis falls back from head-sharding to
d_model-sharding, 8 grok experts fall to the EPxTP path, etc.) — the same
policy a production framework needs when one mesh must serve many models.

Axis conventions
----------------
``pod``    slowest axis, crosses DCN (multi-pod only)
``data``   batch / ZeRO axis
``model``  tensor / expert / sequence-parallel axis
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..models.config import ModelConfig

__all__ = [
    "batch_axes", "batch_spec", "param_specs", "cache_specs",
    "spec_for_leaf", "named_sharding",
]


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that shard the global batch (everything but `model`)."""
    return tuple(a for a in mesh.axis_names if a != "model")


# -- rule tables ---------------------------------------------------------------
# leaf-name -> list of candidate specs (shapes WITHOUT the stacked layer dim;
# a leading None is prepended automatically for stacked per-layer leaves).

_PARAM_RULES: Dict[str, Sequence[P]] = {
    # embeddings
    "embed": [P("model", None), P(None, "model")],
    "unembed": [P(None, "model"), P("model", None)],
    # attention projections [d, H, hd] / [H, hd, d]
    "wq": [P(None, "model", None), P("model", None, None)],
    "wk": [P(None, "model", None), P("model", None, None)],
    "wv": [P(None, "model", None), P("model", None, None)],
    "wo": [P("model", None, None), P(None, None, "model")],
    # dense MLP [d, f] / [f, d]
    "w_gate": [P(None, "model")],
    "w_up": [P(None, "model")],
    "w_down": [P("model", None)],
    # MoE (parent-qualified below): [E, d, f] / [E, f, d]
    "moe/w_gate": [P("model", None, None), P(None, None, "model")],
    "moe/w_up": [P("model", None, None), P(None, None, "model")],
    "moe/w_down": [P("model", None, None), P(None, "model", None)],
    "moe/router": [P(None, None)],
    # rwkv6: column-parallel projections (bf16-pinned gathers), value-
    # channel-sharded gate, row-parallel output
    "ck": [P(None, "model")],
    "cv": [P("model", None)],
    "cr": [P(None, "model")],
    "ssm/wr": [P(None, "model")],
    "ssm/wk": [P(None, "model")],
    "ssm/wv": [P(None, None, "model")],   # [d, H, hd]: shard value channels
    "ssm/wg": [P(None, None, "model")],
    "ssm/wo": [P(None, "model", None)],   # [H, hd, d]: contract sharded hd
    # mamba2
    "w_in": [P(None, "model")],
    "conv_w": [P(None, "model")],
    "w_out": [P("model", None)],
    # whisper gelu mlp
    "w1": [P(None, "model")],
    "w2": [P("model", None)],
}


def _fits(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> bool:
    if len(spec) > len(shape):
        return False
    for dim, names in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if names is None:
            continue
        names = names if isinstance(names, tuple) else (names,)
        size = int(np.prod([mesh.shape[n] for n in names]))
        if dim % size != 0:
            return False
    return True


def spec_for_leaf(path: Tuple[str, ...], shape: Tuple[int, ...],
                  mesh: Mesh, cfg: Optional[ModelConfig] = None) -> P:
    """PartitionSpec for one param leaf (path of dict keys)."""
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    stacked = any(s in ("layers", "enc_layers", "dec_layers") for s in path)
    is_ssm = cfg is not None and cfg.family == "ssm"
    keys = []
    if parent == "moe":
        keys.append(f"moe/{name}")
    if is_ssm:
        keys.append(f"ssm/{name}")
    keys.append(name)
    for key in keys:
        for cand in _PARAM_RULES.get(key, ()):  # preference order
            spec = P(*((None,) + tuple(cand))) if stacked else cand
            if _fits(spec, shape, mesh):
                return spec
    return P()  # replicate


def param_specs(params: Any, mesh: Mesh,
                cfg: Optional[ModelConfig] = None) -> Any:
    """Pytree of PartitionSpecs matching ``params`` (works on shape structs)."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    specs = []
    for keypath, leaf in flat:
        path = tuple(
            k.key if hasattr(k, "key") else str(k) for k in keypath
        )
        specs.append(spec_for_leaf(path, tuple(leaf.shape), mesh, cfg))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_spec(mesh: Mesh, ndim: int, batch_dim: int = 0,
               batch_size: Optional[int] = None) -> P:
    """Shard the batch dim over (pod, data); replicate the rest.  With a
    known ``batch_size``, fall back to fewer axes (then replication) when
    the batch does not divide — the batch=1 long-context cells."""
    axes = batch_axes(mesh)
    dims: list = [None] * ndim
    candidates = [axes] + [axes[i:] for i in range(1, len(axes))] + [()]
    for cand in candidates:
        size = int(np.prod([mesh.shape[a] for a in cand])) if cand else 1
        if batch_size is None or batch_size % size == 0:
            dims[batch_dim] = (cand if len(cand) > 1 else
                               (cand[0] if cand else None))
            return P(*dims)
    return P(*([None] * ndim))


def _first_fitting(shape, mesh, candidates):
    for c in candidates:
        if _fits(c, shape, mesh):
            return c
    return P()


def cache_specs(cache: Any, mesh: Mesh, cfg: ModelConfig) -> Any:
    """Decode cache sharding: batch over (pod,data) when divisible; KV cache
    sequence over `model` as fallback (sequence-parallel KV for batch=1
    long-context decode); states sharded on their widest divisible dim."""
    axes = batch_axes(mesh)
    baxes = axes if len(axes) > 1 else axes[0]

    def leaf_spec(keypath, leaf) -> P:
        name = keypath[-1].key if hasattr(keypath[-1], "key") else str(keypath[-1])
        shape = tuple(leaf.shape)
        if name == "index":
            return P()
        if name in ("k", "v", "shared_k", "shared_v", "cross_k", "cross_v"):
            # [L/apps, B, S, KV, hd]
            return _first_fitting(shape, mesh, [
                P(None, baxes, "model", None, None),   # batch + seq(SP)
                P(None, baxes, None, "model", None),   # batch + kv heads
                P(None, baxes, None, None, "model"),   # batch + head dim
                P(None, None, "model", None, None),    # seq only (B=1)
                P(None, None, None, None, "model"),
            ])
        if name == "wkv":       # [L, B, H, hd, hd]
            return _first_fitting(shape, mesh, [
                P(None, baxes, "model", None, None),
                P(None, baxes, None, "model", None),
                P(None, None, "model", None, None),
                P(None, None, None, "model", None),
            ])
        if name == "ssm":       # [L, B, H, hd, N]
            return _first_fitting(shape, mesh, [
                P(None, baxes, "model", None, None),
                P(None, None, "model", None, None),
                P(None, None, None, "model", None),
            ])
        if name == "conv":      # [L, B, K-1, C]
            return _first_fitting(shape, mesh, [
                P(None, baxes, None, "model"),
                P(None, None, None, "model"),
            ])
        if name in ("xp_att", "xp_ffn"):  # [L, B, d]
            return _first_fitting(shape, mesh, [
                P(None, baxes, "model"),
                P(None, None, "model"),
            ])
        return P()

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def named_sharding(mesh: Mesh, tree_of_specs: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_of_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
