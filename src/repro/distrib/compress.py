"""Compressed collectives — the paper's compression study (section 7.4)
transplanted to the gradient plane.

PipeGen found dictionary compression wins once links are slow (40 ms WAN)
and loses when colocated.  The same trade governs cross-pod gradient
all-reduce over DCN: ``compressed_psum`` quantizes block-wise to uint8
before the sum and dequantizes after, cutting DCN bytes 4x (f32) at the
cost of quantization error; error feedback (the residual is returned so the
optimizer can re-inject it next step) keeps training unbiased in practice.

Used by ``train.train_step`` when ``grad_compression="q8"`` — applied ONLY
to the `pod` axis (cross-DCN), never intra-pod ICI, mirroring the paper's
"compress when distant, not when colocated" conclusion.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_q8", "dequantize_q8", "compressed_psum", "psum_with_compression"]

_BLOCK = 256


def quantize_q8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise symmetric uint8 quantization. Returns (q [int8], scale)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_q8(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compressed_psum(x: jnp.ndarray, axis_name: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """psum with uint8 on-the-wire representation (inside shard_map).

    Returns (summed value, local quantization residual for error feedback).
    """
    q, scale = quantize_q8(x)
    deq_local = dequantize_q8(q, scale, x.shape, jnp.float32)
    residual = x.astype(jnp.float32) - deq_local
    # the int8 payload crosses the wire; sum in f32 after dequant
    summed = jax.lax.psum(deq_local, axis_name)
    return summed.astype(x.dtype), residual.astype(x.dtype)


def psum_with_compression(grads: Any, mesh, *, pod_axis: str = "pod",
                          data_axes: Tuple[str, ...] = ("data",),
                          compress: bool = True) -> Any:
    """Gradient reduction for use inside shard_map: full-precision psum over
    intra-pod `data`, optionally-compressed psum over the cross-DCN `pod`
    axis.  Returns (reduced grads, residuals or None)."""

    def reduce_leaf(g):
        g = jax.lax.psum(g, data_axes)
        if pod_axis in mesh.axis_names:
            if compress:
                g, r = compressed_psum(g, pod_axis)
                return g, r
            g = jax.lax.psum(g, pod_axis)
        return g, jnp.zeros((), g.dtype)

    out = jax.tree_util.tree_map(reduce_leaf, grads)
    grads_out = jax.tree_util.tree_map(lambda t: t[0], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
    residuals = jax.tree_util.tree_map(lambda t: t[1], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
    return grads_out, residuals
