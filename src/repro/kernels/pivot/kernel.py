"""Pallas TPU kernel: row-major wire block -> column-major device tensors.

This is FormOpt's section 5.4 pivot executed on device: a pipe lands a
row-major [N, W] block of fixed-width words in HBM; the consumer (input
pipeline -> trainer) wants column-major tensors.  On TPU the transform is
HBM -> VMEM tiled copies with a transpose in VREGs.

Tiling: grid over (row tiles, column-group tiles).  Each program instance
reads a [TILE_N, TILE_W] row-major tile into VMEM and writes the transposed
[TILE_W, TILE_N] tile of the column-major output.  TILE_N x TILE_W x 4B
must fit VMEM with double buffering: 256 x 256 x 4 x 2buf = 512 KiB.
Both tile dims are multiples of the 8x128 VREG lane layout, so the
transpose lowers to full-lane shuffles rather than gathers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pivot_tiled", "TILE_N", "TILE_W"]

TILE_N = 256
TILE_W = 256


def _pivot_kernel(rows_ref, out_ref):
    """rows_ref: [TILE_N, TILE_W] VMEM tile; out_ref: [TILE_W, TILE_N]."""
    out_ref[...] = rows_ref[...].T


@functools.partial(jax.jit, static_argnames=("interpret",))
def pivot_tiled(rows: jnp.ndarray, interpret: bool = False) -> jnp.ndarray:
    """Transpose [N, W] -> [W, N] via VMEM tiles (N, W padded to tiles)."""
    N, W = rows.shape
    pad_n = (-N) % TILE_N
    pad_w = (-W) % TILE_W
    padded = jnp.pad(rows, ((0, pad_n), (0, pad_w)))
    Np, Wp = padded.shape
    grid = (Np // TILE_N, Wp // TILE_W)
    out = pl.pallas_call(
        _pivot_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_N, TILE_W), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((TILE_W, TILE_N), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((Wp, Np), rows.dtype),
        interpret=interpret,
    )(padded)
    return out[:W, :N]
