"""Pure-jnp oracle for the row->column pivot (paper section 5.4 on device).

The wire delivers a row-major block of fixed-width records
``rows [N, W]`` (W = packed row width in 4-byte words); the device wants
column-major tensors.  The pivot is a strided transpose; the oracle is just
``jnp.transpose`` plus the per-column slice, but specified explicitly so
the Pallas kernel has a bit-exact reference.
"""

from __future__ import annotations

from typing import List, Sequence

import jax.numpy as jnp

__all__ = ["pivot_ref"]


def pivot_ref(rows: jnp.ndarray, widths: Sequence[int]) -> List[jnp.ndarray]:
    """rows: [N, W] int32 words; widths: words per column (sum == W).
    Returns per-column arrays [N, w_i] (column-major layout)."""
    out = []
    off = 0
    for w in widths:
        out.append(rows[:, off: off + w])
        off += w
    return [jnp.asarray(c) for c in out]


def unpivot_ref(cols: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Inverse: concatenate column blocks back to row-major [N, W]."""
    return jnp.concatenate(list(cols), axis=1)
