"""jit'd public wrapper around the pivot kernel."""

from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from .kernel import pivot_tiled
from .ref import pivot_ref

__all__ = ["pivot", "pivot_columns"]


def pivot(rows: jnp.ndarray, *, use_pallas: bool = True,
          interpret: bool = True) -> jnp.ndarray:
    """[N, W] row-major words -> [W, N] column-major words."""
    if use_pallas:
        return pivot_tiled(rows, interpret=interpret)
    return rows.T


def pivot_columns(rows: jnp.ndarray, widths: Sequence[int], *,
                  use_pallas: bool = True,
                  interpret: bool = True) -> List[jnp.ndarray]:
    """[N, W] + per-column word widths -> list of [N, w_i] column tensors
    (each contiguous; i.e. the arrowcol layout on device)."""
    colmajor = pivot(rows, use_pallas=use_pallas, interpret=interpret)
    out = []
    off = 0
    for w in widths:
        out.append(colmajor[off: off + w].T)
        off += w
    return out
