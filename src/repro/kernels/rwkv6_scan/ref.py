"""Pure-jnp oracle for the RWKV-6 WKV recurrence (time-step scan)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["wkv_ref"]


def wkv_ref(r, k, v, w, u, state):
    """r,k,v,w: [B,S,H,hd] (f32; w is the per-step decay in (0,1));
    u: [H,hd] bonus; state: [B,H,hd,hd] key-major.
    Returns (y [B,S,H,hd], final state) — identical math to
    ``repro.models.rwkv6._wkv_scan``."""
    def step(s, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        yt = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, yt

    rs, ks, vs, ws = (jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, (rs, ks, vs, ws))
    return jnp.moveaxis(ys, 0, 1), state
