"""jit'd public wrapper for the WKV recurrence."""

from __future__ import annotations

import jax.numpy as jnp

from .kernel import wkv_chunked
from .ref import wkv_ref

__all__ = ["wkv"]


def wkv(r, k, v, w, u, state, *, use_pallas: bool = True,
        interpret: bool = True, chunk: int = 64):
    if use_pallas:
        return wkv_chunked(r, k, v, w, u, state, chunk=chunk,
                           interpret=interpret)
    return wkv_ref(r, k, v, w, u, state)
