"""Pallas TPU kernel: RWKV-6 WKV recurrence, chunk-tiled.

TPU adaptation: the recurrence is sequential in t, but only the [hd, hd]
state matrix carries between steps.  The kernel tiles time into CHUNK-sized
VMEM blocks — per grid step it streams r/k/v/w chunks from HBM once, runs
the recurrence in-register/VMEM (fori_loop over the chunk), and carries the
state in VMEM scratch across the (innermost, sequential) chunk axis.  HBM
traffic is one pass over the inputs — the memory-bound floor — versus a
naive lax.scan which round-trips the state every step.

Grid: (B*H, S/CHUNK).  hd is 64 for rwkv6 heads: the state tile is
64x64xf32 = 16 KiB, so state + 4 input chunks fit VMEM comfortably.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wkv_chunked", "CHUNK"]

CHUNK = 64


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                y_ref, sout_ref, state_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0]

    u = u_ref[0]                                   # [hd]

    def step(t, _):
        rt = r_ref[0, t].astype(jnp.float32)       # [hd]
        kt = k_ref[0, t].astype(jnp.float32)
        vt = v_ref[0, t].astype(jnp.float32)
        wt = w_ref[0, t].astype(jnp.float32)
        s = state_ref[...]                         # [hd, hd] key-major
        kv = kt[:, None] * vt[None, :]             # outer product
        y = jnp.einsum("k,kv->v", rt, s + u[:, None] * kv)
        y_ref[0, t] = y.astype(y_ref.dtype)
        state_ref[...] = wt[:, None] * s + kv
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(ci == pl.num_programs(1) - 1)
    def _emit_state():
        sout_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_chunked(r, k, v, w, u, state, chunk: int = CHUNK,
                interpret: bool = False):
    """r,k,v,w: [B,S,H,hd]; u: [H,hd]; state: [B,H,hd,hd].
    Returns (y [B,S,H,hd] f32, final state [B,H,hd,hd] f32)."""
    B, S, H, hd = r.shape
    chunk = min(chunk, S)
    BH = B * H

    def flat(t):
        return t.transpose(0, 2, 1, 3).reshape(BH, S, hd)

    rf, kf, vf, wf = flat(r), flat(k), flat(v), flat(w)
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(BH, hd)
    sf = state.reshape(BH, hd, hd).astype(jnp.float32)

    grid = (BH, S // chunk)
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    y, s_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hd), lambda b, c: (b, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((BH, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf, sf)
    y = y.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    return y, s_out.reshape(B, H, hd, hd)
