"""Pure-jnp oracle for blockwise (flash) causal GQA attention."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    """q: [B,Sq,H,hd]; k,v: [B,Sk,KV,hd]; GQA via head grouping.
    Returns [B,Sq,H,hd] (f32 accumulation, cast back to q.dtype)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    qf = q.astype(jnp.float32).reshape(B, Sq, KV, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, k.shape[1]), bool), k.shape[1] - Sq)
        s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)
