"""Pallas TPU kernel: blockwise causal attention (FlashAttention-style).

TPU adaptation notes (vs the CUDA original): no shared-memory banking or
warp shuffles — the analogue is VMEM-resident [BLK_Q, hd] / [BLK_K, hd]
tiles feeding the 128x128 MXU, with the online-softmax running max/sum kept
in VMEM scratch (f32).  Block sizes are MXU-aligned (multiples of 128 on
the contracting dims); the K/V loop is the pallas grid's innermost axis so
the revisit pattern is sequential in HBM.

Grid: (batch*heads, q_blocks, k_blocks); the accumulator lives in VMEM
scratch (revisited across the k axis for fixed q) and is normalized into
the output on the last K block.  Causal masking zeroes fully-masked K
blocks via ``pl.when``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention", "BLK_Q", "BLK_K"]

BLK_Q = 128
BLK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, causal: bool, blk_q: int, blk_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if causal:
        run = (ki * blk_k) <= (qi * blk_q + blk_q - 1)
    else:
        run = True

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)             # [BLK_Q, hd]
        k = k_ref[0].astype(jnp.float32)             # [BLK_K, hd]
        v = v_ref[0].astype(jnp.float32)             # [BLK_K, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [BLK_Q, BLK_K]
        if causal:
            rows = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_ref[...]                           # [BLK_Q, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # [BLK_Q, BLK_K]
        alpha = jnp.exp(m_prev - m_new)               # [BLK_Q, 1]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, ...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                         ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "blk_q", "blk_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, blk_q: int = BLK_Q,
                    blk_k: int = BLK_K, interpret: bool = False) -> jnp.ndarray:
    """q: [B,Sq,H,hd]; k,v: [B,Sk,KV,hd] (GQA: KV divides H).
    Sq % blk_q == 0 and Sk % blk_k == 0 (callers pad)."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(B * H, Sk, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(B * H, Sk, hd)

    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    grid = (B * H, Sq // blk_q, Sk // blk_k)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               blk_q=blk_q, blk_k=blk_k)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),   # running max
            pltpu.VMEM((blk_q, 1), jnp.float32),   # running sum
            pltpu.VMEM((blk_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
