"""jit'd public wrapper for blockwise attention."""

from __future__ import annotations

import jax.numpy as jnp

from .kernel import flash_attention
from .ref import attention_ref

__all__ = ["attention"]


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, use_pallas: bool = True,
              interpret: bool = True, blk_q: int = 128,
              blk_k: int = 128) -> jnp.ndarray:
    """Drop-in blockwise GQA attention; falls back to the jnp oracle."""
    if use_pallas:
        return flash_attention(q, k, v, causal=causal, blk_q=blk_q,
                               blk_k=blk_k, interpret=interpret)
    return attention_ref(q, k, v, causal=causal)
