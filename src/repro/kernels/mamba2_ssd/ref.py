"""Pure-jnp oracle for the Mamba-2 selective state-space scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssd_ref"]


def ssd_ref(x, dt, A, B, C, D, state):
    """x: [B,S,H,hd]; dt: [B,S,H] (post-softplus); A: [H] (negative);
    B,C: [B,S,N]; D: [H]; state: [B,H,hd,N].
    Identical math to ``repro.models.mamba2._ssd_scan``."""
    def step(s, inp):
        xt, dtt, Bt, Ct = inp
        da = jnp.exp(dtt * A)
        dBx = jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, Bt)
        s = da[..., None, None] * s + dBx
        yt = jnp.einsum("bhpn,bn->bhp", s, Ct) + D[None, :, None] * xt
        return s, yt

    xs = jnp.moveaxis(x, 1, 0)
    dts = jnp.moveaxis(dt, 1, 0)
    Bs = jnp.moveaxis(B, 1, 0)
    Cs = jnp.moveaxis(C, 1, 0)
    state, ys = jax.lax.scan(step, state, (xs, dts, Bs, Cs))
    return jnp.moveaxis(ys, 0, 1), state
