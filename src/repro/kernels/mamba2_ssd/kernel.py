"""Pallas TPU kernel: Mamba-2 SSD scan, chunk-parallel within a tile.

The SSD dual form: within a chunk, outputs decompose into an *intra-chunk*
part (a lower-triangular decay-weighted attention-like matmul — MXU work)
plus an *inter-chunk* part (the carried state applied through cumulative
decays).  Only the [hd, N] state carries across chunks, held in VMEM
scratch along the sequential chunk grid axis.

Grid: (B*H, S/CHUNK).  Per chunk, with hd=64, N=64, CHUNK=64: tiles are
64x64 f32 — MXU-shaped — and the whole working set is ~100 KiB of VMEM.

The intra-chunk math here follows the SSD paper's scalar-decay-per-head
structure:  decay(i<-j) = exp(cum[i] - cum[j]) with cum = cumsum(dt*A).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_chunked", "CHUNK"]

CHUNK = 64


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, s0_ref,
                y_ref, sout_ref, state_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0]

    x = x_ref[0].astype(jnp.float32)               # [C, hd]
    dt = dt_ref[0].astype(jnp.float32)             # [C]
    A = a_ref[0, 0]                                # scalar (per head)
    Bm = b_ref[0].astype(jnp.float32)              # [C, N]
    Cm = c_ref[0].astype(jnp.float32)              # [C, N]
    D = d_ref[0, 0]                                # scalar

    da = dt * A                                    # [C] (negative)
    cum = jnp.cumsum(da)                           # [C]
    # inter-chunk: y_inter[i] = exp(cum[i]) * C_i . state
    carry = state_ref[...]                         # [hd, N]
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, carry, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)        # [C, hd]
    # intra-chunk: G[i,j] = exp(cum[i]-cum[j]) * (C_i . B_j) * dt[j], j<=i
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [C, C]
    ii = jax.lax.broadcasted_iota(jnp.int32, cb.shape, 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, cb.shape, 1)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    G = jnp.where(jj <= ii, cb * decay * dt[None, :], 0.0)
    y_intra = jax.lax.dot_general(G, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_ref[0] = (y_inter + y_intra + D * x).astype(y_ref.dtype)
    # state update: S' = exp(cum[-1]) * S + sum_j exp(cum[-1]-cum[j]) dt_j x_j B_j^T
    wts = jnp.exp(cum[-1] - cum) * dt              # [C]
    sx = jax.lax.dot_general(x * wts[:, None], Bm,
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [hd, N]
    state_ref[...] = jnp.exp(cum[-1]) * carry + sx

    @pl.when(ci == pl.num_programs(1) - 1)
    def _emit():
        sout_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked(x, dt, A, B, C, D, state, chunk: int = CHUNK,
                interpret: bool = False):
    """x: [B,S,H,hd]; dt: [B,S,H]; A,D: [H]; B,C: [B,S,N];
    state: [B,H,hd,N].  Returns (y [B,S,H,hd] f32, final state f32)."""
    Bb, S, H, hd = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    BH = Bb * H
    xf = x.transpose(0, 2, 1, 3).reshape(BH, S, hd)
    dtf = dt.transpose(0, 2, 1).reshape(BH, S)
    af = jnp.broadcast_to(A[None], (Bb, H)).reshape(BH, 1)
    df = jnp.broadcast_to(D[None], (Bb, H)).reshape(BH, 1)
    bf = jnp.broadcast_to(B[:, None], (Bb, H, S, N)).reshape(BH, S, N)
    cf = jnp.broadcast_to(C[:, None], (Bb, H, S, N)).reshape(BH, S, N)
    sf = state.reshape(BH, hd, N).astype(jnp.float32)

    grid = (BH, S // chunk)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, s_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
            pl.BlockSpec((1, hd, N), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hd, N), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((BH, hd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, af, bf, cf, df, sf)
    y = y.reshape(Bb, H, S, hd).transpose(0, 2, 1, 3)
    return y, s_out.reshape(Bb, H, hd, N)
