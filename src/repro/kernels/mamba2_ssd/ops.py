"""jit'd public wrapper for the SSD scan."""

from __future__ import annotations

from .kernel import ssd_chunked
from .ref import ssd_ref

__all__ = ["ssd"]


def ssd(x, dt, A, B, C, D, state, *, use_pallas: bool = True,
        interpret: bool = True, chunk: int = 64):
    if use_pallas:
        return ssd_chunked(x, dt, A, B, C, D, state, chunk=chunk,
                           interpret=interpret)
    return ssd_ref(x, dt, A, B, C, D, state)
