"""Pure-jnp oracle for single-token decode attention over a long KV cache."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["decode_attention_ref"]


def decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, length: int) -> jnp.ndarray:
    """q: [B,H,hd] (one token); k_cache/v_cache: [B,S,KV,hd];
    ``length``: valid prefix of the cache.  Returns [B,H,hd]."""
    B, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    g = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, g, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qf, k_cache.astype(jnp.float32))
    s = s / math.sqrt(hd)
    valid = jnp.arange(S) < length
    s = jnp.where(valid[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", w, v_cache.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)
