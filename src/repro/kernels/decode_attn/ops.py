"""jit'd public wrapper for decode attention."""

from __future__ import annotations

import jax.numpy as jnp

from .kernel import decode_attention
from .ref import decode_attention_ref

__all__ = ["decode_attn"]


def decode_attn(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                length, *, use_pallas: bool = True,
                interpret: bool = True, blk_s: int = 512) -> jnp.ndarray:
    if use_pallas:
        return decode_attention(q, k_cache, v_cache, length,
                                blk_s=blk_s, interpret=interpret)
    return decode_attention_ref(q, k_cache, v_cache, length)
