"""Pallas TPU kernel: one-token decode attention over a paged/long KV cache.

Decode attention is memory-bound: the whole valid KV prefix streams from
HBM once per token while compute is a [H, hd] x [hd, BLK_S] matvec-like
contraction.  The kernel tiles the cache sequence dim into VMEM blocks
(BLK_S x hd per KV head), keeps the online-softmax state in VMEM scratch,
and masks the tail beyond ``length`` with the running-max trick — so HBM
traffic is exactly one pass over K and V (the roofline floor for decode).

Grid: (batch, kv_heads, s_blocks); innermost s visits the cache
sequentially.  All of this head's group queries [g, hd] ride in VMEM.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention", "BLK_S"]

BLK_S = 512
NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, blk_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0]
    # skip blocks entirely past the valid prefix
    @pl.when(si * blk_s < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # [g, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)         # [BLK_S, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)         # [BLK_S, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [g, BLK_S]
        pos = si * blk_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(si == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk_s", "interpret"))
def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, length: jnp.ndarray,
                     blk_s: int = BLK_S, interpret: bool = False) -> jnp.ndarray:
    """q: [B,H,hd]; k/v_cache: [B,S,KV,hd]; length: [] int32."""
    B, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    blk_s = min(blk_s, S)
    qg = q.reshape(B, KV, g, hd)
    grid = (B, KV, S // blk_s)
    kernel = functools.partial(_decode_kernel, scale=scale, blk_s=blk_s)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, blk_s, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, blk_s, 1, hd), lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(length, jnp.int32).reshape(1), qg, k_cache, v_cache)
    return out.reshape(B, H, hd)
