"""Pallas TPU kernels for the perf-critical layers (validated in interpret
mode on CPU; tiled for VMEM/MXU on real hardware):

    pivot        FormOpt section 5.4 row->column pivot, on device
    flashattn    blockwise causal GQA attention (train / prefill)
    decode_attn  one-token attention over a long KV cache (serving)
    rwkv6_scan   RWKV-6 WKV recurrence, chunk-tiled
    mamba2_ssd   Mamba-2 SSD chunk-parallel dual form

Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper
with a use_pallas/ref switch), ref.py (pure-jnp oracle).
"""

from .pivot.ops import pivot, pivot_columns
from .flashattn.ops import attention as flash_attention
from .decode_attn.ops import decode_attn
from .rwkv6_scan.ops import wkv
from .mamba2_ssd.ops import ssd

__all__ = ["pivot", "pivot_columns", "flash_attention", "decode_attn",
           "wkv", "ssd"]
