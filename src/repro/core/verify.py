"""Verification proxy (paper section 4.1).

After PipeGen generates a pipe, it validates the modified engine by running
the engine's own unit tests with the pipe activated while a *proxy* plays
the role of the remote DBMS:

* export leg — the proxy registers as an importer, receives everything the
  engine pushes down the pipe, and spools it to a real disk file using the
  original text rendering;
* import leg — the proxy reads that spool file and transmits it through a
  pipe into the engine's importer.

The engine's existing test assertions (exported data == imported data) then
validate the generated pipe end to end: if FormOpt mis-inferred a delimiter
or dropped a value, the spooled text differs and the test fails, which makes
PipeGen disable the offending optimization (sections 5.1/5.3.1).

The *probabilistic runtime check* (first-n rows shipped in V frames and
compared on the import side) lives in the data pipe itself
(``PipeConfig.verify_first_n``); this module provides the compile-time
proxy.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, List, Optional

from .astring import AString
from .datapipe import DataPipeInput, DataPipeOutput, PipeConfig
from .directory import DirectoryLike, get_directory

__all__ = ["VerificationProxy", "VerificationResult", "validate_generated_pipe"]

EXPORT_LEG = "pgv-export"
IMPORT_LEG = "pgv-import"


@dataclass
class VerificationResult:
    engine: str
    passed: bool
    detail: str = ""
    spool_bytes: int = 0


class VerificationProxy:
    """Plays the remote DBMS for both legs of a round-trip unit test."""

    def __init__(
        self,
        spool_dir: Path,
        directory: Optional[DirectoryLike] = None,
        config: Optional[PipeConfig] = None,
    ):
        self.spool_dir = Path(spool_dir)
        self.spool_dir.mkdir(parents=True, exist_ok=True)
        self.directory = directory or get_directory()
        self.config = config or PipeConfig()
        self.errors: List[str] = []
        self._spooled: dict = {}

    def _spool_event(self, dataset: str) -> threading.Event:
        return self._spooled.setdefault(dataset, threading.Event())

    # -- export leg: pipe -> disk ------------------------------------------------
    def start_sink(self, dataset: str) -> threading.Thread:
        """Register as importer for the export leg; spool received data to
        disk exactly as the file path would have."""

        def run() -> None:
            try:
                pipe = DataPipeInput(
                    f"db://{dataset}?query={EXPORT_LEG}", directory=self.directory
                )
                text = pipe.read()
                pipe.close()
                self.spool_path(dataset).write_text(text)
            except Exception as e:  # noqa: BLE001 - surfaced via self.errors
                self.errors.append(f"sink({dataset}): {e!r}")
            finally:
                self._spool_event(dataset).set()

        t = threading.Thread(target=run, daemon=True)
        t.start()
        return t

    # -- import leg: disk -> pipe ------------------------------------------------
    def start_source(self, dataset: str) -> threading.Thread:
        """Read the spool file and transmit it through a pipe into the
        engine's importer (which registers with the directory)."""

        def run() -> None:
            try:
                # connect first (blocks until the engine's importer registers),
                # by which time the sink has spooled the export leg
                pipe = DataPipeOutput(
                    f"db://{dataset}?query={IMPORT_LEG}",
                    config=self.config,
                    directory=self.directory,
                )
                if not self._spool_event(dataset).wait(timeout=30):
                    raise TimeoutError("export leg never spooled")
                text = self.spool_path(dataset).read_text()
                for line in text.splitlines(keepends=True):
                    # feed as AStrings so FormOpt modes work on the proxy side
                    pipe.write(AString((line,)))
                pipe.close()
            except Exception as e:  # noqa: BLE001
                self.errors.append(f"source({dataset}): {e!r}")

        t = threading.Thread(target=run, daemon=True)
        t.start()
        return t

    def spool_path(self, dataset: str) -> Path:
        return self.spool_dir / f"{dataset}.spool"


def validate_generated_pipe(
    engine_name: str,
    roundtrip_test: Callable[[str, str], None],
    spool_dir: Path,
    dataset: Optional[str] = None,
    directory: Optional[DirectoryLike] = None,
    config: Optional[PipeConfig] = None,
) -> VerificationResult:
    """Run one engine round-trip unit test across the verification proxy.

    ``roundtrip_test(export_target, import_target)`` must export known data
    to the first name, import from the second, and assert equality — the
    engine's own unit-test logic.  We hand it reserved names wired through
    the proxy; any assertion failure means the generated pipe corrupted
    data and the caller disables the optimization under test.
    """
    dataset = dataset or f"verify-{engine_name}"
    proxy = VerificationProxy(spool_dir, directory=directory, config=config)
    sink = proxy.start_sink(dataset)
    source = proxy.start_source(dataset)

    export_name = f"db://{dataset}?query={EXPORT_LEG}"
    import_name = f"db://{dataset}?query={IMPORT_LEG}"
    try:
        roundtrip_test(export_name, import_name)
    except Exception as e:  # noqa: BLE001 - verification outcome, not a crash
        return VerificationResult(engine_name, False, f"unit test failed: {e!r}")
    finally:
        sink.join(timeout=30)
        source.join(timeout=30)
    if proxy.errors:
        return VerificationResult(engine_name, False, "; ".join(proxy.errors))
    spool = proxy.spool_path(dataset)
    size = spool.stat().st_size if spool.exists() else 0
    return VerificationResult(engine_name, True, "round-trip matched", size)
