"""Stream fabric: stripe one pipe across N member transports, and merge
N exporter streams into one importer-facing stream.

The seed transports carry one logical pipe over exactly one connection, so
a single large export is bounded by one core/NIC no matter how parallel the
engines are (the top ROADMAP open item).  This module composes the
*existing* transports (socket, channel, shm — anything implementing
:class:`~repro.core.transport.Transport`) into two fabric shapes:

* **Striping** (:class:`StripedSender` / :class:`StripedReceiver`): one
  exporter's frame sequence is spread round-robin across N member
  connections, each frame tagged with a monotonically increasing global
  sequence number, and reassembled in order on the import side through a
  bounded reorder window with per-stream credits.
* **Fan-in** (:class:`FaninTransport`): the N→M shuffle's import side — N
  independent exporter streams (each a well-formed schema→blocks→EOF
  sequence) merged into one stream, with duplicate schema frames dropped
  and end-of-stream delivered only after every source finished.

Striped wire protocol (per member connection)::

    frame 0:  kind 'M' (FRAME_STRIPE)  json {"stream": i, "streams": n}
    frame k:  original kind            u32-LE seq || original payload

Sequence numbers are assigned by the sender from a single counter across
all members, so reassembly is a total order: the receiver delivers seq 0,
1, 2, … regardless of which member each frame traveled on.  The explicit
EOF frame the pipe writer emits is tagged like any other frame (its
payload is the 4-byte seq alone), so end-of-stream is itself ordered after
every data frame; a *bare* EOF (zero-byte payload: peer FIN, stub
connection, ring writer death) terminates that member without a sequence
number.

Backpressure: the receiver's reorder window is ``window`` frames, split
into per-stream credits (``max(2, window // n)`` each).  A member reader
blocks acquiring its stream's credit *before* buffering a frame, which
stops it from reading its transport — TCP flow control, the channel's
bounded queue, or the shm ring's fullness then push back on the sender.
Because every stream keeps at least two credits of its own, the stream
carrying the next-in-order frame can always make progress: no deadlock.

Zero-copy note: reassembly hands frames to a consumer on another thread,
so member payloads that are views of transport memory (shm ring spans)
are copied out once at the reader.  Striping trades that copy for N-way
transport parallelism; an unstriped shm pipe remains the zero-copy path.
"""

from __future__ import annotations

import json
import queue
import struct
import threading
from typing import Dict, List, Optional, Tuple

from . import faults
from . import telemetry
from .transport import (
    FRAME_BLOCK,
    FRAME_EOF,
    FRAME_PARTS,
    FRAME_SCHEMA,
    FRAME_STRIPE,
    FRAME_VERIFY,
    Transport,
)

__all__ = [
    "StripedSender",
    "StripedReceiver",
    "FaninTransport",
    "DEFAULT_STREAM_WINDOW",
]

_SEQ = struct.Struct("<I")

#: default reorder-window size (frames buffered out of order, all streams)
DEFAULT_STREAM_WINDOW = 64


def _hello_payload(stream: int, streams: int) -> bytes:
    return json.dumps({"stream": stream, "streams": streams}).encode()


def _parse_hello(payload) -> Tuple[int, int]:
    doc = json.loads(bytes(payload).decode())
    return int(doc["stream"]), int(doc["streams"])


class StripedSender(Transport):
    """Spread one frame sequence across N member transports.

    ``send_frames`` materializes the payload once (the member send happens
    on a per-stream thread after the caller's pooled buffers are recycled
    — the same contract as :class:`~repro.core.transport.ChannelTransport`),
    tags it with the next global sequence number, and enqueues it on the
    ``seq % n`` member's bounded queue.  Per-stream worker threads do the
    actual transport sends, so N sockets (or rings) are written
    concurrently.  Errors latch: the first member failure is re-raised on
    the next submit or, at the latest, on :meth:`close`; queued frames
    drain so the producer never blocks on a dead member.
    """

    _DONE = object()

    def __init__(self, transports: List[Transport], depth: int = 4):
        if not transports:
            raise ValueError("striped sender needs at least one member")
        self.members = list(transports)
        self.error: Optional[BaseException] = None
        self._seq = 0
        self._busy_s = [0.0] * len(self.members)
        self._queues: List["queue.Queue"] = [
            queue.Queue(maxsize=max(1, depth)) for _ in self.members
        ]
        n = len(self.members)
        for i, tr in enumerate(self.members):
            tr.send_frame(FRAME_STRIPE, _hello_payload(i, n))
        self._threads = [
            threading.Thread(target=self._run, args=(i,),
                             name=f"pipegen-stripe-{i}", daemon=True)
            for i in range(n)
        ]
        for t in self._threads:
            t.start()

    @property
    def nstreams(self) -> int:
        return len(self.members)

    # aggregated counters (read by DataPipeOutput.close after drain)
    @property
    def bytes_sent(self) -> int:  # type: ignore[override]
        return sum(m.bytes_sent for m in self.members)

    @property
    def frames_sent(self) -> int:  # type: ignore[override]
        return sum(m.frames_sent for m in self.members)

    @property
    def shm_spans(self) -> int:
        return sum(getattr(m, "shm_spans", 0) for m in self.members)

    def per_stream(self) -> List[dict]:
        """Per-member breakdown for ``PipeStats.per_stream``."""
        return [
            {"stream": i, "bytes": m.bytes_sent, "frames": m.frames_sent,
             "busy_s": self._busy_s[i]}
            for i, m in enumerate(self.members)
        ]

    def send_frames(self, kind: bytes, segments) -> None:
        if self.error is not None:
            raise self.error
        segs = [bytes(s) for s in segments]
        payload = segs[0] if len(segs) == 1 else b"".join(segs)
        if faults._ACTIVE is not None:
            # pre-striping hook: a dropped frame here means a hole in the
            # seq space, which the receiver's reorder window must surface
            # as a loud stall/timeout rather than silent reordering
            act = faults.fire("stream.send", kind=kind)
            if act == "drop":
                self._seq += 1  # the seq is consumed but never sent
                return
            if act == "corrupt" and payload:
                buf = bytearray(payload)
                buf[len(buf) // 2] ^= 0xFF
                payload = bytes(buf)
        seq = self._seq
        self._seq += 1
        self._queues[seq % len(self.members)].put(
            (kind, _SEQ.pack(seq), payload))

    def _run(self, idx: int) -> None:
        import time as _time

        tr = self._queues[idx]
        member = self.members[idx]
        while True:
            item = tr.get()
            if item is self._DONE:
                return
            if self.error is not None:
                continue  # drain: the producer must not block on a dead pipe
            kind, seq_hdr, payload = item
            t0 = _time.perf_counter()
            try:
                member.send_frames(kind, (seq_hdr, payload))
            except BaseException as e:  # noqa: BLE001 - latched, re-raised
                self.error = self.error or e
            finally:
                self._busy_s[idx] += _time.perf_counter() - t0

    def close(self) -> None:
        for q in self._queues:
            q.put(self._DONE)
        for t in self._threads:
            t.join()
        for m in self.members:
            m.close()
        if self.error is not None:
            raise self.error


class StripedReceiver(Transport):
    """Reassemble a striped frame sequence in global seq order.

    Presents the ordinary :meth:`recv_frame` surface, so
    ``DataPipeInput`` consumes a striped pipe exactly like a single
    connection.  One reader thread per member pulls frames, copies
    transport-owned views out, and buffers them under their sequence
    number after acquiring its stream's credit; :meth:`recv_frame` waits
    for the next in-order frame and releases the credit on delivery.
    """

    def __init__(self, transports: List[Transport],
                 window: int = DEFAULT_STREAM_WINDOW):
        if not transports:
            raise ValueError("striped receiver needs at least one member")
        self.members = list(transports)
        n = len(self.members)
        self._credit_per_stream = max(2, window // n)
        self._credits = [threading.Semaphore(self._credit_per_stream)
                         for _ in range(n)]
        self._lock = threading.Condition()
        self._buf: Dict[int, Tuple[bytes, object, int]] = {}
        self._next = 0
        self._done = 0
        self._closing = False
        self._error: Optional[BaseException] = None
        self._frames = [0] * n
        self._bytes = [0] * n
        # head-of-line waits: the next in-order frame was absent while
        # later frames sat buffered (skew between member streams)
        self.reorder_stalls = 0
        self._threads = [
            threading.Thread(target=self._reader, args=(i,),
                             name=f"pipegen-reasm-{i}", daemon=True)
            for i in range(n)
        ]
        for t in self._threads:
            t.start()

    @property
    def nstreams(self) -> int:
        return len(self.members)

    @property
    def shm_spans(self) -> int:
        return sum(getattr(m, "shm_spans", 0) for m in self.members)

    def per_stream(self) -> List[dict]:
        return [
            {"stream": i, "frames": self._frames[i], "bytes": self._bytes[i]}
            for i in range(len(self.members))
        ]

    def _reader(self, idx: int) -> None:
        tr = self.members[idx]
        sem = self._credits[idx]
        try:
            while True:
                kind, payload = tr.recv_frame()
                if kind == FRAME_STRIPE:
                    _, streams = _parse_hello(payload)
                    if streams != len(self.members):
                        raise IOError(
                            f"striped peer announces {streams} streams, "
                            f"importer built {len(self.members)}")
                    continue
                if len(payload) < _SEQ.size:
                    if kind == FRAME_EOF:
                        return  # bare EOF: FIN / stub / member death
                    raise IOError(
                        f"striped frame {kind!r} too short for a sequence "
                        f"header ({len(payload)} bytes)")
                # reassembly hands the frame to the consumer thread, so
                # transport-owned views (shm ring spans) are copied out now
                if isinstance(payload, memoryview):
                    payload = bytes(payload)
                seq = _SEQ.unpack_from(payload)[0]
                inner = memoryview(payload)[_SEQ.size:]
                sem.acquire()
                with self._lock:
                    if self._closing:
                        return
                    self._buf[seq] = (kind, inner, idx)
                    self._frames[idx] += 1
                    self._bytes[idx] += len(payload)
                    self._lock.notify_all()
                if kind == FRAME_EOF:
                    return  # the tagged EOF is the stream-final frame
        except BaseException as e:  # noqa: BLE001 - surfaced on recv_frame
            with self._lock:
                if not self._closing:
                    self._error = self._error or e
                self._lock.notify_all()
        finally:
            with self._lock:
                self._done += 1
                self._lock.notify_all()

    def recv_frame(self) -> Tuple[bytes, bytes]:
        with self._lock:
            while True:
                got = self._buf.pop(self._next, None)
                if got is not None:
                    kind, inner, idx = got
                    self._next += 1
                    self._credits[idx].release()
                    if kind == FRAME_EOF:
                        return FRAME_EOF, b""
                    # only block/parts payloads may be views (the decoders
                    # consume them in place); everything else goes through
                    # str.decode downstream and must be bytes — the same
                    # invariant as ShmRingTransport._ZERO_COPY_KINDS
                    if kind in (FRAME_BLOCK, FRAME_PARTS):
                        return kind, inner
                    return kind, bytes(inner)
                if self._error is not None:
                    raise IOError(
                        f"striped member failed: {self._error!r}"
                    ) from self._error
                if self._done >= len(self.members):
                    if self._buf:
                        missing = self._next
                        have = sorted(self._buf)
                        raise IOError(
                            f"striped stream ended with frame {missing} "
                            f"missing (buffered seqs {have[:8]}...)")
                    return FRAME_EOF, b""
                if self._buf:
                    self.reorder_stalls += 1
                    telemetry.counter("stream.reorder_stalls").inc()
                self._lock.wait(0.5)

    def close(self) -> None:
        with self._lock:
            self._closing = True
            self._lock.notify_all()
        # unblock readers parked on exhausted credits
        for sem in self._credits:
            for _ in range(self._credit_per_stream):
                sem.release()
        for m in self.members:
            m.close()
        for t in self._threads:
            t.join(timeout=5.0)


_SOURCE_DONE = object()


class FaninTransport(Transport):
    """Merge N exporter streams into one importer-facing frame stream.

    Two wirings, one surface:

    * **multi-member** (sockets): one accepted connection per exporter;
      a reader thread per member funnels frames into a queue and the
      merged stream ends when every member reached end-of-stream;
    * **single shared member** (the in-process channel, whose queue is
      already multi-producer-safe): frames from all exporters interleave
      on one transport and the merged stream ends after
      ``expected_sources`` explicit EOF frames.

    Each source is a well-formed ``schema → data → EOF`` sequence; the
    merge passes the first schema frame through, drops the duplicates
    (a shuffle's exporters all describe the same relation), and drops
    verify frames — row order across sources is not defined, so the
    section 4.1 probabilistic check is meaningless on a merged stream
    (``ShuffleWriter`` disables it at the source too).
    """

    def __init__(self, transports: List[Transport],
                 expected_sources: Optional[int] = None):
        if not transports:
            raise ValueError("fan-in needs at least one member")
        self.members = list(transports)
        self.expected_sources = expected_sources or len(self.members)
        self._schema_seen = False
        self._first_schema: bytes = b""
        self._ended = 0
        self._eof = False
        if len(self.members) > 1:
            self._q: "queue.Queue" = queue.Queue(maxsize=64)
            self._threads = [
                threading.Thread(target=self._reader, args=(tr,),
                                 name="pipegen-fanin", daemon=True)
                for tr in self.members
            ]
            for t in self._threads:
                t.start()
        else:
            self._threads = []

    @property
    def fanin(self) -> int:
        return self.expected_sources

    def _reader(self, tr: Transport) -> None:
        try:
            while True:
                kind, payload = tr.recv_frame()
                if isinstance(payload, memoryview):
                    payload = bytes(payload)
                if kind == FRAME_EOF:
                    return
                self._q.put((kind, payload))
        except BaseException as e:  # noqa: BLE001 - surfaced on recv_frame
            self._q.put(e)
        finally:
            self._q.put(_SOURCE_DONE)

    def _next_raw(self) -> Tuple[bytes, bytes]:
        """One frame from the merged firehose; EOF once every source ended."""
        if not self._threads:  # shared single member: count EOF frames
            while True:
                kind, payload = self.members[0].recv_frame()
                if kind == FRAME_EOF:
                    self._ended += 1
                    if self._ended >= self.expected_sources:
                        return FRAME_EOF, b""
                    continue
                return kind, payload
        while True:
            item = self._q.get()
            if item is _SOURCE_DONE:
                self._ended += 1
                if self._ended >= len(self.members):
                    return FRAME_EOF, b""
                continue
            if isinstance(item, BaseException):
                raise IOError(f"fan-in source failed: {item!r}") from item
            return item

    def recv_frame(self) -> Tuple[bytes, bytes]:
        while not self._eof:
            kind, payload = self._next_raw()
            if kind == FRAME_EOF:
                self._eof = True
                return FRAME_EOF, b""
            if kind == FRAME_SCHEMA:
                if self._schema_seen:
                    # same relation, described N times -- but a mis-wired
                    # shuffle mixing relations must fail here, not decode
                    # the other source's blocks under the wrong layout
                    self._check_schema_match(payload)
                    continue
                self._schema_seen = True
                self._first_schema = bytes(payload)
            elif kind == FRAME_VERIFY:
                continue  # undefined row order across sources
            return kind, payload
        return FRAME_EOF, b""

    def _check_schema_match(self, payload) -> None:
        if bytes(payload) == self._first_schema:
            return
        from .wire import decode_schema

        first, _ = decode_schema(self._first_schema)
        other, _ = decode_schema(bytes(payload))
        if first.types != other.types:
            raise IOError(
                f"fan-in sources disagree on the relation: {first!r} "
                f"vs {other!r}")
        # same column types, different meta (e.g. a per-source sniffed
        # delimiter): the first source's dialect already won, carry on

    def close(self) -> None:
        for m in self.members:
            m.close()
        for t in self._threads:
            t.join(timeout=5.0)
