"""Typed-parts row format: binary values, delimiters retained.

This is the intermediate rung of fig. 11 ("transmit fixed-width values in
binary form" but *before* "delimiter removal"): each row is the AString part
sequence the serializer produced, with primitives in binary and delimiter /
structural strings still present as string parts.

Block layout:
    nrows: uint32
    per row: nparts uint16, then per part: tag byte + payload
      tag 'q' int64 | 'd' float64 | '?' bool | 's' string(uint32 len + utf8)
"""

from __future__ import annotations

import struct
from typing import List, Sequence

from ..astring import AString
from ..types import ColumnBlock, Schema
from .base import WireFormat, register_wire_format

_TAG_INT = b"q"[0]
_TAG_FLT = b"d"[0]
_TAG_BOO = b"?"[0]
_TAG_STR = b"s"[0]


@register_wire_format
class PartsRowsFormat(WireFormat):
    name = "parts_rows"

    # This format is special: it round-trips *part rows*, not ColumnBlocks.
    def encode_parts(self, part_rows: Sequence[Sequence]) -> bytes:
        out: List[bytes] = [struct.pack("<I", len(part_rows))]
        for parts in part_rows:
            out.append(struct.pack("<H", len(parts)))
            for p in parts:
                if isinstance(p, bool):
                    out.append(struct.pack("<Bb", _TAG_BOO, int(p)))
                elif isinstance(p, int):
                    out.append(struct.pack("<Bq", _TAG_INT, p))
                elif isinstance(p, float):
                    out.append(struct.pack("<Bd", _TAG_FLT, p))
                else:
                    b = str(p).encode("utf-8", "surrogatepass")
                    out.append(struct.pack("<BI", _TAG_STR, len(b)))
                    out.append(b)
        return b"".join(out)

    def decode_parts(self, data: bytes) -> List[AString]:
        (nrows,) = struct.unpack_from("<I", data, 0)
        off = 4
        rows: List[AString] = []
        for _ in range(nrows):
            (nparts,) = struct.unpack_from("<H", data, off)
            off += 2
            parts = []
            for _ in range(nparts):
                tag = data[off]
                off += 1
                if tag == _TAG_INT:
                    (v,) = struct.unpack_from("<q", data, off)
                    off += 8
                elif tag == _TAG_FLT:
                    (v,) = struct.unpack_from("<d", data, off)
                    off += 8
                elif tag == _TAG_BOO:
                    v = bool(data[off])
                    off += 1
                else:
                    (ln,) = struct.unpack_from("<I", data, off)
                    off += 4
                    v = data[off : off + ln].decode("utf-8", "surrogatepass")
                    off += ln
                parts.append(v)
            rows.append(AString(parts))
        return rows

    # ColumnBlock interface for uniformity: delegate through part rows with a
    # single delimiter part between cells (used only in benchmarks that force
    # this rung on block data).
    def encode_block(self, block: ColumnBlock) -> bytes:
        rb = block.to_rows()
        part_rows = []
        for row in rb.rows:
            parts: List = []
            for j, v in enumerate(row):
                if j:
                    parts.append(",")
                parts.append(v)
            part_rows.append(parts)
        return self.encode_parts(part_rows)

    def decode_block(self, data: bytes, schema: Schema) -> ColumnBlock:
        from ..formopt import DelimitedAssembler

        asm = DelimitedAssembler(sample_rows=4)
        for astr in self.decode_parts(data):
            asm.write(astr)
            asm.write(AString(("\n",)))
        asm.flush()
        rb = asm.take_rows()
        # trust the stream schema (names) over inference
        rb.schema = schema
        return rb.to_columns()
