"""Typed-parts row format: binary values, delimiters retained.

This is the intermediate rung of fig. 11 ("transmit fixed-width values in
binary form" but *before* "delimiter removal"): each row is the AString part
sequence the serializer produced, with primitives in binary and delimiter /
structural strings still present as string parts.

Block layout:
    nrows: uint32
    per row: nparts uint16, then per part: tag byte + payload
      tag 'q' int64 | 'd' float64 | '?' bool | 's' string(uint32 len + utf8)

Encode writes straight into a pooled store via BufWriter (scatter-gather
contract: :meth:`encode_parts`/:meth:`encode_block` return a SegmentList).
"""

from __future__ import annotations

import struct
from typing import List, Optional, Sequence

from ..astring import AString
from ..iobuf import BufferPool, BufWriter, DecodeArena, SegmentList
from ..types import ColumnBlock, Schema
from .base import WireFormat, register_wire_format, tobytes

_TAG_INT = b"q"[0]
_TAG_FLT = b"d"[0]
_TAG_BOO = b"?"[0]
_TAG_STR = b"s"[0]

_NROWS = struct.Struct("<I")
_NPARTS = struct.Struct("<H")
_P_BOO = struct.Struct("<Bb")
_P_INT = struct.Struct("<Bq")
_P_FLT = struct.Struct("<Bd")
_P_STR = struct.Struct("<BI")


@register_wire_format
class PartsRowsFormat(WireFormat):
    name = "parts_rows"

    # This format is special: it round-trips *part rows*, not ColumnBlocks.
    def encode_parts(
        self, part_rows: Sequence[Sequence], pool: Optional[BufferPool] = None
    ) -> SegmentList:
        w = BufWriter(pool, size_hint=4 + 16 * sum(len(p) for p in part_rows))
        w.pack_into(_NROWS, len(part_rows))
        for parts in part_rows:
            w.pack_into(_NPARTS, len(parts))
            for p in parts:
                if isinstance(p, bool):
                    w.pack_into(_P_BOO, _TAG_BOO, int(p))
                elif isinstance(p, int):
                    w.pack_into(_P_INT, _TAG_INT, p)
                elif isinstance(p, float):
                    w.pack_into(_P_FLT, _TAG_FLT, p)
                else:
                    b = str(p).encode("utf-8", "surrogatepass")
                    w.pack_into(_P_STR, _TAG_STR, len(b))
                    w.write(b)
        return w.detach()

    def decode_parts(self, data) -> List[AString]:
        (nrows,) = struct.unpack_from("<I", data, 0)
        off = 4
        rows: List[AString] = []
        for _ in range(nrows):
            (nparts,) = struct.unpack_from("<H", data, off)
            off += 2
            parts = []
            for _ in range(nparts):
                tag = data[off]
                off += 1
                if tag == _TAG_INT:
                    (v,) = struct.unpack_from("<q", data, off)
                    off += 8
                elif tag == _TAG_FLT:
                    (v,) = struct.unpack_from("<d", data, off)
                    off += 8
                elif tag == _TAG_BOO:
                    v = bool(data[off])
                    off += 1
                else:
                    (ln,) = struct.unpack_from("<I", data, off)
                    off += 4
                    v = tobytes(data[off : off + ln]).decode(
                        "utf-8", "surrogatepass")
                    off += ln
                parts.append(v)
            rows.append(AString(parts))
        return rows

    # ColumnBlock interface for uniformity: delegate through part rows with a
    # single delimiter part between cells (used only in benchmarks that force
    # this rung on block data).
    def encode_block(
        self, block: ColumnBlock, pool: Optional[BufferPool] = None
    ) -> SegmentList:
        rb = block.to_rows()
        part_rows = []
        for row in rb.rows:
            parts: List = []
            for j, v in enumerate(row):
                if j:
                    parts.append(",")
                parts.append(v)
            part_rows.append(parts)
        return self.encode_parts(part_rows, pool)

    def decode_block(self, data, schema: Schema,
                     arena: Optional[DecodeArena] = None) -> ColumnBlock:
        from ..formopt import DelimitedAssembler

        asm = DelimitedAssembler(sample_rows=4)
        for astr in self.decode_parts(data):
            asm.write(astr)
            asm.write(AString(("\n",)))
        asm.flush()
        rb = asm.take_rows()
        # trust the stream schema (names) over inference
        rb.schema = schema
        return rb.to_columns(arena=arena)
