"""Wire format interface + schema frame codec.

Contract (zero-copy scatter-gather): :meth:`WireFormat.encode_block`
returns a :class:`~repro.core.iobuf.SegmentList` -- an ordered list of
buffer views over live column memory and pooled stores -- NOT one
concatenated ``bytes``.  The transport sends the segments with a single
vectored syscall and then releases them back to the buffer pool.  Callers
that genuinely need contiguous bytes (compressing codecs, tests) use
``SegmentList.join()`` and pay for the copy explicitly.

:meth:`WireFormat.decode_block` accepts any contiguous bytes-like object --
including a ``memoryview`` straight into a shared-memory ring span, which
it consumes **in place** (no up-front ``bytes(data)`` materialization; only
the decoded values leave the view).  With ``arena`` set, the fixed-width
output columns are carved from a recycled
:class:`~repro.core.iobuf.DecodeArena` store instead of freshly allocated.
"""

from __future__ import annotations

import json
from typing import Dict, Optional, Type, Union

from ..iobuf import BufferPool, DecodeArena, SegmentList
from ..types import ColumnBlock, Schema

__all__ = [
    "WireFormat",
    "encode_schema",
    "decode_schema",
    "WIRE_FORMATS",
    "get_wire_format",
    "register_wire_format",
    "tobytes",
]

WireData = Union[bytes, bytearray, memoryview]


def tobytes(data: WireData) -> bytes:
    """Materialize a slice of wire data (string heaps and the like).
    Free for ``bytes`` input; a bounded copy for in-place views."""
    return data if isinstance(data, bytes) else bytes(data)


class WireFormat:
    """Serializes/deserializes one ColumnBlock payload (framing is the
    transport's job; schema travels once per stream in a schema frame)."""

    name: str = "abstract"

    def encode_block(
        self, block: ColumnBlock, pool: Optional[BufferPool] = None
    ) -> SegmentList:
        """Encode ``block`` into a list of buffer views.  ``pool`` supplies
        reusable backing stores; ``None`` uses the process-default pool."""
        raise NotImplementedError

    def decode_block(self, data: WireData, schema: Schema,
                     arena: Optional[DecodeArena] = None) -> ColumnBlock:
        """Decode one block.  ``data`` may be a ``memoryview`` into live
        transport memory (consumed in place; the caller recycles the span
        after this returns).  ``arena`` supplies pooled output stores for
        the fixed-width columns."""
        raise NotImplementedError


def encode_schema(schema: Schema, meta: dict | None = None) -> bytes:
    """Schema frame: transmitted exactly once per stream.  ``meta`` carries
    the text-format profile (delimiter, json flavor) so the importing side
    can regenerate byte-identical text when the engine insists on characters
    -- this is the 'key header'/metadata-once idea of section 5.3.2 applied
    to the whole stream."""
    doc = {"schema": schema.to_dict(), "meta": meta or {}}
    return json.dumps(doc).encode("utf-8")


def decode_schema(data: bytes) -> tuple:
    doc = json.loads(bytes(data).decode("utf-8"))
    return Schema.from_dict(doc["schema"]), doc.get("meta", {})


WIRE_FORMATS: Dict[str, Type[WireFormat]] = {}


def register_wire_format(cls: Type[WireFormat]) -> Type[WireFormat]:
    WIRE_FORMATS[cls.name] = cls
    return cls


def get_wire_format(name: str, **kw) -> WireFormat:
    try:
        return WIRE_FORMATS[name](**kw)
    except KeyError:
        raise ValueError(
            f"unknown wire format {name!r}; have {sorted(WIRE_FORMATS)}"
        ) from None
