"""Wire format interface + schema frame codec."""

from __future__ import annotations

import json
from typing import Dict, Type

from ..types import ColumnBlock, Schema

__all__ = [
    "WireFormat",
    "encode_schema",
    "decode_schema",
    "WIRE_FORMATS",
    "get_wire_format",
    "register_wire_format",
]


class WireFormat:
    """Serializes/deserializes one ColumnBlock payload (framing is the
    transport's job; schema travels once per stream in a schema frame)."""

    name: str = "abstract"

    def encode_block(self, block: ColumnBlock) -> bytes:
        raise NotImplementedError

    def decode_block(self, data: bytes, schema: Schema) -> ColumnBlock:
        raise NotImplementedError


def encode_schema(schema: Schema, meta: dict | None = None) -> bytes:
    """Schema frame: transmitted exactly once per stream.  ``meta`` carries
    the text-format profile (delimiter, json flavor) so the importing side
    can regenerate byte-identical text when the engine insists on characters
    -- this is the 'key header'/metadata-once idea of section 5.3.2 applied
    to the whole stream."""
    doc = {"schema": schema.to_dict(), "meta": meta or {}}
    return json.dumps(doc).encode("utf-8")


def decode_schema(data: bytes) -> tuple:
    doc = json.loads(data.decode("utf-8"))
    return Schema.from_dict(doc["schema"]), doc.get("meta", {})


WIRE_FORMATS: Dict[str, Type[WireFormat]] = {}


def register_wire_format(cls: Type[WireFormat]) -> Type[WireFormat]:
    WIRE_FORMATS[cls.name] = cls
    return cls


def get_wire_format(name: str, **kw) -> WireFormat:
    try:
        return WIRE_FORMATS[name](**kw)
    except KeyError:
        raise ValueError(
            f"unknown wire format {name!r}; have {sorted(WIRE_FORMATS)}"
        ) from None
