"""Intermediate wire formats (paper sections 5.4 and 7.3).

The paper compares a custom row-major binary format, protocol buffers
(static and dynamic message templates), and Apache Arrow (row and column
oriented), finding Arrow-columnar fastest.  We implement analogs of each so
the fig. 13 benchmark reproduces the comparison:

* ``parts_rows``   -- typed AString parts, delimiters retained (the
                      "+binary, no delimiter removal" rung of fig. 11)
* ``binary_rows``  -- custom format: schema header, fixed-width values in
                      binary, length-prefixed strings, row-major
* ``tagged``       -- protobuf-like tag/varint encoding, static or dynamic
                      message templates
* ``arrowrow``     -- preallocated typed buffers, row-major interleaved
                      (numpy structured arrays; Arrow row-oriented analog)
* ``arrowcol``     -- per-column contiguous buffers + string heaps (Arrow
                      columnar analog; the winner and PipeGen's default)

Every format encodes/decodes ``ColumnBlock``s; a stream begins with a schema
frame produced by :func:`encode_schema`.
"""

from .base import WireFormat, encode_schema, decode_schema, get_wire_format, WIRE_FORMATS
from .binary_rows import BinaryRowsFormat
from .parts_rows import PartsRowsFormat
from .tagged import TaggedFormat
from .arrowcol import ArrowColFormat, ArrowRowFormat

__all__ = [
    "WireFormat",
    "encode_schema",
    "decode_schema",
    "get_wire_format",
    "WIRE_FORMATS",
    "BinaryRowsFormat",
    "PartsRowsFormat",
    "TaggedFormat",
    "ArrowColFormat",
    "ArrowRowFormat",
]
