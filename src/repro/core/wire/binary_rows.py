"""Custom row-major binary format (paper section 7.3's 'custom format').

Schema travels in the stream's schema frame; each block is:

    nrows: uint32
    then per row: fixed-width values packed little-endian in schema order,
    strings as uint32 length prefix + utf8 bytes.

Deliberately row-major with a per-row pack loop: this is the paper's
"basic custom format" rung, faster than text but slower than the
column-pivoted Arrow analog.  The pack loop now writes straight into a
pooled store (no per-block list-of-bytes + join allocation).
"""

from __future__ import annotations

import struct
from typing import List, Optional

import numpy as np

from ..iobuf import BufferPool, BufWriter, DecodeArena, SegmentList
from ..types import ColType, ColumnBlock, Schema
from .base import WireFormat, register_wire_format, tobytes

_FIXED_FMT = {
    ColType.INT32: "i",
    ColType.INT64: "q",
    ColType.FLOAT32: "f",
    ColType.FLOAT64: "d",
    ColType.BOOL: "?",
}

_LEN = struct.Struct("<I")


@register_wire_format
class BinaryRowsFormat(WireFormat):
    name = "binary_rows"

    def encode_block(
        self, block: ColumnBlock, pool: Optional[BufferPool] = None
    ) -> SegmentList:
        schema = block.schema
        rb = block.to_rows()
        w = BufWriter(pool, size_hint=4 + len(rb) * (schema.fixed_row_width + 8))
        w.pack_into(_LEN, len(rb))
        # precompile a packer for maximal runs of fixed-width fields
        plan = _pack_plan(schema)
        for row in rb.rows:
            for kind, payload in plan:
                if kind == "fixed":
                    st, idxs = payload
                    w.pack_into(st, *[row[i] for i in idxs])
                else:  # string
                    b = row[payload].encode("utf-8", "surrogatepass")
                    w.pack_into(_LEN, len(b))
                    w.write(b)
        return w.detach()

    def decode_block(self, data, schema: Schema,
                     arena: Optional[DecodeArena] = None) -> ColumnBlock:
        (nrows,) = struct.unpack_from("<I", data, 0)
        off = 4
        plan = _pack_plan(schema)
        ncols = len(schema)
        cols: List[list] = [[] for _ in range(ncols)]
        for _ in range(nrows):
            for kind, payload in plan:
                if kind == "fixed":
                    st, idxs = payload
                    vals = st.unpack_from(data, off)
                    off += st.size
                    for i, v in zip(idxs, vals):
                        cols[i].append(v)
                else:
                    (ln,) = struct.unpack_from("<I", data, off)
                    off += 4
                    cols[payload].append(
                        tobytes(data[off : off + ln]).decode(
                            "utf-8", "surrogatepass")
                    )
                    off += ln
        arrays = []
        for f, c in zip(schema, cols):
            if f.type is ColType.STRING:
                arrays.append(c)
            elif arena is not None:
                arrays.append(arena.take(f.type.np_dtype, nrows, c))
            else:
                arrays.append(np.asarray(c, dtype=f.type.np_dtype))
        return ColumnBlock(schema, arrays)


def _pack_plan(schema: Schema):
    """Group consecutive fixed-width fields into one struct.Struct."""
    plan = []
    fmt = "<"
    idxs: List[int] = []
    for i, f in enumerate(schema):
        if f.type.is_fixed_width:
            fmt += _FIXED_FMT[f.type]
            idxs.append(i)
        else:
            if idxs:
                plan.append(("fixed", (struct.Struct(fmt), tuple(idxs))))
                fmt, idxs = "<", []
            plan.append(("string", i))
    if idxs:
        plan.append(("fixed", (struct.Struct(fmt), tuple(idxs))))
    return plan
