"""Arrow-analog wire formats (paper sections 5.4 and 7.3).

``arrowcol`` -- columnar: each fixed-width column is one contiguous
little-endian buffer (a single memcpy from the numpy array); string columns
are an int32 offsets vector plus a utf8 heap.  This is PipeGen's default
wire format and the fastest in the paper's comparison.

``arrowrow`` -- the row-oriented counterpart: the same typed buffers but
interleaved row-major via a numpy structured array.  Still vectorized, but
the per-column strided gathers on decode make it modestly slower than
columnar, reproducing the paper's observation.

Block layout (arrowcol):
    nrows: uint32
    per column, in schema order:
      fixed-width: raw buffer (nrows * width bytes)
      string:      offsets int32[nrows + 1], then heap bytes (offsets[-1])
"""

from __future__ import annotations

import struct
from typing import List

import numpy as np

from ..types import ColType, ColumnBlock, Schema
from .base import WireFormat, register_wire_format


@register_wire_format
class ArrowColFormat(WireFormat):
    name = "arrowcol"

    def __init__(self, buffer_rows: int = 65536):
        # preallocated per-column ArrowBuf size, paper fig. 14
        self.buffer_rows = buffer_rows

    def encode_block(self, block: ColumnBlock) -> bytes:
        n = len(block)
        out: List[bytes] = [struct.pack("<I", n)]
        for f, col in zip(block.schema, block.columns):
            if f.type is ColType.STRING:
                heap = "".join(col).encode("utf-8", "surrogatepass")
                lens = np.fromiter(
                    (len(s.encode("utf-8", "surrogatepass")) for s in col),
                    dtype=np.int32,
                    count=n,
                )
                # fast path: pure-ascii heap lets us avoid re-encoding each
                # string for its length
                if len(heap) == sum(len(s) for s in col):
                    lens = np.fromiter((len(s) for s in col), np.int32, count=n)
                offsets = np.zeros(n + 1, dtype=np.int32)
                np.cumsum(lens, out=offsets[1:])
                out.append(offsets.tobytes())
                out.append(heap)
            else:
                a = np.ascontiguousarray(col, dtype=f.type.np_dtype)
                out.append(a.tobytes())
        return b"".join(out)

    def decode_block(self, data: bytes, schema: Schema) -> ColumnBlock:
        (n,) = struct.unpack_from("<I", data, 0)
        off = 4
        cols: List = []
        for f in schema:
            if f.type is ColType.STRING:
                offsets = np.frombuffer(data, np.int32, n + 1, off)
                off += offsets.nbytes
                heap_len = int(offsets[-1]) if n else 0
                heap = data[off : off + heap_len]
                off += heap_len
                text = heap.decode("utf-8", "surrogatepass")
                if len(text) == heap_len:  # ascii: offsets == char offsets
                    cols.append(
                        [text[offsets[i] : offsets[i + 1]] for i in range(n)]
                    )
                else:
                    cols.append(
                        [
                            heap[offsets[i] : offsets[i + 1]].decode(
                                "utf-8", "surrogatepass"
                            )
                            for i in range(n)
                        ]
                    )
            else:
                width = f.type.width
                a = np.frombuffer(data, f.type.np_dtype, n, off).copy()
                off += n * width
                cols.append(a)
        return ColumnBlock(schema, cols)


@register_wire_format
class ArrowRowFormat(WireFormat):
    """Row-oriented Arrow analog: typed buffers interleaved row-major."""

    name = "arrowrow"

    def encode_block(self, block: ColumnBlock) -> bytes:
        n = len(block)
        fixed = [
            (i, f) for i, f in enumerate(block.schema) if f.type.is_fixed_width
        ]
        strings = [
            (i, f) for i, f in enumerate(block.schema) if not f.type.is_fixed_width
        ]
        out: List[bytes] = [struct.pack("<I", n)]
        if fixed:
            dt = np.dtype(
                [(f"f{i}", f.type.np_dtype.newbyteorder("<")) for i, f in fixed]
            )
            rec = np.empty(n, dtype=dt)
            for (i, f) in fixed:
                rec[f"f{i}"] = block.columns[i]
            out.append(rec.tobytes())
        for i, f in strings:
            col = block.columns[i]
            heap = "".join(col).encode("utf-8", "surrogatepass")
            lens = np.fromiter(
                (len(s.encode("utf-8", "surrogatepass")) for s in col),
                dtype=np.int32,
                count=n,
            )
            offsets = np.zeros(n + 1, dtype=np.int32)
            np.cumsum(lens, out=offsets[1:])
            out.append(offsets.tobytes())
            out.append(heap)
        return b"".join(out)

    def decode_block(self, data: bytes, schema: Schema) -> ColumnBlock:
        (n,) = struct.unpack_from("<I", data, 0)
        off = 4
        fixed = [(i, f) for i, f in enumerate(schema) if f.type.is_fixed_width]
        strings = [(i, f) for i, f in enumerate(schema) if not f.type.is_fixed_width]
        cols: List = [None] * len(schema)
        if fixed:
            dt = np.dtype(
                [(f"f{i}", f.type.np_dtype.newbyteorder("<")) for i, f in fixed]
            )
            rec = np.frombuffer(data, dt, n, off)
            off += dt.itemsize * n
            for (i, f) in fixed:
                cols[i] = np.ascontiguousarray(rec[f"f{i}"])  # strided gather
        for i, f in strings:
            offsets = np.frombuffer(data, np.int32, n + 1, off)
            off += offsets.nbytes
            heap_len = int(offsets[-1]) if n else 0
            heap = data[off : off + heap_len]
            off += heap_len
            cols[i] = [
                heap[offsets[k] : offsets[k + 1]].decode("utf-8", "surrogatepass")
                for k in range(n)
            ]
        return ColumnBlock(schema, cols)
