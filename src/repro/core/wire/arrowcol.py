"""Arrow-analog wire formats (paper sections 5.4 and 7.3).

``arrowcol`` -- columnar: each fixed-width column is one contiguous
little-endian buffer; string columns are an int32 offsets vector plus a
utf8 heap.  This is PipeGen's default wire format and the fastest in the
paper's comparison.

Zero-copy encode: fixed-width columns go on the wire as *views* of the
live numpy buffers (no ``tobytes`` copy); string offsets are computed
directly into a pooled store.  The encoded block is a
:class:`~repro.core.iobuf.SegmentList` the transport scatter-gathers with
one vectored syscall.

``arrowrow`` -- the row-oriented counterpart: the same typed buffers but
interleaved row-major via a numpy structured array.  Still vectorized, but
the per-column strided gathers on decode make it modestly slower than
columnar, reproducing the paper's observation.

Block layout (arrowcol):
    nrows: uint32
    per column, in schema order:
      fixed-width: raw buffer (nrows * width bytes)
      string:      offsets int32[nrows + 1], then heap bytes (offsets[-1])
"""

from __future__ import annotations

import struct
from typing import List, Optional

import numpy as np

from ..iobuf import BufferPool, DecodeArena, SegmentList, default_pool
from ..types import ColType, ColumnBlock, Schema
from .base import WireFormat, register_wire_format, tobytes


#: string columns ship each encoded part as its own scatter-gather
#: segment (no heap materialization at all) while the whole *frame* stays
#: under this many segments; past the budget — long columns, or wide
#: blocks of many string columns — the per-part iovec bookkeeping would
#: outweigh one gather, so the parts go into a single pooled store
_STRING_SEG_CAP = 1024


def _encode_string_col(col, n: int, pool: BufferPool, out: SegmentList) -> None:
    """Append offsets + heap segments for one string column.

    Single pass: each string is encoded exactly once; lengths fall out of
    the encoded parts (no second length-scan, no ascii re-check).  Offsets
    are cumsummed straight into a pooled int32 store.  The heap never
    re-materializes through ``b"".join`` (the seed path's second full copy
    of every string column): short columns ship the encoded parts as
    individual segments — the transport's vectored send walks them — and
    long columns gather them into one pooled store, so steady-state string
    traffic allocates no fresh heap either way.
    """
    bparts: List[bytes] = [s.encode("utf-8", "surrogatepass") for s in col]
    off_buf = pool.acquire(4 * (n + 1))
    offsets = np.frombuffer(off_buf.store, np.int32, n + 1)
    offsets[0] = 0
    heap_len = 0
    if n:
        lens = np.fromiter(map(len, bparts), np.int32, count=n)
        np.cumsum(lens, out=offsets[1:])
        heap_len = int(offsets[n])
    out.append_pooled(off_buf)
    if n + len(out.segments) <= _STRING_SEG_CAP:  # per-FRAME budget
        for b in bparts:
            if b:
                out.append(b)
        out.copies_avoided += 1  # the joined-heap copy never happened
        return
    heap_buf = pool.acquire(heap_len)
    store = heap_buf.store
    pos = 0
    for b in bparts:
        ln = len(b)
        store[pos:pos + ln] = b
        pos += ln
    out.append_pooled(heap_buf)


def _fixed_col_view(col, dtype: np.dtype, out: SegmentList) -> None:
    """Append a fixed-width column as a view of its live buffer when the
    engine already holds it in wire layout (the common case)."""
    a = np.ascontiguousarray(col, dtype=dtype)
    out.append(a.data, zero_copy=a is col)


@register_wire_format
class ArrowColFormat(WireFormat):
    name = "arrowcol"

    def __init__(self, buffer_rows: int = 65536):
        # preallocated per-column ArrowBuf size, paper fig. 14
        self.buffer_rows = buffer_rows

    def encode_block(
        self, block: ColumnBlock, pool: Optional[BufferPool] = None
    ) -> SegmentList:
        pool = pool or default_pool()
        n = len(block)
        out = SegmentList([struct.pack("<I", n)])
        for f, col in zip(block.schema, block.columns):
            if f.type is ColType.STRING:
                _encode_string_col(col, n, pool, out)
            else:
                _fixed_col_view(col, f.type.np_dtype, out)
        return out

    def decode_block(self, data, schema: Schema,
                     arena: Optional[DecodeArena] = None) -> ColumnBlock:
        (n,) = struct.unpack_from("<I", data, 0)
        off = 4
        cols: List = []
        for f in schema:
            if f.type is ColType.STRING:
                offsets = np.frombuffer(data, np.int32, n + 1, off)
                off += offsets.nbytes
                heap_len = int(offsets[-1]) if n else 0
                heap = tobytes(data[off : off + heap_len])
                off += heap_len
                text = heap.decode("utf-8", "surrogatepass")
                if len(text) == heap_len:  # ascii: offsets == char offsets
                    cols.append(
                        [text[offsets[i] : offsets[i + 1]] for i in range(n)]
                    )
                else:
                    cols.append(
                        [
                            heap[offsets[i] : offsets[i + 1]].decode(
                                "utf-8", "surrogatepass"
                            )
                            for i in range(n)
                        ]
                    )
            else:
                width = f.type.width
                src = np.frombuffer(data, f.type.np_dtype, n, off)
                off += n * width
                cols.append(arena.take(f.type.np_dtype, n, src) if arena
                            else src.copy())
        return ColumnBlock(schema, cols)


@register_wire_format
class ArrowRowFormat(WireFormat):
    """Row-oriented Arrow analog: typed buffers interleaved row-major."""

    name = "arrowrow"

    def encode_block(
        self, block: ColumnBlock, pool: Optional[BufferPool] = None
    ) -> SegmentList:
        pool = pool or default_pool()
        n = len(block)
        fixed = [
            (i, f) for i, f in enumerate(block.schema) if f.type.is_fixed_width
        ]
        strings = [
            (i, f) for i, f in enumerate(block.schema) if not f.type.is_fixed_width
        ]
        out = SegmentList([struct.pack("<I", n)])
        if fixed:
            dt = np.dtype(
                [(f"f{i}", f.type.np_dtype.newbyteorder("<")) for i, f in fixed]
            )
            rec = np.empty(n, dtype=dt)
            for (i, f) in fixed:
                rec[f"f{i}"] = block.columns[i]
            # the gather into rec is the only copy; the record buffer itself
            # goes out as a view
            out.append(rec.data, zero_copy=True)
        for i, f in strings:
            _encode_string_col(block.columns[i], n, pool, out)
        return out

    def decode_block(self, data, schema: Schema,
                     arena: Optional[DecodeArena] = None) -> ColumnBlock:
        (n,) = struct.unpack_from("<I", data, 0)
        off = 4
        fixed = [(i, f) for i, f in enumerate(schema) if f.type.is_fixed_width]
        strings = [(i, f) for i, f in enumerate(schema) if not f.type.is_fixed_width]
        cols: List = [None] * len(schema)
        if fixed:
            dt = np.dtype(
                [(f"f{i}", f.type.np_dtype.newbyteorder("<")) for i, f in fixed]
            )
            rec = np.frombuffer(data, dt, n, off)
            off += dt.itemsize * n
            for (i, f) in fixed:
                # strided gather out of the wire view, into a pooled store
                # when an arena is supplied.  Without one, .copy() (never
                # ascontiguousarray, which is a no-op view for a
                # single-field record) so the column cannot alias a
                # transport span that is recycled after this returns.
                cols[i] = (arena.take(f.type.np_dtype, n, rec[f"f{i}"])
                           if arena else rec[f"f{i}"].copy())
        for i, f in strings:
            offsets = np.frombuffer(data, np.int32, n + 1, off)
            off += offsets.nbytes
            heap_len = int(offsets[-1]) if n else 0
            heap = tobytes(data[off : off + heap_len])
            off += heap_len
            cols[i] = [
                heap[offsets[k] : offsets[k + 1]].decode("utf-8", "surrogatepass")
                for k in range(n)
            ]
        return ColumnBlock(schema, cols)
