"""Protobuf-analog tag/length/value format (paper fig. 13's 'protocol
buffers', in static and dynamic template flavors).

Encoding per message (= row): for each field, a tag byte
``(field_number << 3) | wire_type`` followed by the value:
  wire_type 0: varint (ints, bools, zigzag for negatives)
  wire_type 1: fixed64 (doubles)
  wire_type 2: length-delimited (strings; varint length + utf8)

``static=True`` precompiles the per-row pack plan from the schema (compile
time message templates); ``static=False`` re-derives the plan from each
value's runtime type (dynamic templates), which is measurably slower --
matching the paper's observation.
"""

from __future__ import annotations

import struct
from typing import List, Optional

import numpy as np

from ..iobuf import BufferPool, BufWriter, DecodeArena, SegmentList
from ..types import ColType, ColumnBlock, Schema
from .base import WireFormat, register_wire_format, tobytes


def _zigzag(n: int) -> int:
    return (n << 1) ^ (n >> 63)


def _unzigzag(n: int) -> int:
    return (n >> 1) ^ -(n & 1)


def _varint(n: int) -> bytes:
    out = bytearray()
    while True:
        b = n & 0x7F
        n >>= 7
        if n:
            out.append(b | 0x80)
        else:
            out.append(b)
            return bytes(out)


def _read_varint(data: bytes, off: int) -> tuple:
    shift = 0
    result = 0
    while True:
        b = data[off]
        off += 1
        result |= (b & 0x7F) << shift
        if not b & 0x80:
            return result, off
        shift += 7


@register_wire_format
class TaggedFormat(WireFormat):
    name = "tagged"

    def __init__(self, static: bool = True):
        self.static = static

    def encode_block(
        self, block: ColumnBlock, pool: Optional[BufferPool] = None
    ) -> SegmentList:
        rb = block.to_rows()
        w = BufWriter(pool, size_hint=4 + len(rb) * (block.schema.fixed_row_width + 8))
        w.write(struct.pack("<I", len(rb)))
        if self.static:
            plan = self._static_plan(block.schema)
            for row in rb.rows:
                msg = b"".join(enc(v) for enc, v in zip(plan, row))
                w.write(_varint(len(msg)))
                w.write(msg)
        else:
            for row in rb.rows:
                msg_parts = []
                for i, v in enumerate(row):
                    msg_parts.append(self._dynamic_encode(i, v))
                msg = b"".join(msg_parts)
                w.write(_varint(len(msg)))
                w.write(msg)
        return w.detach()

    @staticmethod
    def _static_plan(schema: Schema):
        plan = []
        for i, f in enumerate(schema):
            tag_v = bytes([(i + 1) << 3 | 0])
            tag_f = bytes([(i + 1) << 3 | 1])
            tag_l = bytes([(i + 1) << 3 | 2])
            if f.type in (ColType.INT32, ColType.INT64):
                plan.append(lambda v, t=tag_v: t + _varint(_zigzag(int(v))))
            elif f.type is ColType.BOOL:
                plan.append(lambda v, t=tag_v: t + _varint(int(v)))
            elif f.type in (ColType.FLOAT32, ColType.FLOAT64):
                plan.append(lambda v, t=tag_f: t + struct.pack("<d", v))
            else:
                plan.append(
                    lambda v, t=tag_l: (
                        lambda b: t + _varint(len(b)) + b
                    )(v.encode("utf-8", "surrogatepass"))
                )
        return plan

    @staticmethod
    def _dynamic_encode(i: int, v) -> bytes:
        # dynamic template: inspect the runtime type of every value
        if isinstance(v, bool):
            return bytes([(i + 1) << 3 | 0]) + _varint(int(v))
        if isinstance(v, (int, np.integer)):
            return bytes([(i + 1) << 3 | 0]) + _varint(_zigzag(int(v)))
        if isinstance(v, (float, np.floating)):
            return bytes([(i + 1) << 3 | 1]) + struct.pack("<d", float(v))
        b = str(v).encode("utf-8", "surrogatepass")
        return bytes([(i + 1) << 3 | 2]) + _varint(len(b)) + b

    def decode_block(self, data, schema: Schema,
                     arena: Optional[DecodeArena] = None) -> ColumnBlock:
        (nrows,) = struct.unpack_from("<I", data, 0)
        off = 4
        ncols = len(schema)
        cols: List[list] = [[] for _ in range(ncols)]
        types = schema.types
        for _ in range(nrows):
            msg_len, off = _read_varint(data, off)
            end = off + msg_len
            while off < end:
                tag = data[off]
                off += 1
                field = (tag >> 3) - 1
                wt = tag & 7
                if wt == 0:
                    raw, off = _read_varint(data, off)
                    if types[field] is ColType.BOOL:
                        cols[field].append(bool(raw))
                    else:
                        cols[field].append(_unzigzag(raw))
                elif wt == 1:
                    (v,) = struct.unpack_from("<d", data, off)
                    off += 8
                    cols[field].append(v)
                else:
                    ln, off = _read_varint(data, off)
                    cols[field].append(
                        tobytes(data[off : off + ln]).decode(
                            "utf-8", "surrogatepass")
                    )
                    off += ln
        arrays = []
        for f, c in zip(schema, cols):
            if f.type is ColType.STRING:
                arrays.append(c)
            elif arena is not None:
                arrays.append(arena.take(f.type.np_dtype, nrows, c))
            else:
                arrays.append(np.asarray(c, dtype=f.type.np_dtype))
        return ColumnBlock(schema, arrays)
