"""Telemetry: span tracer, metrics registry, and flight recorder.

Three small, dependency-free facilities shared by the whole fabric:

* **Span tracer** — ``with span("export.encode"):`` contexts on the
  monotonic clock.  Disabled by default and *near-free* when disabled
  (one global ``is None`` test, the same idiom as ``faults.fire``).
  Trace context is a ``"<trace_id>:<span_id>"`` string that travels
  across processes through the pipe schema hello and the directory
  registration, so the exporter and importer of one edge land in a
  single trace.  Finished spans export as Chrome-trace / Perfetto JSON
  (``chrome://tracing`` or https://ui.perfetto.dev).

* **Metrics registry** — labeled counters, gauges, and fixed-bucket
  histograms.  Always on (a counter bump is a dict lookup + add); the
  broker, transports, pools, and the stats sink publish here and the
  broker ``stats`` RPC snapshots it for ``repro.tools.pipetop``.

* **Flight recorder** — a bounded per-pipe ring of recent events
  (frames, retries, lease renewals, faults).  When a transport/lease/
  admission error is raised, :func:`attach_flight` staples the recent
  timeline onto the exception so seeded-fault failures arrive with a
  causal history instead of a bare traceback.

This module must not import anything else from ``repro.core`` — every
other core module imports *it*.
"""
from __future__ import annotations

import bisect
import json
import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Span", "Tracer", "enable_tracing", "disable_tracing",
    "tracing_enabled", "tracer", "span", "current_ctx", "trace_context",
    "new_trace_ctx", "new_span_id", "split_ctx", "chrome_trace",
    "dump_chrome_trace",
    "merge_trace_dir",
    "MetricsRegistry", "registry", "counter", "gauge", "histogram",
    "FlightRecorder", "attach_flight", "fault_recorder",
]

_now = time.monotonic  # CLOCK_MONOTONIC: system-wide on Linux, so
                       # cross-process span timestamps share one axis.


def _new_id() -> str:
    return os.urandom(8).hex()


def new_span_id() -> str:
    """A fresh 16-hex span id (for callers that pre-allocate ids so a
    propagated context can name a span recorded later)."""
    return _new_id()


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

class Span:
    """One finished span.  Immutable-by-convention; ``__slots__`` keeps
    the per-span cost to one small object."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id",
                 "t0", "t1", "pid", "tid", "attrs")

    def __init__(self, name: str, trace_id: str, span_id: str,
                 parent_id: str, t0: float, t1: float,
                 pid: int, tid: int, attrs: Optional[Dict[str, Any]]):
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = t0
        self.t1 = t1
        self.pid = pid
        self.tid = tid
        self.attrs = attrs

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def to_event(self) -> Dict[str, Any]:
        """Chrome-trace complete ('X') event, microsecond clock."""
        args: Dict[str, Any] = {"trace_id": self.trace_id,
                                "span_id": self.span_id}
        if self.parent_id:
            args["parent_id"] = self.parent_id
        if self.attrs:
            args.update(self.attrs)
        return {"name": self.name, "ph": "X", "cat": "pipegen",
                "ts": self.t0 * 1e6, "dur": (self.t1 - self.t0) * 1e6,
                "pid": self.pid, "tid": self.tid, "args": args}


class Tracer:
    """Collects finished spans into a bounded ring."""

    def __init__(self, capacity: int = 8192):
        self.capacity = capacity
        self._spans: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self.dropped = 0

    def record(self, name: str, t0: float, t1: float, *,
               trace_id: str, span_id: Optional[str] = None,
               parent_id: str = "", pid: Optional[int] = None,
               tid: Optional[int] = None,
               attrs: Optional[Dict[str, Any]] = None) -> str:
        """Record an already-timed span with explicit ids.  Used by the
        pipes, which time phases themselves across worker threads and
        stitch parentage from propagated context strings."""
        sid = span_id or _new_id()
        sp = Span(name, trace_id, sid, parent_id, t0, t1,
                  pid if pid is not None else os.getpid(),
                  tid if tid is not None else threading.get_ident(),
                  attrs)
        with self._lock:
            if len(self._spans) == self._spans.maxlen:
                self.dropped += 1
            self._spans.append(sp)
        _spill(sp)
        return sid

    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self.dropped = 0


class _NullSpan:
    """The disabled-path singleton: entering/exiting is two no-op
    method calls on a preallocated object — no allocation, no clock."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> None:
        return None

    def set(self, **attrs: Any) -> None:
        return None


_NULL_SPAN = _NullSpan()


class _LiveSpan:
    __slots__ = ("name", "attrs", "trace_id", "parent_id",
                 "span_id", "t0")

    def __init__(self, name: str, attrs: Dict[str, Any]):
        self.name = name
        self.attrs = attrs or None
        self.span_id = _new_id()
        self.t0 = 0.0
        self.trace_id = ""
        self.parent_id = ""

    def set(self, **attrs: Any) -> None:
        if self.attrs is None:
            self.attrs = {}
        self.attrs.update(attrs)

    def __enter__(self) -> "_LiveSpan":
        stack = _ctx_stack()
        if stack:
            self.trace_id, self.parent_id = stack[-1]
        else:
            self.trace_id, self.parent_id = _new_id(), ""
        stack.append((self.trace_id, self.span_id))
        self.t0 = _now()
        return self

    def __exit__(self, *exc: Any) -> None:
        t1 = _now()
        stack = _ctx_stack()
        if stack and stack[-1][1] == self.span_id:
            stack.pop()
        tr = _TRACER
        if tr is not None:
            tr.record(self.name, self.t0, t1, trace_id=self.trace_id,
                      span_id=self.span_id, parent_id=self.parent_id,
                      attrs=self.attrs)


_TRACER: Optional[Tracer] = None
_local = threading.local()


def _ctx_stack() -> List[Tuple[str, str]]:
    st = getattr(_local, "stack", None)
    if st is None:
        st = _local.stack = []
    return st


def enable_tracing(capacity: int = 8192) -> Tracer:
    """Turn the tracer on process-wide; returns the (new) Tracer."""
    global _TRACER
    _TRACER = Tracer(capacity)
    return _TRACER


def disable_tracing() -> None:
    global _TRACER
    _TRACER = None


def tracing_enabled() -> bool:
    return _TRACER is not None


def tracer() -> Optional[Tracer]:
    return _TRACER


def span(name: str, **attrs: Any):
    """Open a span.  When tracing is disabled this returns a shared
    no-op singleton — the fast path is one global load + ``is None``."""
    if _TRACER is None:
        return _NULL_SPAN
    return _LiveSpan(name, attrs)


def current_ctx() -> str:
    """The propagatable ``"trace_id:span_id"`` for this thread, or ``""``."""
    if _TRACER is None:
        return ""
    st = getattr(_local, "stack", None)
    if not st:
        return ""
    tid, sid = st[-1]
    return f"{tid}:{sid}"


def new_trace_ctx() -> str:
    """A fresh root context (new trace id, synthetic root span id)."""
    return f"{_new_id()}:{_new_id()}"


def split_ctx(ctx: str) -> Tuple[str, str]:
    """``"trace:span"`` -> ``(trace_id, parent_span_id)``; tolerant of
    junk (returns fresh ids so a corrupt hello never breaks a pipe)."""
    if ctx and ":" in ctx:
        tid, _, sid = ctx.partition(":")
        if tid and sid:
            return tid, sid
    return _new_id(), ""


@contextmanager
def trace_context(ctx: str):
    """Adopt a foreign ``"trace:span"`` context on this thread, so spans
    opened inside parent under it (used by plan worker threads, which do
    not inherit the spawning thread's stack)."""
    if _TRACER is None or not ctx:
        yield
        return
    stack = _ctx_stack()
    stack.append(split_ctx(ctx))
    try:
        yield
    finally:
        stack.pop()


# -- Chrome-trace export -----------------------------------------------------

def chrome_trace(spans: Optional[Iterable[Span]] = None) -> Dict[str, Any]:
    if spans is None:
        spans = _TRACER.spans() if _TRACER is not None else []
    return {"traceEvents": [s.to_event() for s in spans],
            "displayTimeUnit": "ms"}


def dump_chrome_trace(path: str,
                      spans: Optional[Iterable[Span]] = None) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(spans), f)
    return path


# -- cross-process spill -----------------------------------------------------
#
# PIPEGEN_TRACE=1 auto-enables the tracer at import; PIPEGEN_TRACE_DIR
# makes every process append finished spans to <dir>/spans-<pid>.jsonl,
# so a parent can merge child traces without any wiring.

_SPILL_DIR = os.environ.get("PIPEGEN_TRACE_DIR") or None
_spill_lock = threading.Lock()
_spill_fh = None


def _spill(sp: Span) -> None:
    global _spill_fh
    if _SPILL_DIR is None:
        return
    line = json.dumps({
        "name": sp.name, "trace_id": sp.trace_id, "span_id": sp.span_id,
        "parent_id": sp.parent_id, "t0": sp.t0, "t1": sp.t1,
        "pid": sp.pid, "tid": sp.tid, "attrs": sp.attrs})
    with _spill_lock:
        if _spill_fh is None:
            try:
                os.makedirs(_SPILL_DIR, exist_ok=True)
                _spill_fh = open(
                    os.path.join(_SPILL_DIR, f"spans-{os.getpid()}.jsonl"),
                    "a")
            except OSError:
                return
        _spill_fh.write(line + "\n")
        _spill_fh.flush()


def merge_trace_dir(path: str) -> List[Span]:
    """Load every ``spans-*.jsonl`` under ``path`` into Span objects."""
    out: List[Span] = []
    try:
        names = sorted(os.listdir(path))
    except OSError:
        return out
    for fn in names:
        if not (fn.startswith("spans-") and fn.endswith(".jsonl")):
            continue
        with open(os.path.join(path, fn)) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    d = json.loads(line)
                except ValueError:
                    continue
                out.append(Span(d["name"], d["trace_id"], d["span_id"],
                                d.get("parent_id", ""), d["t0"], d["t1"],
                                d.get("pid", 0), d.get("tid", 0),
                                d.get("attrs")))
    out.sort(key=lambda s: s.t0)
    return out


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted(labels.items()))


class Counter:
    __slots__ = ("name", "labels", "value", "_lock")

    def __init__(self, name: str, labels: Dict[str, str]):
        self.name = name
        self.labels = labels
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n


class Gauge:
    __slots__ = ("name", "labels", "value", "_lock")

    def __init__(self, name: str, labels: Dict[str, str]):
        self.name = name
        self.labels = labels
        self.value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        self.value = v

    def add(self, v: float) -> None:
        with self._lock:
            self.value += v


#: default latency buckets (seconds): 100us .. ~100s, x4 steps
DEFAULT_BUCKETS = (1e-4, 4e-4, 1.6e-3, 6.4e-3, 2.56e-2,
                   0.1024, 0.4096, 1.6384, 6.5536, 26.2144, 104.8576)


class Histogram:
    """Fixed-bucket histogram: counts per upper-bound plus +Inf."""

    __slots__ = ("name", "labels", "bounds", "counts",
                 "total", "sum", "_lock")

    def __init__(self, name: str, labels: Dict[str, str],
                 bounds: Tuple[float, ...] = DEFAULT_BUCKETS):
        self.name = name
        self.labels = labels
        self.bounds = tuple(bounds)
        self.counts = [0] * (len(self.bounds) + 1)  # last = +Inf
        self.total = 0
        self.sum = 0.0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self.counts[i] += 1
            self.total += 1
            self.sum += v

    def quantile(self, q: float) -> float:
        """Approximate quantile (upper bucket bound)."""
        with self._lock:
            if self.total == 0:
                return 0.0
            target = q * self.total
            seen = 0
            for i, c in enumerate(self.counts):
                seen += c
                if seen >= target:
                    return (self.bounds[i] if i < len(self.bounds)
                            else float("inf"))
        return float("inf")


class MetricsRegistry:
    """Get-or-create registry of labeled instruments."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._instruments: Dict[Tuple[str, str, Tuple], Any] = {}

    def _get(self, cls: Any, kind: str, name: str,
             labels: Dict[str, str], **kw: Any) -> Any:
        key = (kind, name, _label_key(labels))
        inst = self._instruments.get(key)
        if inst is not None:
            return inst
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = cls(name, dict(labels), **kw)
                self._instruments[key] = inst
        return inst

    def counter(self, name: str, **labels: str) -> Counter:
        return self._get(Counter, "c", name, labels)

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self._get(Gauge, "g", name, labels)

    def histogram(self, name: str,
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS,
                  **labels: str) -> Histogram:
        return self._get(Histogram, "h", name, labels, bounds=buckets)

    def snapshot(self) -> Dict[str, Any]:
        """JSON-serializable dump: {counters, gauges, histograms}."""
        out: Dict[str, Any] = {"counters": {}, "gauges": {},
                               "histograms": {}}
        with self._lock:
            items = list(self._instruments.items())
        for (kind, name, lkey), inst in items:
            label = name if not lkey else (
                name + "{" + ",".join(f"{k}={v}" for k, v in lkey) + "}")
            if kind == "c":
                out["counters"][label] = inst.value
            elif kind == "g":
                out["gauges"][label] = inst.value
            else:
                out["histograms"][label] = {
                    "total": inst.total, "sum": inst.sum,
                    "p50": inst.quantile(0.5), "p95": inst.quantile(0.95),
                    "p99": inst.quantile(0.99),
                    "buckets": dict(zip(
                        [str(b) for b in inst.bounds] + ["+Inf"],
                        inst.counts))}
        return out

    def drop(self, name: str, kind: str = "g", **labels: str) -> None:
        """Remove one instrument (e.g. a closed subscription's lag gauge)
        so snapshots stop reporting a stale last value."""
        with self._lock:
            self._instruments.pop((kind, name, _label_key(labels)), None)

    def reset(self) -> None:
        with self._lock:
            self._instruments.clear()


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    return _REGISTRY


def counter(name: str, **labels: str) -> Counter:
    return _REGISTRY.counter(name, **labels)


def gauge(name: str, **labels: str) -> Gauge:
    return _REGISTRY.gauge(name, **labels)


def histogram(name: str, buckets: Tuple[float, ...] = DEFAULT_BUCKETS,
              **labels: str) -> Histogram:
    return _REGISTRY.histogram(name, buckets, **labels)


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

class FlightRecorder:
    """Bounded ring of recent events for one pipe/edge.  Cheap enough to
    leave always on: a note is one tuple append under a lock."""

    def __init__(self, depth: int = 64, name: str = ""):
        self.name = name
        self._ring: deque = deque(maxlen=max(4, depth))
        self._lock = threading.Lock()

    def note(self, event: str, **kv: Any) -> None:
        with self._lock:
            self._ring.append((_now(), event, kv or None))

    def events(self) -> List[Tuple[float, str, Optional[Dict[str, Any]]]]:
        with self._lock:
            return list(self._ring)

    def __len__(self) -> int:
        return len(self._ring)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def render(self) -> str:
        evs = self.events()
        if not evs:
            return "(flight recorder empty)"
        t_end = evs[-1][0]
        lines = []
        if self.name:
            lines.append(f"flight recorder [{self.name}]:")
        else:
            lines.append("flight recorder:")
        for t, event, kv in evs:
            extra = ""
            if kv:
                extra = " " + " ".join(f"{k}={v!r}" for k, v in kv.items())
            lines.append(f"  t-{t_end - t:8.3f}s  {event}{extra}")
        return "\n".join(lines)


#: process-wide recorder the fault harness notes matched rules into,
#: so injected faults always appear in attached timelines.
fault_recorder = FlightRecorder(depth=128, name="faults")


def attach_flight(exc: BaseException,
                  *recorders: Optional[FlightRecorder]) -> BaseException:
    """Staple recent flight-recorder timelines onto ``exc``:

    * sets ``exc.flight_timeline`` (rendered text) — idempotent;
    * appends the timeline to the exception message so it shows up in
      a bare traceback (Python 3.10-safe: no ``add_note``);
    * if ``PIPEGEN_FLIGHT_DUMP`` names a file, appends the timeline
      there so CI can assert a dump was produced.
    """
    if getattr(exc, "flight_timeline", None) is not None:
        return exc
    parts = [r.render() for r in recorders
             if r is not None and len(r) > 0]
    if len(fault_recorder) > 0 and fault_recorder not in recorders:
        parts.append(fault_recorder.render())
    if not parts:
        return exc
    text = "\n".join(parts)
    try:
        exc.flight_timeline = text  # type: ignore[attr-defined]
    except Exception:
        return exc
    try:
        if exc.args and isinstance(exc.args[0], str):
            exc.args = (exc.args[0] + "\n" + text,) + exc.args[1:]
        elif not exc.args:
            exc.args = (text,)
    except Exception:
        pass
    dump = os.environ.get("PIPEGEN_FLIGHT_DUMP")
    if dump:
        try:
            with open(dump, "a") as f:
                f.write(f"=== {type(exc).__name__}: "
                        f"{exc.args[0] if exc.args else ''}\n{text}\n\n")
        except OSError:
            pass
    return exc


if os.environ.get("PIPEGEN_TRACE", "") not in ("", "0"):
    enable_tracing()
