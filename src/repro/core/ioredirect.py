"""IORedirect (paper section 4): substitute network data pipes for the file
streams an engine's import/export code opens, activated by reserved
filenames, without disturbing any other file the engine touches.

The JVM prototype rewrote bytecode at the discovered call sites.  In Python
the analogous mechanism is a *pipe-aware open*: :func:`pipegen_open` checks
the filename against the reserved template and returns a
``DataPipeOutput``/``DataPipeInput`` (wrapped to the text-file protocol) or
defers to the real ``open``.  Which call sites are *allowed* to redirect is
decided by the capture phase (:mod:`repro.core.capture`): only call sites
observed opening the import/export target during the engine's own unit
tests are registered; every other ``open`` — debug logs, config files —
passes through untouched even when handed a reserved name.
"""

from __future__ import annotations

import builtins
import inspect
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Set, Tuple

from .datapipe import (
    DataPipeInput,
    DataPipeOutput,
    PipeConfig,
    is_reserved,
)

__all__ = [
    "CallSite",
    "CallSiteRegistry",
    "pipegen_open",
    "default_registry",
    "active_pipe_config",
    "set_pipe_config",
    "PipeOpenContext",
]


@dataclass(frozen=True)
class CallSite:
    """A file-open location in engine source (module:function:line)."""

    module: str
    function: str
    lineno: int

    def __str__(self) -> str:
        return f"{self.module}:{self.function}:{self.lineno}"


@dataclass
class CallSiteRegistry:
    """Call sites allowed to redirect, per engine, as discovered by capture.

    ``allow_all`` supports the pre-capture instrumentation run and tests.
    """

    allowed: Set[CallSite] = field(default_factory=set)
    allow_all: bool = False
    observed: Dict[CallSite, Set[str]] = field(default_factory=dict)

    def allow(self, site: CallSite) -> None:
        self.allowed.add(site)

    def permits(self, site: CallSite) -> bool:
        return self.allow_all or site in self.allowed

    def record(self, site: CallSite, filename: str) -> None:
        self.observed.setdefault(site, set()).add(filename)


_default_registry = CallSiteRegistry(allow_all=True)


def default_registry() -> CallSiteRegistry:
    return _default_registry


_config_local = threading.local()


def active_pipe_config() -> PipeConfig:
    return getattr(_config_local, "config", None) or PipeConfig()


def set_pipe_config(config: Optional[PipeConfig]) -> None:
    _config_local.config = config


class PipeOpenContext:
    """``with PipeOpenContext(PipeConfig(...)):`` scopes the pipe behaviour
    (wire format, codec, link simulation) for opens on this thread."""

    def __init__(self, config: PipeConfig):
        self.config = config

    def __enter__(self):
        self._prev = getattr(_config_local, "config", None)
        set_pipe_config(self.config)
        return self

    def __exit__(self, *exc):
        set_pipe_config(self._prev)


def _caller_site(depth: int = 2) -> CallSite:
    fr = inspect.stack()[depth]
    return CallSite(fr.frame.f_globals.get("__name__", "?"), fr.function, fr.lineno)


class _PipeTextWriter:
    """Adapts DataPipeOutput to the text-file protocol engines expect;
    forwards AStrings intact (the FormOpt hand-off, fig. 5 subtyping)."""

    def __init__(self, pipe: DataPipeOutput):
        self.pipe = pipe

    def write(self, s: Any) -> int:
        return self.pipe.write(s)

    def writelines(self, lines) -> None:
        self.pipe.writelines(lines)

    def flush(self) -> None:
        self.pipe.flush()

    def close(self) -> None:
        self.pipe.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class _PipeBytesWriter:
    """Binary write adapter: a shared-binary-format export (e.g. seqfile)
    streams its bytes through the pipe unmodified (section 5's
    shared-binary-format case)."""

    def __init__(self, pipe: DataPipeOutput):
        self.pipe = pipe

    def write(self, b) -> int:
        return self.pipe.write(bytes(b))

    def flush(self) -> None:
        self.pipe.flush()

    def close(self) -> None:
        self.pipe.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class _PipeBytesReader:
    """Binary read adapter over :meth:`DataPipeInput.read_bytes`."""

    def __init__(self, pipe: DataPipeInput):
        self.pipe = pipe

    def read(self, size: int = -1) -> bytes:
        return self.pipe.read_bytes(size)

    def close(self) -> None:
        self.pipe.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def pipegen_open(
    filename: str,
    mode: str = "r",
    registry: Optional[CallSiteRegistry] = None,
    config: Optional[PipeConfig] = None,
    _site_depth: int = 2,
    real_open: Optional[Callable] = None,
    **kw,
):
    """The substituted ``open``.  Reserved name + permitted call site ->
    data pipe; anything else -> the real ``open`` (fig. 4's conditional).

    ``real_open`` is the unspliced ``open`` (the splice must pass it in;
    ``builtins.open`` may *be* the splice while a pipe context is active)."""
    registry = registry or _default_registry
    site = _caller_site(_site_depth)
    registry.record(site, str(filename))
    if is_reserved(str(filename)) and registry.permits(site):
        cfg = config or active_pipe_config()
        binary = "b" in mode
        if any(m in mode for m in ("w", "a", "x")):
            from dataclasses import replace as _replace

            if cfg.partition:
                # N→M shuffle: one writer fanning across all import workers
                if binary:
                    raise ValueError(
                        "partitioned (shuffle) pipes cannot carry opaque "
                        "binary passthrough streams")
                from .fabric import ShuffleWriter

                return _PipeTextWriter(ShuffleWriter(str(filename), config=cfg))
            if binary:
                cfg = _replace(cfg, mode="bytes")
                return _PipeBytesWriter(DataPipeOutput(str(filename), config=cfg))
            return _PipeTextWriter(DataPipeOutput(str(filename), config=cfg))
        pipe = DataPipeInput(str(filename), link=cfg.link,
                             transport=cfg.transport,
                             shm_capacity=cfg.shm_capacity,
                             shm_doorbell=cfg.shm_doorbell,
                             broadcast=cfg.broadcast,
                             arena=cfg.decode_arena,
                             streams=cfg.streams,
                             fanin=cfg.fanin,
                             stream_window=cfg.stream_window,
                             resume=cfg.resume,
                             attempt=cfg.attempt,
                             lease_s=cfg.lease_s,
                             connect_timeout=cfg.connect_timeout,
                             trace=cfg.trace,
                             trace_ctx=cfg.trace_ctx,
                             flight_depth=cfg.flight_depth,
                             recorder=cfg.recorder)
        return _PipeBytesReader(pipe) if binary else pipe
    return (real_open or builtins.open)(filename, mode, **kw)
