"""PipeGen core: the paper's contribution as a composable library.

Layers (paper section -> module):

    S4 IORedirect   datapipe, ioredirect, directory, transport
    S5 FormOpt      astring, formopt, wire/, compression
    S3 compile loop capture, codegen, verify
"""

from .astring import AString
from .capture import CaptureReport, run_capture
from .codegen import GeneratedPipe, ModificationStats, PipeEnabledEngine, generate_pipe_adapter
from .compression import CODECS, get_codec
from .datapipe import (
    DataPipeInput,
    DataPipeOutput,
    PipeConfig,
    PipeStats,
    ReservedName,
    collect_stats,
    collect_stats_by_attempt,
    is_reserved,
    open_pipe_reader,
    open_pipe_writer,
    parse_reserved,
)
from .telemetry import (
    FlightRecorder,
    MetricsRegistry,
    Tracer,
    attach_flight,
    chrome_trace,
    counter,
    disable_tracing,
    dump_chrome_trace,
    enable_tracing,
    gauge,
    histogram,
    registry,
    span,
    trace_context,
    tracing_enabled,
)
from .fabric import (
    HashPartitioner,
    Partitioner,
    RangePartitioner,
    RoundRobinPartitioner,
    ShuffleWriter,
    compute_range_bounds,
    parse_partition,
    split_block,
)
from .stream import FaninTransport, StripedReceiver, StripedSender
from .iobuf import (
    BufferPool,
    BufWriter,
    DecodeArena,
    SegmentList,
    default_decode_pool,
    default_pool,
)
from .directory import (
    DirectoryClient,
    DirectoryServer,
    Endpoint,
    LeaseRenewer,
    WorkerDirectory,
    get_directory,
    live_renewers,
    set_directory,
)
from .broker import (
    BrokerBusy,
    DoorbellHub,
    PipeBroker,
    TenantQuota,
    get_broker,
    set_broker,
)
from .formopt import DelimitedAssembler, JsonAssembler, infer_delimiter
from .ioredirect import CallSite, CallSiteRegistry, PipeOpenContext, pipegen_open
from .shm_ring import ShmRing, ShmRingTransport
from .transport import Channel, ChannelTransport, LinkSim, SocketTransport
from .types import ColType, ColumnBlock, Field, RowBlock, Schema, infer_schema
from .verify import VerificationProxy, VerificationResult, validate_generated_pipe
from .wire import WIRE_FORMATS, get_wire_format
from .session import TransferResult, adapter_for, transfer, transfer_via_files
from .plan import (
    CompiledPlan,
    EdgePlan,
    PlanError,
    PlanExecutionError,
    PlanResult,
    SubscriptionSet,
    TransferPlan,
    negotiated_config,
    plan,
)
from .subscribe import (
    EpochDelta,
    Publication,
    PublicationEnded,
    ReplayLog,
    Subscription,
    publications_snapshot,
    publish,
    subscribe,
)
