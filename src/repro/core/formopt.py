"""FormOpt -- format optimizer (paper section 5).

Consumes the stream of AString parts that a decorated serializer writes to a
data pipe and recovers *typed rows*, eliminating

* string encoding of numeric types (parts arrive pre-stringification),
* delimiters            (inferred per section 5.3.1, then dropped),
* redundant metadata    (JSON key headers transmitted once, section 5.3.2).

Two assemblers are provided:

``DelimitedAssembler``  for CSV/TSV-style formats.  The delimiter is inferred
from observed parts with the paper's heuristics: most frequent length-one
string (row terminators excluded), ties broken by (i) prefer
non-alphanumeric, (ii) prefer earlier first occurrence.

``JsonAssembler``       for JSON-ish formats written via string production.
A small state machine classifies parts into structural text / keys / values;
the first dictionary's keys become the *key header*; subsequent dictionaries
whose keys match transmit values only.  Superset keys extend the header;
disjoint keys disable the optimization for that record (both per the paper).

The inverse direction (typed rows -> text for the import side of an engine
that insists on reading characters) is implemented by ``render_delimited``
and ``render_json``.
"""

from __future__ import annotations

import json
from collections import Counter
from typing import Any, Iterable, List, Optional, Sequence

from .astring import AString, materialize_part
from .types import ColType, Field, RowBlock, Schema, schema_of_value

__all__ = [
    "infer_delimiter",
    "DelimitedAssembler",
    "JsonAssembler",
    "render_delimited",
    "render_json",
    "FormOptError",
]

ROW_TERMINATORS = ("\n", "\r\n", "\r")
_JSON_STRUCTURAL = set('{}[]:," \t\n\r')


class FormOptError(RuntimeError):
    """Raised when an assembler cannot make sense of the part stream; the
    caller reacts by disabling the optimization (paper sections 5.1/5.3.1)."""


def infer_delimiter(parts: Sequence[Any]) -> Optional[str]:
    """Paper section 5.3.1.  ``parts`` is a flat sample of AString parts.

    Counts length-one string parts (excluding row terminators); the most
    frequent is the delimiter.  Ties: prefer non-alphanumeric, then the one
    appearing earliest in the stream.  When the sample carries no length-one
    parts (a character-fed pipe, e.g. the verification proxy replaying
    spooled text), fall back to character-frequency sniffing inside the
    multi-character string parts.
    """
    first_seen: dict = {}
    counts: Counter = Counter()
    for i, p in enumerate(parts):
        if isinstance(p, str) and len(p) == 1 and p not in ROW_TERMINATORS:
            counts[p] += 1
            first_seen.setdefault(p, i)
    if not counts:
        # character-level fallback: non-alphanumeric chars in string parts
        for i, p in enumerate(parts):
            if isinstance(p, str) and len(p) > 1:
                for ch in p:
                    if (not ch.isalnum() and ch not in ROW_TERMINATORS
                            and ch not in "+-._\"'"):
                        counts[ch] += 1
                        first_seen.setdefault(ch, i)
        if not counts:
            return None
    best = max(counts.values())
    cands = [c for c, n in counts.items() if n == best]
    if len(cands) == 1:
        return cands[0]
    # tie-break (i): prefer non-alphanumeric
    non_alnum = [c for c in cands if not c.isalnum()]
    pool = non_alnum or cands
    # tie-break (ii): prefer earliest occurrence
    return min(pool, key=lambda c: first_seen[c])


def sniff_cell(s: str) -> Any:
    """Type-sniff one character cell the way the engines' file import does."""
    try:
        return int(s)
    except ValueError:
        pass
    try:
        return float(s)
    except ValueError:
        pass
    if s.lower() in ("true", "false"):
        return s.lower() == "true"
    return s


def _typed(v: Any) -> Any:
    """Normalize a cell to a wire-typed value."""
    if isinstance(v, AString):
        v = v.sole_value
    return v


class DelimitedAssembler:
    """Recovers typed rows from decorated delimited-text production."""

    def __init__(self, sample_rows: int = 16):
        self.sample_rows = sample_rows
        self.delimiter: Optional[str] = None
        self._sample_parts: List[Any] = []
        self._sampling = True
        self._pending: List[Any] = []  # parts of the current (unfinished) row
        self._sample_row_parts: List[List[Any]] = []
        self.rows: List[tuple] = []
        self.schema: Optional[Schema] = None
        self.header_names: Optional[tuple] = None
        self.expects_header = False

    # -- ingestion -------------------------------------------------------------
    def write(self, s: Any) -> None:
        parts = s.parts if isinstance(s, AString) else (s,)
        # fast path: one complete row per write (the fig. 8 serializer shape
        # — value/delimiter parts with a trailing newline), delimiter known
        if (
            not self._sampling
            and not self._pending
            and parts
            and parts[-1] == "\n"
        ):
            d = self.delimiter
            row: List[Any] = []
            cur: List[Any] = []
            for p in parts[:-1]:
                if isinstance(p, str) and p == d:
                    row.append(self._cell(cur))
                    cur = []
                elif isinstance(p, str) and "\n" in p:
                    break  # multi-row part: fall back to the general path
                else:
                    cur.append(p)
            else:
                row.append(self._cell(cur))
                self.rows.append(tuple(row))
                return
        for p in parts:
            self._push(p)

    def _push(self, p: Any) -> None:
        if isinstance(p, str) and p in ROW_TERMINATORS:
            self._end_row()
        elif isinstance(p, str) and p.endswith("\n") and len(p) > 1:
            # writers that append '\n' to the last cell's text
            head = p[:-1]
            if head:
                self._pending.append(head)
            self._end_row()
        else:
            self._pending.append(p)

    def _end_row(self) -> None:
        if self._sampling:
            self._sample_row_parts.append(self._pending)
            self._sample_parts.extend(self._pending)
            self._pending = []
            if len(self._sample_row_parts) >= self.sample_rows:
                self._finish_sampling()
        else:
            self.rows.append(self._assemble(self._pending))
            self._pending = []

    def _finish_sampling(self) -> None:
        self.delimiter = infer_delimiter(self._sample_parts)
        self._sampling = False
        for row_parts in self._sample_row_parts:
            self.rows.append(self._assemble(row_parts))
        self._sample_row_parts = []
        self._sample_parts = []

    def _assemble(self, parts: List[Any]) -> tuple:
        d = self.delimiter
        # character row: one string part with embedded delimiters (a pipe
        # fed raw text); split characters and sniff types like file import
        if (
            d is not None
            and len(parts) == 1
            and isinstance(parts[0], str)
            and d in parts[0]
        ):
            self._char_rows = True
            return tuple(sniff_cell(c) for c in parts[0].split(d))
        cells: List[Any] = []
        cur: List[Any] = []
        for p in parts:
            if isinstance(p, str) and p == d:
                cells.append(self._cell(cur))
                cur = []
            else:
                cur.append(p)
        cells.append(self._cell(cur))
        return tuple(cells)

    @staticmethod
    def _cell(parts: List[Any]) -> Any:
        if len(parts) > 1:
            # empty literals (serializers seed lines with lit("")) carry no
            # characters; dropping them preserves the typed single value
            parts = [p for p in parts if p != ""]
        if len(parts) == 1:
            return _typed(parts[0])
        if not parts:
            return ""
        return "".join(materialize_part(p) for p in parts)

    # -- extraction -------------------------------------------------------------
    def flush(self) -> None:
        if self._sampling:
            self._finish_sampling()
        if self._pending:
            self.rows.append(self._assemble(self._pending))
            self._pending = []

    def take_rows(self) -> RowBlock:
        self._ensure_schema()
        rows, self.rows = self.rows, []
        rows = [self._coerce(r) for r in rows]
        return RowBlock(self.schema, rows)

    def _ensure_schema(self) -> None:
        if self.schema is not None or not self.rows:
            return
        first = self.rows[0]
        # Header detection: an all-string first row over otherwise-typed data
        if (
            len(self.rows) > 1
            and all(isinstance(v, str) for v in first)
            and any(not isinstance(v, str) for v in self.rows[1])
        ):
            self.header_names = tuple(first)
            self.rows = self.rows[1:]
            first = self.rows[0]
        try:
            self.schema = Schema(
                [
                    Field(
                        self.header_names[i] if self.header_names else f"column{i+1}",
                        schema_of_value(v),
                    )
                    for i, v in enumerate(first)
                ]
            )
        except TypeError as e:  # pragma: no cover - defensive
            raise FormOptError(str(e)) from e

    def _coerce(self, row: tuple) -> tuple:
        if len(row) != len(self.schema):
            raise FormOptError(
                f"row arity {len(row)} != schema arity {len(self.schema)}; "
                f"likely mis-inferred delimiter {self.delimiter!r}"
            )
        out = []
        for v, f in zip(row, self.schema):
            t = f.type
            if t is ColType.STRING:
                out.append(v if isinstance(v, str) else materialize_part(v))
            elif t in (ColType.INT32, ColType.INT64):
                out.append(int(v) if not isinstance(v, bool) else int(v))
            elif t in (ColType.FLOAT32, ColType.FLOAT64):
                out.append(float(v))
            elif t is ColType.BOOL:
                out.append(v if isinstance(v, bool) else str(v).lower() == "true")
            else:  # pragma: no cover
                out.append(v)
        return tuple(out)


class JsonAssembler:
    """Recovers typed dict-rows from decorated JSON production and applies
    redundant-metadata removal (section 5.3.2)."""

    def __init__(self):
        self.key_header: Optional[List[str]] = None
        self.rows: List[dict] = []
        self.raw_rows: List[dict] = []  # rows with per-row keys (opt disabled)
        self._parts: List[Any] = []

    def write(self, s: Any) -> None:
        parts = s.parts if isinstance(s, AString) else (s,)
        self._parts.extend(parts)

    @staticmethod
    def _is_structural(p: Any) -> bool:
        return isinstance(p, str) and p != "" and all(c in _JSON_STRUCTURAL for c in p)

    def flush(self) -> None:
        """Parse accumulated parts into dict rows via a part-level state
        machine (state: expecting key vs value inside the current dict).
        A trailing *incomplete* document is retained for the next flush so
        block-sized incremental flushing works mid-stream."""
        parts = self._parts
        self._parts = []
        depth = 0
        expecting_key = False
        pending_key: Optional[str] = None
        cur: Optional[dict] = None
        last_complete = 0  # index just past the last fully-emitted document
        i = 0
        while i < len(parts):
            p = parts[i]
            if self._is_structural(p):
                for ch in p:
                    if ch == "{":
                        depth += 1
                        if depth == 1:
                            cur = {}
                            expecting_key = True
                    elif ch == "}":
                        depth -= 1
                        if depth == 0 and cur is not None:
                            self._emit(cur)
                            cur = None
                            last_complete = i + 1
                    elif ch == ":":
                        expecting_key = False
                    elif ch == ",":
                        if depth == 1:
                            expecting_key = True
                i += 1
                continue
            # a data part (typed primitive or free-form string)
            if cur is None:
                raise FormOptError("JSON value outside any dictionary")
            if expecting_key:
                if not isinstance(p, str):
                    raise FormOptError(f"non-string JSON key: {p!r}")
                pending_key = p
                expecting_key = False
            else:
                if pending_key is None:
                    raise FormOptError("JSON value with no key")
                cur[pending_key] = _typed(p)
                pending_key = None
            i += 1
        if depth != 0:
            # keep the unfinished tail for the next flush
            self._parts = list(parts[last_complete:])

    def _emit(self, d: dict) -> None:
        keys = list(d.keys())
        if self.key_header is None:
            self.key_header = keys
            self.rows.append(d)
            return
        kh = self.key_header
        if keys == kh or set(keys) <= set(kh):
            self.rows.append(d)
        elif set(keys) >= set(kh):
            # superset: append new keys to the header (paper: missing-value case)
            for k in keys:
                if k not in kh:
                    kh.append(k)
            self.rows.append(d)
        elif set(keys) & set(kh):
            for k in keys:
                if k not in kh:
                    kh.append(k)
            self.rows.append(d)
        else:
            # disjoint: disable the optimization for this record
            self.raw_rows.append(d)

    def take_rows(self) -> RowBlock:
        if not self.rows and not self.raw_rows:
            return RowBlock(Schema([]), [])
        kh = self.key_header or []
        fields = []
        for k in kh:
            v = next((r[k] for r in self.rows if k in r), "")
            fields.append(Field(k, schema_of_value(v)))
        schema = Schema(fields)
        rows = []
        for r in self.rows:
            rows.append(tuple(r.get(k, _null_of(schema[j].type)) for j, k in enumerate(kh)))
        self.rows = []
        return RowBlock(schema, rows)


def _null_of(t: ColType) -> Any:
    if t is ColType.STRING:
        return ""
    if t is ColType.BOOL:
        return False
    if t in (ColType.FLOAT32, ColType.FLOAT64):
        return float("nan")
    return 0


# -- inverse rendering: typed rows -> text for engines importing characters ---

def render_delimited(block: RowBlock, delimiter: str = ",") -> str:
    out = []
    for row in block.rows:
        out.append(delimiter.join(materialize_part(v) for v in row))
    return "\n".join(out) + ("\n" if out else "")


def render_json(block: RowBlock, per_line: bool = True) -> str:
    names = block.schema.names
    docs = []
    for row in block.rows:
        d = {}
        for n, v in zip(names, row):
            if isinstance(v, float) and v != v:  # NaN -> null
                d[n] = None
            else:
                d[n] = v
        docs.append(json.dumps(d, separators=(", ", ": ")))
    if per_line:
        return "\n".join(docs) + ("\n" if docs else "")
    return "[" + ", ".join(docs) + "]"
