"""Declarative transfer plans: plan → compile → execute.

The paper's usage model is one A→B table move configured by hand; hybrid
analytics is chains and fan-outs across many systems.  This module splits
the user surface into three layers (the intermediate-layer argument):

* **TransferPlan** — a declarative builder for a multi-edge DAG::

      plan().move(src, "t", dst, "t2").options(partition="hash:key",
                                               streams=2)
            .then(dst, "t2", third, "t3")

  ``move`` adds an independent edge (two moves out of the same table are
  a fan-out and run concurrently), ``then`` chains the next edge after
  the previous one, ``options`` refines the last-added edge.

* **The planner** (``TransferPlan.compile``) — resolves every edge to a
  fully-specified :class:`EdgePlan` *before any data moves*: wire mode
  via the FormOpt ladder with a process-wide negotiation cache (the
  lower rung of the two engines wins), transport/streams/partition
  validation, worker pairing and shuffle fan-in, and — for range
  partitions — *global* bounds sampled once from the source relation's
  quantiles and stamped into every exporter's config, so N exporters
  agree on the split.  Dependencies are inferred from data flow (an edge
  reading a table another edge produces waits for it; an edge
  overwriting a table an earlier edge reads waits for the read), checked
  for duplicate targets and cycles, and grouped into stages of
  independent edges.

* **The executor** (``CompiledPlan.execute``) — runs each stage's edges
  concurrently over the shared worker directory, aggregates the per-edge
  :class:`~repro.core.session.TransferResult` into a :class:`PlanResult`,
  and surfaces *all* peer failures (export and import side) instead of
  the first one, chaining secondaries as ``__context__``.

``CompiledPlan.explain()`` renders the per-edge decisions for inspection
(dry-run); ``describe()`` returns them as dicts for programmatic use.
:func:`repro.core.session.transfer` and ``transfer_via_files`` are thin
back-compat shims over a one-edge plan.
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field, fields as dc_fields, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

from . import telemetry
from .broker import QOS_CLASSES, get_broker
from .codegen import PipeEnabledEngine
from .datapipe import PipeConfig, collect_stats, collect_stats_by_attempt
from .directory import DirectoryLike, set_directory
from .fabric import compute_range_bounds, parse_partition
from .ioredirect import PipeOpenContext
from .telemetry import FlightRecorder, attach_flight

__all__ = [
    "plan",
    "TransferPlan",
    "CompiledPlan",
    "EdgePlan",
    "PlanResult",
    "PlanError",
    "PlanExecutionError",
    "SubscriptionSet",
    "negotiated_config",
    "chain_exceptions",
]


class PlanError(ValueError):
    """A plan failed validation at build/compile time (nothing moved)."""


class PlanExecutionError(RuntimeError):
    """One or more edges failed; ``.result`` holds the partial PlanResult."""

    def __init__(self, message: str, result: "PlanResult"):
        super().__init__(message)
        self.result = result


# -- negotiation cache ---------------------------------------------------------
# The FormOpt ladder (session.negotiate_pipe_mode) runs the engine's own
# round-trip tests per rung -- expensive, and its outcome is a property of
# the engine class, so one process-wide probe per engine is enough.

_negotiation_lock = threading.Lock()
_negotiation_cache: Dict[str, PipeConfig] = {}


def negotiated_config(engine: Any) -> PipeConfig:
    """The engine's negotiated :class:`PipeConfig` (FormOpt ladder,
    most-optimized rung that validates), cached process-wide per engine
    name.  Returns a copy — callers mutate freely."""
    key = engine.name
    with _negotiation_lock:
        cfg = _negotiation_cache.get(key)
    if cfg is None:
        from .session import negotiate_pipe_mode

        cfg = negotiate_pipe_mode(engine)
        with _negotiation_lock:
            cfg = _negotiation_cache.setdefault(key, cfg)
    return replace(cfg)


def clear_negotiation_cache() -> None:
    with _negotiation_lock:
        _negotiation_cache.clear()


def chain_exceptions(excs: Sequence[BaseException]) -> BaseException:
    """Return ``excs[0]`` with the remaining exceptions linked onto the
    end of its ``__context__`` chain, so a single ``raise`` surfaces every
    peer failure in the traceback."""
    primary = excs[0]
    seen = {id(primary)}
    node = primary
    while node.__context__ is not None and id(node.__context__) not in seen:
        node = node.__context__
        seen.add(id(node))
    for e in excs[1:]:
        if id(e) in seen:
            continue
        node.__context__ = e
        seen.add(id(e))
        node = e
        while node.__context__ is not None and id(node.__context__) not in seen:
            node = node.__context__
            seen.add(id(node))
    return primary


# -- the declarative surface ---------------------------------------------------

#: edge options that configure the *edge*, not the pipe.  ``broadcast``
#: (default True) gates the planner's fan-out detection: shm edges reading
#: the same source compile onto ONE export over a broadcast ring unless an
#: edge opts out with ``broadcast=False``.
_EDGE_KEYS = frozenset(
    ("workers", "import_workers", "timeout", "via", "dataset", "config",
     "broadcast", "retries", "backoff", "deadline", "failover", "resume",
     "tenant", "qos"))
_PIPE_KEYS = frozenset(f.name for f in dc_fields(PipeConfig))
_VIA = ("pipe", "files")


@dataclass
class _Edge:
    src: Any
    table: str
    dst: Any
    dst_table: str
    options: Dict[str, Any]
    after_prev: bool = False


#: options a subscribe() edge accepts (continuous pipes)
_SUB_KEYS = frozenset(
    ("name", "transport", "mode", "codec", "retain_epochs", "retain_bytes",
     "lease_s", "tenant", "qos", "shm_capacity", "doorbell", "streams",
     "timeout", "broadcast", "schema", "watermark"))


@dataclass
class _SubEdge:
    """A long-lived edge: ``dst`` subscribes to ``src:table`` deltas."""

    src: Any
    table: str
    dst: Any
    dst_table: str
    options: Dict[str, Any]

    @property
    def name(self) -> str:
        return self.options.get("name") or f"{self.src.name}.{self.table}"

    def explain_line(self) -> str:
        o = self.options
        bits = [f"{self.name}: {self.src.name}:{self.table} ~> "
                f"{self.dst.name}:{self.dst_table}",
                f"transport={o.get('transport', 'shm')}"]
        if int(o.get("streams", 1)) > 1:
            bits.append(f"streams={o.get('streams')}")
        if o.get("retain_epochs"):
            bits.append(f"retain={o.get('retain_epochs')}ep")
        bits.append("lifecycle=start/poll/close")
        return "  ".join(bits)


@dataclass
class EdgePlan:
    """One fully-resolved hop of a compiled plan (what ``explain`` shows
    and what the executor runs)."""

    edge_id: str
    source: str                      # source engine name
    table: str
    target: str                      # destination engine name
    dst_table: str
    via: str                         # "pipe" | "files"
    mode: str
    codec: str
    transport: str
    workers: int
    import_workers: int
    streams: int
    partition: Optional[str]
    partition_bounds: Optional[Tuple]   # global range bounds (compile-time)
    bounds_deferred: bool               # source is produced upstream
    fanin: int
    dataset: str
    timeout: float
    negotiated: bool                 # mode came from the FormOpt ladder
    depends_on: Tuple[str, ...]
    # fan-out compiled onto one export (shm broadcast ring): all edges of
    # a group share one dataset/query, the leader's edge runs the single
    # export, and every edge's importer reads a cursor slot of one ring
    broadcast: int = 0               # group size (0 = ordinary edge)
    broadcast_group: Optional[str] = None
    broadcast_leader: bool = False
    # retry policy (the executor's self-healing loop): up to 1 + retries
    # attempts with exponential backoff + seeded jitter, a deadline budget
    # shared across attempts, and — on transport faults — a shm/channel →
    # socket failover ladder.  ``resume`` gates the per-edge ledger that
    # lets attempt k+1 replay locally what attempt k already received and
    # ask the exporter for only the un-acked tail (plain 1:1 edges).
    retries: int = 0
    backoff_s: float = 0.05
    deadline_s: Optional[float] = None
    failover: bool = True
    resume: bool = True
    # broker admission (no-ops unless a PipeBroker is installed): which
    # tenant budget this edge draws from and its scheduling class —
    # queued "latency" tickets are admitted before queued "bulk" ones
    tenant: str = "default"
    qos: str = "bulk"
    broadcast_allowed: bool = field(repr=False, default=True)
    dataset_explicit: bool = field(repr=False, default=False)
    config: PipeConfig = field(repr=False, default=None)
    src_engine: Any = field(repr=False, default=None)
    dst_engine: Any = field(repr=False, default=None)

    def describe(self) -> dict:
        """The declarative decision record (no runtime handles)."""
        return {
            "edge": self.edge_id,
            "source": f"{self.source}:{self.table}",
            "target": f"{self.target}:{self.dst_table}",
            "via": self.via,
            "mode": self.mode,
            "codec": self.codec,
            "transport": self.transport,
            "workers": self.workers,
            "import_workers": self.import_workers,
            "streams": self.streams,
            "partition": self.partition,
            # a deferred edge shows "deferred" until execution samples the
            # bounds, then the sampled values
            "partition_bounds": (
                self.partition_bounds if self.partition_bounds is not None
                else ("deferred" if self.bounds_deferred else None)),
            "fanin": self.fanin,
            "negotiated": self.negotiated,
            "retries": self.retries,
            "resume": self.resume,
            "depends_on": list(self.depends_on),
            "broadcast": (
                {"group": self.broadcast_group, "readers": self.broadcast,
                 "leader": self.broadcast_leader}
                if self.broadcast_group else None),
        }

    def explain_line(self) -> str:
        bits = [f"{self.edge_id}: {self.source}:{self.table} -> "
                f"{self.target}:{self.dst_table}",
                f"via={self.via}"]
        if self.via == "pipe":
            bits += [f"mode={self.mode}"
                     + ("(negotiated)" if self.negotiated else ""),
                     f"codec={self.codec}", f"transport={self.transport}",
                     f"workers={self.workers}->{self.import_workers}"]
            if self.streams > 1:
                bits.append(f"streams={self.streams}")
            if self.partition:
                bits.append(f"partition={self.partition} fanin={self.fanin}")
                if self.partition_bounds is not None:
                    bounds = ", ".join(
                        f"{b:.4g}" if isinstance(b, float) else repr(b)
                        for b in self.partition_bounds)
                    bits.append(f"bounds=[{bounds}]")
                elif self.bounds_deferred:
                    bits.append("bounds=deferred")
            if self.broadcast_group:
                bits.append(
                    f"broadcast={self.broadcast_group}"
                    f"[{'1-export' if self.broadcast_leader else 'shared'}"
                    f",{self.broadcast} readers]")
            if self.retries:
                bits.append(
                    f"retries={self.retries}"
                    + (f" deadline={self.deadline_s:g}s"
                       if self.deadline_s else "")
                    + ("" if self.resume else " resume=off")
                    + ("" if self.failover else " failover=off"))
        else:
            bits.append(f"workers={self.workers}")
        if self.depends_on:
            bits.append(f"after={','.join(self.depends_on)}")
        return "  ".join(bits)


class TransferPlan:
    """Builder for a multi-edge transfer DAG (see module docstring)."""

    def __init__(self, directory: Optional[DirectoryLike] = None,
                 negotiate: bool = True):
        self._edges: List[_Edge] = []
        self._sub_edges: List[_SubEdge] = []
        self._last_edge: Optional[Any] = None
        self._directory = directory
        self._negotiate = negotiate

    # -- building --------------------------------------------------------------
    def move(self, src: Any, table: str, dst: Any, dst_table: str,
             **options: Any) -> "TransferPlan":
        """Add one ``src:table -> dst:dst_table`` edge.  Edges with no
        data dependency run concurrently (a second ``move`` out of the
        same table is a fan-out)."""
        self._edges.append(_Edge(src, table, dst, dst_table, dict(options)))
        self._last_edge = self._edges[-1]
        return self

    def then(self, src: Any, table: str, dst: Any, dst_table: str,
             **options: Any) -> "TransferPlan":
        """Like :meth:`move`, but explicitly sequenced after the
        previously added edge (a chained hop)."""
        if not self._edges:
            raise PlanError("then() needs a preceding move()")
        self._edges.append(
            _Edge(src, table, dst, dst_table, dict(options), after_prev=True))
        self._last_edge = self._edges[-1]
        return self

    def subscribe(self, src: Any, table: str, dst: Any, dst_table: str,
                  **options: Any) -> "TransferPlan":
        """Add a *continuous* edge: ``dst`` subscribes to ``src:table``
        and keeps receiving delta epochs for as long as the handle stays
        open (:mod:`repro.core.subscribe`).  Compile as usual, then call
        :meth:`CompiledPlan.start` — subscribe edges are long-lived, so
        they get a start/poll/close lifecycle instead of ``execute()``.
        Several subscribers of the same source relation share one
        publication, and colocated shm subscribers collapse onto a
        broadcast ring (one encode + one ring write per epoch)."""
        bad = set(options) - _SUB_KEYS
        if bad:
            raise PlanError(
                f"unknown subscribe option(s): {sorted(bad)} "
                f"(allowed: {sorted(_SUB_KEYS)})")
        self._sub_edges.append(
            _SubEdge(src, table, dst, dst_table, dict(options)))
        self._last_edge = self._sub_edges[-1]
        return self

    def options(self, **options: Any) -> "TransferPlan":
        """Refine the last-added edge (``mode=``, ``streams=``,
        ``partition=``, ``workers=``, ... — any PipeConfig knob or edge
        option)."""
        if self._last_edge is None:
            raise PlanError("options() needs a preceding move()")
        if isinstance(self._last_edge, _SubEdge):
            bad = set(options) - _SUB_KEYS
            if bad:
                raise PlanError(
                    f"unknown subscribe option(s): {sorted(bad)}")
        self._last_edge.options.update(options)
        return self

    # -- compile ---------------------------------------------------------------
    def compile(self, directory: Optional[DirectoryLike] = None
                ) -> "CompiledPlan":
        """Validate the whole DAG and resolve every edge to an
        :class:`EdgePlan` — negotiation, partition bounds, worker pairing
        — before any data moves."""
        if not self._edges and not self._sub_edges:
            raise PlanError(
                "empty plan: add edges with move() or subscribe()")
        if not self._edges:
            # subscription-only plan: no batch stages to resolve
            return CompiledPlan([], [], directory or self._directory,
                                sub_edges=list(self._sub_edges))
        with telemetry.span("plan.compile", edges=len(self._edges)):
            return self._compile(directory)

    def _compile(self, directory: Optional[DirectoryLike] = None
                 ) -> "CompiledPlan":
        n = len(self._edges)
        # duplicate targets: two edges writing the same (engine, table)
        produced: Dict[Tuple[int, str], int] = {}
        for i, e in enumerate(self._edges):
            key = (id(e.dst), e.dst_table)
            if key in produced:
                raise PlanError(
                    f"duplicate target: edges e{produced[key]} and e{i} "
                    f"both write {e.dst.name}:{e.dst_table}")
            produced[key] = i
        # data-flow dependencies (+ explicit then-chaining)
        deps: List[set] = [set() for _ in range(n)]
        for i, e in enumerate(self._edges):
            if (id(e.src), e.table) == (id(e.dst), e.dst_table):
                raise PlanError(
                    f"edge e{i} reads and writes the same table "
                    f"{e.src.name}:{e.table} (a one-edge cycle)")
            if e.after_prev:
                deps[i].add(i - 1)
            for j in range(i):  # declaration order resolves hazards
                other = self._edges[j]
                # read-after-write: i consumes what j produces
                if (id(other.dst), other.dst_table) == (id(e.src), e.table):
                    deps[i].add(j)
                # write-after-read: i overwrites what j still reads
                if (id(other.src), other.table) == (id(e.dst), e.dst_table):
                    deps[i].add(j)
            has_producer = any(
                (id(o.dst), o.dst_table) == (id(e.src), e.table)
                for o in self._edges[:i])
            if not has_producer:
                tables = getattr(e.src, "tables", None)
                if tables is not None and e.table not in tables:
                    raise PlanError(
                        f"edge e{i}: source table {e.table!r} does not "
                        f"exist in {e.src.name} and no earlier edge "
                        f"produces it")
        # topological stages (Kahn levels); leftover edges form a cycle
        stages: List[List[int]] = []
        resolved: set = set()
        remaining = set(range(n))
        while remaining:
            level = sorted(i for i in remaining if deps[i] <= resolved)
            if not level:
                raise PlanError(
                    "plan has a dependency cycle among edges "
                    f"{sorted(f'e{i}' for i in remaining)}")
            stages.append(level)
            resolved |= set(level)
            remaining -= set(level)
        # per-edge resolution
        plans: List[EdgePlan] = []
        for i, e in enumerate(self._edges):
            # sample range bounds at compile only when the source relation
            # is already final (not produced/overwritten by an upstream
            # edge) -- otherwise defer sampling to just before the edge runs
            produced_upstream = any(
                (id(o.dst), o.dst_table) == (id(e.src), e.table)
                for o in self._edges[:i])
            plans.append(self._resolve_edge(
                i, e, deps[i],
                table_preexists=(
                    not produced_upstream
                    and e.table in getattr(e.src, "tables", ()))))
        self._group_broadcasts(plans)
        return CompiledPlan(plans, [[f"e{i}" for i in lvl] for lvl in stages],
                            directory or self._directory,
                            sub_edges=list(self._sub_edges))

    @staticmethod
    def _group_broadcasts(plans: List[EdgePlan]) -> None:
        """Detect fan-outs that can share one export: N shm pipe edges
        reading the same source relation to colocated importers, with
        identical wire decisions (mode/codec/block framing), dialect, and
        dependencies.  Each group compiles onto ONE export feeding one
        broadcast ring with N reader cursor slots instead of N exports
        re-encoding the same relation."""
        groups: Dict[Tuple, List[EdgePlan]] = {}
        for ep in plans:
            cfg = ep.config
            if (not ep.broadcast_allowed or ep.via != "pipe"
                    or ep.transport != "shm" or ep.workers != 1
                    or ep.import_workers != 1 or ep.streams != 1
                    or ep.partition or cfg.broadcast
                    # an explicit dataset= names the edge's rendezvous;
                    # grouping would silently rename it to the leader's
                    or ep.dataset_explicit):
                continue
            dst = ep.dst_engine
            key = (id(ep.src_engine), ep.table, ep.mode, ep.codec,
                   cfg.block_rows, cfg.text_format, cfg.delimiter,
                   cfg.verify_first_n, cfg.shm_capacity, cfg.shm_doorbell,
                   id(cfg.link), ep.depends_on,
                   bool(getattr(dst, "writes_header", False)),
                   getattr(dst, "csv_delimiter", ","))
            groups.setdefault(key, []).append(ep)
        gid = 0
        for members in groups.values():
            if len(members) < 2:
                continue
            for k, ep in enumerate(members):
                ep.broadcast = len(members)
                ep.broadcast_group = f"b{gid}"
                ep.broadcast_leader = k == 0
            gid += 1

    def _resolve_edge(self, i: int, e: _Edge, deps: set,
                      table_preexists: bool) -> EdgePlan:
        opts = dict(e.options)
        unknown = set(opts) - _EDGE_KEYS - _PIPE_KEYS
        if unknown:
            raise PlanError(
                f"edge e{i}: unknown option(s) {sorted(unknown)}; have "
                f"{sorted(_EDGE_KEYS | _PIPE_KEYS)}")
        via = opts.pop("via", "pipe")
        if via not in _VIA:
            raise PlanError(f"edge e{i}: via={via!r} not in {_VIA}")
        broadcast_allowed = opts.pop("broadcast", True)
        if not isinstance(broadcast_allowed, bool):
            # the reader count is the planner's to derive (group size);
            # a silent bool() coercion would discard a user's int
            raise PlanError(
                f"edge e{i}: broadcast takes True/False (opt in/out of "
                f"fan-out grouping — the planner derives the reader "
                f"count from the group), got {broadcast_allowed!r}")
        retries = int(opts.pop("retries", 0))
        if retries < 0:
            raise PlanError(f"edge e{i}: retries must be >= 0")
        backoff = float(opts.pop("backoff", 0.05))
        if backoff < 0:
            raise PlanError(f"edge e{i}: backoff must be >= 0")
        deadline_opt = opts.pop("deadline", None)
        deadline_s = float(deadline_opt) if deadline_opt is not None else None
        if deadline_s is not None and deadline_s <= 0:
            raise PlanError(f"edge e{i}: deadline must be > 0")
        tenant = str(opts.pop("tenant", "default"))
        qos = opts.pop("qos", "bulk")
        if qos not in QOS_CLASSES:
            raise PlanError(
                f"edge e{i}: qos={qos!r} not in {QOS_CLASSES}")
        failover = bool(opts.pop("failover", True))
        resume = opts.pop("resume", True)
        if not isinstance(resume, bool):
            raise PlanError(
                f"edge e{i}: resume takes True/False (the executor derives "
                f"the ledger token per run), got {resume!r}")
        workers = int(opts.pop("workers", 1))
        import_workers = opts.pop("import_workers", None)
        timeout = float(opts.pop("timeout", 120.0))
        dataset = opts.pop("dataset", None)
        dataset_explicit = dataset is not None
        dataset = dataset or f"{e.src.name}2{e.dst.name}"
        base = opts.pop("config", None)
        pipe_overrides = {k: v for k, v in opts.items() if k in _PIPE_KEYS}
        if via == "files" and (retries or deadline_s is not None):
            # the retry loop wraps the pipe rendezvous; the file baseline
            # has no peer to resume against
            raise PlanError(
                f"edge e{i}: via='files' does not take a retry policy")
        if via == "files" and (pipe_overrides or base is not None
                               or import_workers is not None):
            # a file edge never opens pipes: pipe knobs silently ignored
            # would be exactly the kwarg fall-through the planner exists
            # to prevent
            bad = sorted(pipe_overrides) + (
                ["config"] if base is not None else []) + (
                ["import_workers"] if import_workers is not None else [])
            raise PlanError(
                f"edge e{i}: via='files' cannot take pipe option(s) {bad}")
        import_workers = (workers if import_workers is None
                          else int(import_workers))
        negotiated = False
        if base is not None:
            cfg = replace(base)
        elif self._negotiate and via == "pipe" and "mode" not in pipe_overrides:
            cfg, negotiated = self._negotiate_pair(e.src, e.dst), True
        else:
            cfg = PipeConfig()
        if pipe_overrides:
            cfg = replace(cfg, **pipe_overrides)
        if cfg.streams < 1:
            raise PlanError(f"edge e{i}: streams must be >= 1")
        if cfg.transport not in ("socket", "channel", "shm"):
            raise PlanError(
                f"edge e{i}: unknown transport {cfg.transport!r}")
        bounds_deferred = False
        if via == "pipe" and cfg.partition:
            try:
                part = parse_partition(cfg.partition,
                                       bounds=cfg.partition_bounds)
            except ValueError as exc:
                raise PlanError(f"edge e{i}: {exc}") from None
            cfg = replace(cfg, fanin=workers)
            if (cfg.partition_bounds is None
                    and cfg.partition.split(":", 1)[0].strip().lower()
                    == "range"):
                if table_preexists:
                    bounds = compute_range_bounds(
                        e.src.get_block(e.table), part.key, import_workers)
                    cfg = replace(cfg, partition_bounds=tuple(bounds))
                else:
                    # the source relation is produced by an upstream edge;
                    # the executor samples bounds right before this edge
                    bounds_deferred = True
        elif via == "pipe":
            cfg = replace(cfg, fanin=1)
        return EdgePlan(
            edge_id=f"e{i}", source=e.src.name, table=e.table,
            target=e.dst.name, dst_table=e.dst_table, via=via,
            mode=cfg.mode if via == "pipe" else "file-csv",
            codec=cfg.codec if via == "pipe" else "none",
            transport=cfg.transport, workers=workers,
            import_workers=import_workers, streams=cfg.streams,
            partition=cfg.partition, partition_bounds=cfg.partition_bounds,
            bounds_deferred=bounds_deferred, fanin=cfg.fanin,
            dataset=dataset, timeout=timeout,
            negotiated=negotiated,
            retries=retries, backoff_s=backoff, deadline_s=deadline_s,
            failover=failover, resume=resume, tenant=tenant, qos=qos,
            depends_on=tuple(f"e{j}" for j in sorted(deps)),
            broadcast_allowed=broadcast_allowed,
            dataset_explicit=dataset_explicit,
            config=cfg, src_engine=e.src, dst_engine=e.dst,
        )

    @staticmethod
    def _negotiate_pair(src: Any, dst: Any) -> PipeConfig:
        """Both engines run the FormOpt ladder (cached); the edge takes
        the *lower* (less optimized) of the two negotiated rungs — the
        most conservative mode both sides validated."""
        from .session import MODE_LADDER

        cfg_s, cfg_d = negotiated_config(src), negotiated_config(dst)
        try:
            rung = max(MODE_LADDER.index(cfg_s.mode),
                       MODE_LADDER.index(cfg_d.mode))
        except ValueError:  # pragma: no cover - ladder always covers both
            return cfg_s
        return replace(cfg_s, mode=MODE_LADDER[rung])

    # -- conveniences ----------------------------------------------------------
    def explain(self) -> str:
        return self.compile().explain()

    def execute(self, directory: Optional[DirectoryLike] = None,
                raise_on_error: bool = True) -> "PlanResult":
        return self.compile(directory).execute(raise_on_error=raise_on_error)


def plan(directory: Optional[DirectoryLike] = None,
         negotiate: bool = True) -> TransferPlan:
    """Start a :class:`TransferPlan` (``negotiate=False`` skips the
    FormOpt ladder and defaults un-configured edges to ``arrowcol``)."""
    return TransferPlan(directory=directory, negotiate=negotiate)


# -- results -------------------------------------------------------------------


@dataclass
class PlanResult:
    """Aggregate outcome of one executed plan."""

    results: Dict[str, Any]              # edge_id -> TransferResult
    errors: List[str]                    # formatted, all edges/sides
    exceptions: List[BaseException]      # the underlying exception objects
    skipped: List[str]                   # edges not run (upstream failed)
    seconds: float = 0.0

    @property
    def ok(self) -> bool:
        return not self.errors and not self.skipped

    @property
    def rows(self) -> int:
        """Total rows landed across *all* edges — a chain counts every
        hop (two 400-row hops report 800); per-relation counts live on
        the per-edge TransferResults."""
        return sum(r.rows for r in self.results.values())

    def edge(self, edge_id: str):
        return self.results[edge_id]

    def single(self):
        """The sole TransferResult of a one-edge plan (the shims' case)."""
        if len(self.results) != 1:
            raise ValueError(f"plan has {len(self.results)} results")
        return next(iter(self.results.values()))


class CompiledPlan:
    """A validated plan: fully-resolved edges grouped into stages of
    independent edges.  ``explain()`` before, ``execute()`` when ready."""

    def __init__(self, edges: List[EdgePlan], stages: List[List[str]],
                 directory: Optional[DirectoryLike],
                 sub_edges: Optional[List[_SubEdge]] = None):
        self.edges = edges
        self.stages = stages
        self.sub_edges = sub_edges or []
        self._directory = directory
        self._by_id = {ep.edge_id: ep for ep in edges}

    def describe(self) -> List[dict]:
        """Per-edge decision dicts, in edge order."""
        return [ep.describe() for ep in self.edges]

    def explain(self) -> str:
        """Human-readable per-edge decisions (the dry-run view)."""
        lines = [f"plan: {len(self.edges)} edge(s), "
                 f"{len(self.stages)} stage(s)"]
        for s, stage in enumerate(self.stages):
            lines.append(f"stage {s}:")
            for eid in stage:
                lines.append("  " + self._by_id[eid].explain_line())
        if self.sub_edges:
            lines.append(f"continuous: {len(self.sub_edges)} "
                         f"subscription edge(s)")
            for se in self.sub_edges:
                lines.append("  " + se.explain_line())
        return "\n".join(lines)

    def execute(self, raise_on_error: bool = True) -> PlanResult:
        """Run the stages in order, each stage's edges concurrently, over
        the shared worker directory.  With ``raise_on_error`` (default) a
        failed edge raises :class:`PlanExecutionError` after the whole
        plan settles, all collected exceptions chained; edges downstream
        of a failure are skipped, independent edges still run."""
        if self.sub_edges and not self.edges:
            raise PlanError(
                "this plan has only subscribe() edges — they are "
                "long-lived; use start() (then poll()/close() the handle)")
        if self._directory is not None:
            set_directory(self._directory)
        # generate every engine's pipe adapter up front, serially: the
        # capture run patches builtins.open process-wide, so it must never
        # overlap another edge's live pipe traffic
        from .session import adapter_for

        for ep in self.edges:
            if ep.via == "pipe":
                adapter_for(ep.src_engine)
                adapter_for(ep.dst_engine)
        t0 = time.perf_counter()
        results: Dict[str, Any] = {}
        errors: List[str] = []
        exceptions: List[BaseException] = []
        skipped: List[str] = []
        failed: set = set()
        for stage in self.stages:
            runnable: List[EdgePlan] = []
            for eid in stage:
                ep = self._by_id[eid]
                bad = [d for d in ep.depends_on if d in failed]
                if bad:
                    skipped.append(eid)
                    failed.add(eid)
                    errors.append(
                        f"{eid}: skipped (upstream {','.join(bad)} failed)")
                else:
                    runnable.append(ep)
            if not runnable:
                continue
            outs: Dict[str, Tuple[Any, List[BaseException]]] = {}
            # work units: ordinary edges run alone; a broadcast group's
            # edges run as ONE unit (one export + R importers over one
            # ring), sharing a single dataset/query rendezvous
            units: List[List[EdgePlan]] = []
            by_group: Dict[str, List[EdgePlan]] = {}
            for ep in runnable:
                if ep.broadcast_group:
                    grp = by_group.setdefault(ep.broadcast_group, [])
                    grp.append(ep)
                    if len(grp) == 1:
                        units.append(grp)
                else:
                    units.append([ep])
            # fresh query ids per run: a re-executed compiled plan must
            # not collide with its previous rendezvous (the directory's
            # per-(dataset, query) state — sender slots, stats — persists)
            from .session import _query_counter

            qids = {id(unit): f"q{next(_query_counter)}" for unit in units}
            broker = get_broker()
            # captured before the worker threads spawn: thread-locals do
            # not cross threads, so each unit re-adopts the plan's trace
            # context explicitly
            plan_ctx = telemetry.current_ctx()

            def run(unit: List[EdgePlan]) -> None:
                recorder = FlightRecorder(
                    name=f"edge {unit[0].edge_id} "
                         f"({unit[0].dataset}:{qids[id(unit)]})")
                with telemetry.trace_context(plan_ctx), \
                        telemetry.span("plan.unit",
                                       edge=unit[0].edge_id,
                                       dataset=unit[0].dataset):
                    self._run_unit(unit, qids[id(unit)], broker, outs,
                                   recorder)

            if len(units) == 1:
                run(units[0])
            else:
                threads = [
                    threading.Thread(target=run, args=(unit,),
                                     name=f"pipegen-plan-{unit[0].edge_id}",
                                     daemon=True)
                    for unit in units
                ]
                for t in threads:
                    t.start()
                for t in threads:
                    t.join()
            for ep in runnable:
                res, excs = outs[ep.edge_id]
                if res is not None:
                    results[ep.edge_id] = res
                if excs:
                    failed.add(ep.edge_id)
                    exceptions.extend(excs)
                    errors.extend(
                        f"{ep.edge_id}: {m}"
                        for m in (res.errors if res is not None
                                  else [repr(x) for x in excs]))
        pr = PlanResult(results=results, errors=errors, exceptions=exceptions,
                        skipped=skipped, seconds=time.perf_counter() - t0)
        if raise_on_error and exceptions:
            raise PlanExecutionError(
                f"{len(failed)} edge(s) failed: " + "; ".join(errors), pr
            ) from chain_exceptions(exceptions)
        return pr

    # -- continuous edges (subscribe() verb) -----------------------------------
    def start(self, timeout: float = 30.0) -> "SubscriptionSet":
        """Bring the plan's subscribe() edges live and return the handle.

        Per distinct (source, table, name) one :class:`~repro.core.
        subscribe.Publication` is created — seeded with a snapshot of the
        source table if it has rows — and wired to the source engine's
        ``on_append`` delta-capture hook, so every ``engine.append()``
        commits an epoch.  Each subscribe edge becomes a
        :class:`~repro.core.subscribe.Subscription` applying epochs into
        its target engine; shm subscribers of one publication share a
        broadcast ring.  The caller owns the returned handle:
        ``poll()`` to apply deltas, ``close()`` to tear everything down.
        """
        if not self.sub_edges:
            raise PlanError("no subscribe() edges in this plan — "
                            "use execute() for batch moves")
        # `from .subscribe import ...` resolves inside the module itself —
        # the package attribute `subscribe` is shadowed by the factory
        # function of the same name once repro.core finishes importing
        from .subscribe import Publication, Subscription, apply_to_engine
        from .directory import get_directory

        directory = self._directory if self._directory is not None \
            else get_directory()
        groups: Dict[Tuple[int, str, str], List[_SubEdge]] = {}
        for se in self.sub_edges:
            groups.setdefault((id(se.src), se.table, se.name),
                              []).append(se)
        pubs: Dict[str, Any] = {}
        unhooks: List[Any] = []
        subs: List[Tuple[str, Any]] = []
        try:
            for (_, table, name), edges in groups.items():
                se0 = edges[0]
                src, o = se0.src, se0.options
                initial = (src.get_block(table)
                           if table in getattr(src, "tables", ()) else None)
                schema = (initial.schema if initial is not None
                          else o.get("schema"))
                if schema is None:
                    raise PlanError(
                        f"subscribe: source table "
                        f"{src.name}:{table} is empty — pass schema=")
                pub = Publication(
                    name, schema, directory=directory,
                    mode=o.get("mode", "arrowcol"),
                    codec=o.get("codec", "none"),
                    retain_epochs=int(o.get("retain_epochs", 64)),
                    retain_bytes=int(o.get("retain_bytes", 64 << 20)),
                    lease_s=o.get("lease_s"),
                    tenant=o.get("tenant", "default"),
                    qos=o.get("qos", "bulk"))
                pubs[name] = pub
                if initial is not None and len(initial):
                    pub.commit_snapshot(initial)
                if hasattr(src, "on_append"):
                    unhooks.append(src.on_append(
                        table, lambda _t, blk, p=pub: p.append(blk)))
                # colocated shm subscribers collapse onto one broadcast
                # ring — one encode + one ring write per epoch
                shm_edges = [
                    e for e in edges
                    if e.options.get("transport", "shm") == "shm"
                    and int(e.options.get("streams", 1)) == 1
                    and e.options.get("broadcast", True)]
                bc = len(shm_edges) if len(shm_edges) > 1 else 0
                for se in edges:
                    eo = se.options
                    kw: Dict[str, Any] = {
                        "directory": directory,
                        "transport": eo.get("transport", "shm"),
                        "streams": int(eo.get("streams", 1)),
                        "watermark": int(eo.get("watermark", 0)),
                        "timeout": eo.get("timeout", timeout),
                        "apply": apply_to_engine(se.dst, se.dst_table),
                    }
                    if bc and se in shm_edges:
                        kw["broadcast"] = bc
                    for opt in ("shm_capacity", "doorbell", "lease_s"):
                        if opt in eo:
                            kw[opt] = eo[opt]
                    label = f"{name}->{se.dst.name}:{se.dst_table}"
                    if any(l == label for l, _ in subs):
                        label = f"{label}#{len(subs)}"
                    subs.append((label, Subscription(name, **kw)))
        except BaseException:
            for _, s in subs:
                s.close()
            for u in unhooks:
                u()
            for p in pubs.values():
                p.close()
            raise
        return SubscriptionSet(pubs, subs, unhooks)

    @staticmethod
    def _run_unit(unit: List[EdgePlan], qid: str, broker, outs: Dict,
                  recorder: FlightRecorder) -> None:
        """One work unit end to end: admission ticket (queue under the
        broker's QoS gate), then the edge / broadcast-group runner.  The
        unit's FlightRecorder accumulates admission, attempt, and pipe
        events; any terminal failure leaves with that timeline attached."""
        ticket = None
        if broker is not None:
            # hold an admission ticket for the unit's whole lifetime:
            # over-quota units queue here (in their own thread) while
            # admitted ones move data
            vec = _admission_vector(unit)
            recorder.note("admission.request", **vec)
            t0 = time.monotonic()
            try:
                with telemetry.span("plan.admit", edge=unit[0].edge_id,
                                    tenant=vec["tenant"], qos=vec["qos"]):
                    ticket = broker.admit(**vec)
            except BaseException as e:  # noqa: BLE001 - aggregated
                recorder.note("admission.rejected", error=repr(e))
                attach_flight(e, recorder)
                for ep in unit:
                    outs[ep.edge_id] = (None, [e])
                return
            if getattr(ticket, "degraded", False):
                # control plane unreachable: admission suspended (the
                # degraded ladder), the unit proceeds un-gated
                recorder.note("admission.degraded")
                telemetry.counter("plan.admit_degraded").inc()
            recorder.note("admission.granted",
                          wait_s=round(time.monotonic() - t0, 6))
        try:
            if len(unit) == 1 and not unit[0].broadcast_group:
                outs[unit[0].edge_id] = _run_edge(unit[0], qid, recorder)
                return
            try:
                outs.update(_run_broadcast_group(unit, qid, recorder))
            except BaseException as e:  # noqa: BLE001 - aggregated
                for ep in unit:
                    outs[ep.edge_id] = (None, [e])
        finally:
            if ticket is not None:
                ticket.release()


class SubscriptionSet:
    """The live handle :meth:`CompiledPlan.start` returns for a plan's
    continuous edges: per-name publications fed by the source engines'
    append hooks, plus one subscription per edge applying epochs into its
    target engine.  ``poll()`` to apply pending deltas, ``close()`` to
    tear down subscriptions → hooks → publications, in that order."""

    def __init__(self, publications: Dict[str, Any],
                 subscriptions: List[Tuple[str, Any]],
                 unhooks: List[Any]):
        self.publications = publications
        self.subscriptions = subscriptions
        self._unhooks = unhooks
        self._closed = False

    def poll(self, timeout: float = 0.0) -> Dict[str, List[Any]]:
        """Drain every subscription once (deltas apply into the target
        engines via their ``apply`` callbacks); label -> epochs."""
        out: Dict[str, List[Any]] = {}
        for label, sub in self.subscriptions:
            try:
                out[label] = sub.poll(timeout)
            except BrokenPipeError:
                out[label] = []
        return out

    @property
    def watermarks(self) -> Dict[str, int]:
        return {label: s.watermark for label, s in self.subscriptions}

    def wait_caught_up(self, timeout: float = 10.0) -> bool:
        """Poll until every subscription's watermark reaches its
        publication's head (True) or ``timeout`` elapses (False)."""
        deadline = time.monotonic() + timeout
        while True:
            heads = {n: p.head for n, p in self.publications.items()}
            behind = [
                (label, s) for label, s in self.subscriptions
                if s.watermark < heads.get(label.split("->", 1)[0], 0)]
            if not behind:
                return True
            if time.monotonic() >= deadline:
                return False
            self.poll(timeout=0.05)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for _, sub in self.subscriptions:
            sub.close()
        for unhook in self._unhooks:
            try:
                unhook()
            except Exception:
                pass
        for pub in self.publications.values():
            pub.close()

    def __enter__(self) -> "SubscriptionSet":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


# -- the edge runners ----------------------------------------------------------


def _admission_vector(unit: List[EdgePlan]) -> Dict[str, Any]:
    """The broker resource vector for one work unit.  shm edges cost
    rings (streams × shuffle fan-in, 2 doorbell fds each while live) and
    their summed ring bytes; a broadcast group costs ONE segment however
    many readers it fans out to; channel/socket/file edges cost only a
    concurrency slot.  Tenant/QoS come from the first edge — a broadcast
    group shares one export, so its edges share one ticket."""
    lead = unit[0]
    rings = segments = nbytes = 0
    for ep in unit:
        if ep.via != "pipe" or ep.transport != "shm":
            continue
        if ep.broadcast_group:
            if segments == 0:
                segments = 1
                rings += 1
                nbytes += ep.config.shm_capacity
            continue
        n = max(1, ep.streams) * max(1, ep.fanin)
        rings += n
        segments += n
        nbytes += n * ep.config.shm_capacity
    return {"tenant": lead.tenant, "qos": lead.qos, "rings": rings,
            "segments": segments, "nbytes": nbytes}


def _run_edge(ep: EdgePlan, query_id: str,
              recorder: Optional[FlightRecorder] = None):
    """Execute one edge under the executor's per-run ``query_id``;
    returns ``(TransferResult | None, exceptions)``.  Never raises: all
    failures (both sides, timeout) are collected."""
    try:
        if ep.via == "files":
            return _run_file_edge(ep)
        return _run_pipe_edge(ep, query_id, recorder)
    except BaseException as e:  # noqa: BLE001 - the executor aggregates
        return None, [e]


def _transport_fault(excs: Sequence[BaseException]) -> bool:
    """True when any failure looks like the transport (not the data or
    the engine) let the edge down — the failover ladder's trigger."""
    return any(isinstance(e, (OSError, TimeoutError)) for e in excs)


def _run_pipe_edge(ep: EdgePlan, query_id: str,
                   recorder: Optional[FlightRecorder] = None):
    """The self-healing wrapper: run :func:`_run_pipe_attempt` up to
    ``1 + ep.retries`` times.  Each retry gets a fresh query id (the
    directory's per-(dataset, query) rendezvous state is single-use), a
    bumped ``attempt`` epoch, and — on resumable edges — the shared
    resume-ledger token, so the new importer replays the staged prefix
    and the new exporter skips to the acked watermark instead of
    re-moving the whole relation.  Backoff is exponential with seeded
    jitter; ``deadline`` bounds the whole loop; on transport faults a
    shm/channel edge fails over to the socket rendezvous."""
    from .datapipe import clear_resume

    config = ep.config
    if ep.bounds_deferred:
        # the source relation now exists (its producer edge ran): sample
        # the global range bounds that compile had to defer.  The flag
        # stays set — a re-executed plan re-samples (the upstream edge
        # re-ran too); ep.partition_bounds is updated for observability.
        part = parse_partition(ep.partition)
        bounds = tuple(compute_range_bounds(
            ep.src_engine.get_block(ep.table), part.key, ep.import_workers))
        config = replace(config, partition_bounds=bounds)
        ep.partition_bounds = bounds
    max_attempts = 1 + max(0, ep.retries)
    # resume needs a single 1:1 pipe: stripes/shuffles/broadcasts have
    # per-member frame orders one watermark cannot describe
    resumable = (ep.resume and max_attempts > 1 and ep.streams == 1
                 and ep.fanin == 1 and not ep.partition
                 and not ep.broadcast_group
                 and ep.workers == 1 and ep.import_workers == 1)
    token = f"{ep.dataset}:{query_id}:{ep.edge_id}" if resumable else None
    rng = random.Random(hash((ep.dataset, query_id, ep.edge_id)) & 0x7FFFFFFF)
    deadline = (time.monotonic() + ep.deadline_s) if ep.deadline_s else None
    transport = config.transport
    recorder = recorder if recorder is not None else FlightRecorder(
        name=f"edge {ep.edge_id} ({ep.dataset}:{query_id})")
    attempts: List[dict] = []
    history: List[str] = []
    result = None
    excs: List[BaseException] = []
    try:
        for k in range(max_attempts):
            qid = query_id if k == 0 else f"{query_id}a{k}"
            # a rendezvous must not outlive its attempt: a side blocked in
            # the directory past ep.timeout is already abandoned (the
            # attempt's join gave up on it), and an orphaned exporter
            # thread still holds its open-splice registration
            cfg = replace(config, transport=transport, resume=token,
                          attempt=k, recorder=recorder,
                          trace_ctx=(config.trace_ctx
                                     or telemetry.current_ctx()),
                          connect_timeout=min(config.connect_timeout,
                                              ep.timeout))
            recorder.note("edge.attempt", attempt=k, query_id=qid,
                          transport=transport,
                          resumed=bool(token and k > 0))
            t0 = time.monotonic()
            with telemetry.span("edge.attempt", edge=ep.edge_id,
                                attempt=k, transport=transport):
                result, excs = _run_pipe_attempt(ep, cfg, qid)
            rec = {"attempt": k, "query_id": qid, "transport": transport,
                   "seconds": round(time.monotonic() - t0, 6),
                   "ok": not excs,
                   "error": repr(excs[0]) if excs else None}
            if result is not None:
                # per-attempt attribution: this attempt's own stats, not
                # the fold across earlier failed attempts
                rec["export_stats"] = result.export_stats
                rec["import_stats"] = result.import_stats
            attempts.append(rec)
            if not excs:
                break
            recorder.note("edge.attempt_failed", attempt=k,
                          error=rec["error"])
            history.append(f"attempt {k} ({transport}): {rec['error']}")
            if k + 1 >= max_attempts:
                break
            if deadline is not None and time.monotonic() >= deadline:
                history.append(
                    f"retry budget exhausted after attempt {k} "
                    f"(deadline {ep.deadline_s:g}s)")
                break
            if (ep.failover and transport in ("shm", "channel")
                    and _transport_fault(excs)):
                history.append(f"failover: {transport} -> socket")
                recorder.note("edge.failover", frm=transport, to="socket")
                transport = "socket"
            delay = ep.backoff_s * (2 ** k) * (0.5 + rng.random())
            if deadline is not None:
                delay = min(delay, max(0.0, deadline - time.monotonic()))
            if delay > 0:
                time.sleep(delay)
    finally:
        if token is not None:
            clear_resume(token)
    if result is not None:
        result.attempts = attempts
        if history:
            result.errors = history + result.errors
    if excs:
        # terminal failure: every raised error carries the edge timeline
        for e in excs:
            attach_flight(e, recorder)
    return result, excs


def _run_pipe_attempt(ep: EdgePlan, config, query_id: str):
    from .session import TransferResult, adapter_for

    src, dst = ep.src_engine, ep.dst_engine
    gp_src, gp_dst = adapter_for(src), adapter_for(dst)
    name_exp = (f"db://{ep.dataset}?workers={ep.workers}"
                f"&query={query_id}")
    name_imp = (f"db://{ep.dataset}?workers={ep.import_workers}"
                f"&query={query_id}")
    # (side, exception) in *completion order*: the first failure is the
    # root cause (a crashed peer orphans the survivor, whose secondary
    # timeout then rides along as __context__)
    errs: List[Tuple[str, BaseException]] = []
    times = {"export": 0.0, "import": 0.0}

    def run_import() -> None:
        t0 = time.perf_counter()
        try:
            with PipeEnabledEngine(gp_dst), PipeOpenContext(config):
                dst.import_csv_parallel(ep.dst_table, name_imp,
                                        workers=ep.import_workers)
        except BaseException as e:  # noqa: BLE001 - surfaced via result
            errs.append(("import", e))
        times["import"] = time.perf_counter() - t0

    def run_export() -> None:
        t0 = time.perf_counter()
        try:
            with PipeEnabledEngine(gp_src), PipeOpenContext(config):
                src.export_csv_parallel(
                    ep.table, name_exp, workers=ep.workers,
                    header=dst.writes_header, delimiter=dst.csv_delimiter,
                )
        except BaseException as e:  # noqa: BLE001
            errs.append(("export", e))
        times["export"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    # daemon: a failed peer must not pin the process on an orphaned
    # accept/recv (the surviving side times out on its own)
    ti = threading.Thread(target=run_import, daemon=True,
                          name=f"pipegen-import-{query_id}")
    te = threading.Thread(target=run_export, daemon=True,
                          name=f"pipegen-export-{query_id}")
    ti.start()
    te.start()
    ti.join(ep.timeout)
    te.join(ep.timeout)
    elapsed = time.perf_counter() - t0
    excs: List[BaseException] = []
    messages: List[str] = []
    for side, e in errs:
        excs.append(e)
        messages.append(f"{side}: {e!r}")
    if not excs and (ti.is_alive() or te.is_alive()):
        stuck = [nm for nm, th in (("import", ti), ("export", te))
                 if th.is_alive()]
        excs.append(TimeoutError(
            f"transfer {ep.dataset} did not complete within {ep.timeout}s "
            f"({'/'.join(stuck)} still running)"))
        messages.append(f"timeout: {excs[-1]}")
    try:
        rows = len(dst.get_block(ep.dst_table))
    except KeyError:
        rows = 0
    stats = collect_stats(ep.dataset, query_id)
    exp_stats = stats.get("export")
    result = TransferResult(
        source=src.name, target=dst.name, mode=config.mode,
        codec=config.codec, rows=rows, seconds=elapsed,
        export_seconds=times["export"], import_seconds=times["import"],
        bytes_moved=exp_stats.bytes_sent if exp_stats else 0,
        errors=messages,
        export_stats=exp_stats, import_stats=stats.get("import"),
    )
    return result, excs


def _run_broadcast_group(eps: List[EdgePlan], query_id: str,
                         recorder: Optional[FlightRecorder] = None,
                         ) -> Dict[str, Tuple[Any, List[BaseException]]]:
    """Run one compiled fan-out group: a SINGLE export of the shared
    source relation into a broadcast shm ring, consumed concurrently by
    every edge's importer from its own cursor slot.  All edges share the
    leader's dataset and this run's ``query_id``; the one export's stats
    land on the leader edge (the other edges carry no export stats — the
    encode genuinely happened once).  Never raises: failures are collected
    per edge (an import failure is that edge's own; an export failure
    fails the whole group)."""
    from .session import TransferResult, adapter_for

    n_readers = len(eps)
    leader = next((ep for ep in eps if ep.broadcast_leader), eps[0])
    src = leader.src_engine
    dataset = leader.dataset
    recorder = recorder if recorder is not None else FlightRecorder(
        name=f"broadcast {dataset}:{query_id}")
    recorder.note("broadcast.start", dataset=dataset, readers=n_readers)
    bcast_ctx = telemetry.current_ctx()
    name = f"db://{dataset}?workers=1&query={query_id}"
    timeout = max(ep.timeout for ep in eps)
    errs: List[Tuple[str, BaseException]] = []  # (edge_id | "export", exc)
    times: Dict[str, float] = {}

    def run_import(ep: EdgePlan) -> None:
        t0 = time.perf_counter()
        cfg = replace(ep.config, transport="shm", broadcast=n_readers,
                      partition=None, fanin=1, streams=1,
                      recorder=recorder,
                      trace_ctx=ep.config.trace_ctx or bcast_ctx)
        try:
            with PipeEnabledEngine(adapter_for(ep.dst_engine)), \
                    PipeOpenContext(cfg):
                ep.dst_engine.import_csv_parallel(ep.dst_table, name,
                                                  workers=1)
        except BaseException as e:  # noqa: BLE001 - aggregated
            errs.append((ep.edge_id, e))
        times[ep.edge_id] = time.perf_counter() - t0

    def run_export() -> None:
        t0 = time.perf_counter()
        cfg = replace(leader.config, partition=None, fanin=1,
                      recorder=recorder,
                      trace_ctx=leader.config.trace_ctx or bcast_ctx)
        try:
            with PipeEnabledEngine(adapter_for(src)), PipeOpenContext(cfg):
                src.export_csv_parallel(
                    leader.table, name, workers=1,
                    header=leader.dst_engine.writes_header,
                    delimiter=leader.dst_engine.csv_delimiter,
                )
        except BaseException as e:  # noqa: BLE001
            errs.append(("export", e))
        times["export"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    imp_threads = [
        threading.Thread(target=run_import, args=(ep,), daemon=True,
                         name=f"pipegen-bcast-{ep.edge_id}")
        for ep in eps
    ]
    te = threading.Thread(target=run_export, daemon=True,
                          name=f"pipegen-bcast-export-{query_id}")
    for t in imp_threads:
        t.start()
    te.start()
    deadline = time.monotonic() + timeout
    for t in imp_threads + [te]:
        t.join(max(0.1, deadline - time.monotonic()))
    elapsed = time.perf_counter() - t0
    stats = collect_stats(dataset, query_id)
    exp_stats = stats.get("export")
    imp_stats = stats.get("import")  # merged across all reader slots
    export_excs = [e for tag, e in errs if tag == "export"]
    out: Dict[str, Tuple[Any, List[BaseException]]] = {}
    for ep, th in zip(eps, imp_threads):
        own = [e for tag, e in errs if tag == ep.edge_id]
        excs = own + export_excs
        messages = [f"import: {e!r}" for e in own]
        messages += [f"export: {e!r}" for e in export_excs]
        if not excs and (th.is_alive() or te.is_alive()):
            stuck = [nm for nm, alive in (("import", th.is_alive()),
                                          ("export", te.is_alive()))
                     if alive]
            excs = [TimeoutError(
                f"broadcast transfer {dataset} did not complete within "
                f"{timeout}s ({'/'.join(stuck)} still running)")]
            messages = [f"timeout: {excs[0]}"]
        for e in excs:  # attach_flight is idempotent on shared excs
            attach_flight(e, recorder)
        try:
            rows = len(ep.dst_engine.get_block(ep.dst_table))
        except KeyError:
            rows = 0
        result = TransferResult(
            source=src.name, target=ep.dst_engine.name,
            mode=leader.mode, codec=leader.codec, rows=rows,
            seconds=elapsed,
            export_seconds=(times.get("export", 0.0)
                            if ep.broadcast_leader else 0.0),
            import_seconds=times.get(ep.edge_id, 0.0),
            bytes_moved=(exp_stats.bytes_sent
                         if exp_stats and ep.broadcast_leader else 0),
            errors=messages,
            export_stats=exp_stats if ep.broadcast_leader else None,
            import_stats=imp_stats if ep.broadcast_leader else None,
        )
        out[ep.edge_id] = (result, excs)
    return out


def run_file_transfer(src: Any, table: str, dst: Any, dst_table: str,
                      workers: int, td: Optional[str] = None):
    """The file-system baseline, shared by ``via='files'`` edges and the
    :func:`~repro.core.session.transfer_via_files` shim.  With ``td``
    (caller-owned spool dir) the part files are kept; otherwise a temp
    dir is created and removed."""
    import os
    import tempfile

    from .session import TransferResult

    own_tmp = td is None
    td = td or tempfile.mkdtemp(prefix="pipegen-fs-")
    base = os.path.join(td, f"{src.name}2{dst.name}.csv")
    t0 = time.perf_counter()
    src.export_csv_parallel(
        table, base, workers=workers,
        header=dst.writes_header, delimiter=dst.csv_delimiter,
    )
    t1 = time.perf_counter()
    # single-worker export writes `base` itself; parallel writes part files
    if workers <= 1:
        if not os.path.exists(base):
            raise FileNotFoundError(base)
        dst.import_csv(dst_table, base)
    else:
        dst.import_csv_parallel(dst_table, base, workers=workers)
    t2 = time.perf_counter()
    bytes_moved = 0
    for fn in os.listdir(td):
        if fn.startswith(os.path.basename(base)):
            bytes_moved += os.path.getsize(os.path.join(td, fn))
    if own_tmp:
        for fn in os.listdir(td):
            os.unlink(os.path.join(td, fn))
        os.rmdir(td)
    rows = len(dst.get_block(dst_table))
    return TransferResult(
        source=src.name, target=dst.name, mode="file-csv", codec="none",
        rows=rows, seconds=t2 - t0,
        export_seconds=t1 - t0, import_seconds=t2 - t1,
        bytes_moved=bytes_moved,
    )


def _run_file_edge(ep: EdgePlan):
    return run_file_transfer(ep.src_engine, ep.table, ep.dst_engine,
                             ep.dst_table, ep.workers), []
