"""Capture phase (paper sections 3.2 and 4.1): run the engine's own
import/export unit tests with every file-open instrumented, record which
call sites touch the test's target file, and eliminate all others.

This is the test-guided discovery that lets PipeGen distinguish the
import/export path from unrelated opens (debug logs, configs).  The JVM
prototype instrumented ``FileInput/OutputStream`` constructors; here the
uniform choke point is ``builtins.open``, which the engines use for all
file IO.
"""

from __future__ import annotations

import builtins
import inspect
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

from .ioredirect import CallSite

__all__ = ["OpenEvent", "CaptureReport", "instrumented_open", "run_capture"]


@dataclass(frozen=True)
class OpenEvent:
    site: CallSite
    filename: str
    mode: str


@dataclass
class CaptureReport:
    """Outcome of one capture run over an engine's unit tests."""

    engine: str = "?"
    events: List[OpenEvent] = field(default_factory=list)
    export_sites: Set[CallSite] = field(default_factory=set)
    import_sites: Set[CallSite] = field(default_factory=set)
    rejected_sites: Set[CallSite] = field(default_factory=set)
    elapsed_s: float = 0.0

    @property
    def sites(self) -> Set[CallSite]:
        return self.export_sites | self.import_sites

    def summary(self) -> str:
        return (
            f"[capture:{self.engine}] {len(self.events)} opens observed, "
            f"{len(self.export_sites)} export + {len(self.import_sites)} import "
            f"sites kept, {len(self.rejected_sites)} unrelated rejected "
            f"({self.elapsed_s:.2f}s)"
        )


_capture_lock = threading.Lock()


def _site_of_caller() -> CallSite:
    # stack[0]=_site_of_caller, [1]=wrapper, [2]=engine code
    fr = inspect.stack()[2]
    return CallSite(fr.frame.f_globals.get("__name__", "?"), fr.function, fr.lineno)


@contextmanager
def instrumented_open(events: List[OpenEvent]):
    """Patch ``builtins.open`` to record (call-site, filename, mode)."""
    real_open = builtins.open

    def recording_open(file, mode="r", *a, **kw):
        try:
            events.append(OpenEvent(_site_of_caller(), str(file), mode))
        except Exception:
            pass  # never let instrumentation break the engine under test
        return real_open(file, mode, *a, **kw)

    with _capture_lock:
        builtins.open = recording_open
        try:
            yield
        finally:
            builtins.open = real_open


def run_capture(
    engine_name: str,
    export_test: Callable[[str], None],
    import_test: Callable[[str], None],
    target_filename: str,
) -> CaptureReport:
    """Execute the engine's export and import unit tests against
    ``target_filename`` with instrumentation, then classify call sites.

    A site is kept iff it was observed opening the target (paper: "all calls
    with filenames other than the target of the import/export are
    eliminated").  Write-ish modes classify it as an export site, read-ish
    as import.
    """
    report = CaptureReport(engine=engine_name)
    t0 = time.perf_counter()
    with instrumented_open(report.events):
        export_test(target_filename)
    n_export_events = len(report.events)
    with instrumented_open(report.events):
        import_test(target_filename)
    report.elapsed_s = time.perf_counter() - t0

    for i, ev in enumerate(report.events):
        on_target = target_filename in ev.filename
        if not on_target:
            report.rejected_sites.add(ev.site)
            continue
        if any(m in ev.mode for m in ("w", "a", "x")):
            report.export_sites.add(ev.site)
        else:
            report.import_sites.add(ev.site)
    # a site both read and written on-target stays in both sets; a site seen
    # on-target is never "rejected"
    report.rejected_sites -= report.sites
    return report
