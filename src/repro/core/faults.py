"""Deterministic, seeded fault injection for the pipe fabric.

Files gave PipeGen's predecessors restartability for free; pipes have to
earn it.  Earning it starts with being able to *cause* every failure the
recovery machinery claims to handle, on demand and reproducibly — not
only via SIGKILL races in multiprocess tests.  This module is that
switchboard: a :class:`FaultPlan` holds seeded rules, the fabric calls
:func:`fire` at a small set of named hook points, and the plan decides
per event whether to kill a peer, drop/corrupt/duplicate a frame, break
a doorbell, fail a ``sendmsg`` with a transient errno, or eat a
directory RPC.

Hook sites (``site`` strings, with the context keys each supplies):

    transport.send      transport=socket|channel|shm|stripe, kind=b"B"...
    transport.recv      transport=socket|channel|shm, kind not yet known
    stream.send         kind (striped fabric, before seq-tagging)
    shm.doorbell.open   (action "break" -> waiter falls back to polling)
    shm.doorbell.ring   (action "drop" skips the ring; "delay" sleeps)
    directory.rpc       op=register|query|renew|... (client side)
    broker.rpc          op=... (DirectoryClient only: "kill" = broker
                        death -> degraded ladder; "stale" = restart ->
                        stale_epoch reject + epoch re-attach)

The hot path stays cheap: every hook site checks ``faults._ACTIVE is
None`` inline before calling in.  With no plan active the cost is one
module-attribute load per frame.

Determinism: rules either fire on the Nth matching event (``at``, a
per-rule counter) or probabilistically via a ``random.Random(seed)``
owned by the plan.  Both are reproducible for a fixed seed and a fixed
per-thread event order; tests that need exact frame arithmetic should
use ``at`` rules.

Injected exceptions:

    InjectedPeerDeath   subclass of BrokenPipeError — a "kill" rule.  The
                        pipe layer treats it as the peer's process dying:
                        the transport is closed (fds die with a process)
                        and the error surfaces to the plan executor,
                        whose retry policy may resume the edge.
    OSError(errno,...)  a "fail_errno" rule (transient sendmsg failure).
"""

from __future__ import annotations

import random
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Tuple

from . import telemetry

__all__ = [
    "FaultPlan",
    "FaultRule",
    "InjectedPeerDeath",
    "fire",
    "active",
    "use",
    "suppressed",
]


class InjectedPeerDeath(BrokenPipeError):
    """A fault-plan "kill": the peer process is gone mid-stream."""


# actions a site must cooperate with (returned from fire()); "kill",
# "errno" and "delay" are handled inside fire() itself.  "stale" is the
# broker-restart verdict: the directory client answers the RPC as a new
# broker incarnation would (stale_epoch reject), driving its re-attach.
_SITE_ACTIONS = frozenset({"drop", "dup", "corrupt", "break", "stale"})


@dataclass
class FaultRule:
    """One injection rule.  ``at`` is 1-based over *matching* events;
    ``at=0`` means every eligible event (gated by ``prob``/``count``)."""

    site: str
    action: str                 # kill|drop|dup|corrupt|delay|errno|break
    at: int = 0
    count: int = 1              # max fires; -1 = unlimited
    prob: float = 1.0           # used only when at == 0
    err: int = 0                # errno for action == "errno"
    delay_s: float = 0.0
    where: Dict[str, Any] = field(default_factory=dict)
    seen: int = 0
    fired: int = 0

    def matches(self, site: str, ctx: Dict[str, Any]) -> bool:
        if site != self.site and not site.startswith(self.site + "."):
            return False
        for k, v in self.where.items():
            if ctx.get(k) != v:
                return False
        return True


class FaultPlan:
    """A seeded set of fault rules plus a log of what actually fired."""

    def __init__(self, seed: int = 0, rules: Iterable[FaultRule] = ()):
        self.seed = seed
        self.rules: List[FaultRule] = list(rules)
        self.events: List[Tuple[str, str, Dict[str, Any]]] = []  # (site, action, ctx)
        self._rng = random.Random(seed)
        self._lock = threading.Lock()

    # -- builders (chainable) -------------------------------------------------
    def add(self, rule: FaultRule) -> "FaultPlan":
        self.rules.append(rule)
        return self

    def kill(self, site: str, at: int = 0, count: int = 1,
             prob: float = 1.0, **where: Any) -> "FaultPlan":
        return self.add(FaultRule(site, "kill", at=at, count=count,
                                  prob=prob, where=where))

    def drop(self, site: str, at: int = 0, count: int = 1,
             prob: float = 1.0, **where: Any) -> "FaultPlan":
        return self.add(FaultRule(site, "drop", at=at, count=count,
                                  prob=prob, where=where))

    def duplicate(self, site: str, at: int = 0, count: int = 1,
                  prob: float = 1.0, **where: Any) -> "FaultPlan":
        return self.add(FaultRule(site, "dup", at=at, count=count,
                                  prob=prob, where=where))

    def corrupt(self, site: str, at: int = 0, count: int = 1,
                prob: float = 1.0, **where: Any) -> "FaultPlan":
        return self.add(FaultRule(site, "corrupt", at=at, count=count,
                                  prob=prob, where=where))

    def delay(self, site: str, delay_s: float, at: int = 0, count: int = -1,
              prob: float = 1.0, **where: Any) -> "FaultPlan":
        return self.add(FaultRule(site, "delay", at=at, count=count,
                                  prob=prob, delay_s=delay_s, where=where))

    def fail_errno(self, site: str, err: int, at: int = 0, count: int = 1,
                   prob: float = 1.0, **where: Any) -> "FaultPlan":
        return self.add(FaultRule(site, "errno", at=at, count=count,
                                  prob=prob, err=err, where=where))

    def break_doorbell(self, count: int = -1) -> "FaultPlan":
        """Make doorbells un-openable: waiters degrade to capped polling."""
        return self.add(FaultRule("shm.doorbell.open", "break", count=count))

    def drop_rpc(self, op: Optional[str] = None, at: int = 0,
                 count: int = 1) -> "FaultPlan":
        where = {"op": op} if op is not None else {}
        return self.add(FaultRule("directory.rpc", "drop", at=at,
                                  count=count, where=where))

    def broker_crash(self, at: int = 0, count: int = 1,
                     op: Optional[str] = None) -> "FaultPlan":
        """The control plane dies under a client RPC: the directory
        client sees a peer death and must walk its degraded-mode ladder
        (fall back to local rendezvous, no-op admission, re-attach when
        probes land)."""
        where = {"op": op} if op is not None else {}
        return self.add(FaultRule("broker.rpc", "kill", at=at, count=count,
                                  where=where))

    def broker_restart(self, at: int = 0, count: int = 1,
                       op: Optional[str] = None) -> "FaultPlan":
        """The broker comes back as a *new incarnation*: the client's
        next RPC is answered with a ``stale_epoch`` reject, forcing it
        to adopt the bumped fencing epoch and replay the op."""
        where = {"op": op} if op is not None else {}
        return self.add(FaultRule("broker.rpc", "stale", at=at, count=count,
                                  where=where))

    # -- introspection --------------------------------------------------------
    def fired(self, site: Optional[str] = None) -> int:
        return sum(1 for s, _a, _c in self.events
                   if site is None or s == site or s.startswith(site + "."))

    # -- the hook entry point -------------------------------------------------
    def _fire(self, site: str, ctx: Dict[str, Any]) -> Optional[str]:
        act = None
        rule = None
        with self._lock:
            for r in self.rules:
                if not r.matches(site, ctx):
                    continue
                r.seen += 1
                if r.count != -1 and r.fired >= r.count:
                    continue
                if r.at:
                    if r.seen != r.at:
                        continue
                elif r.prob < 1.0 and self._rng.random() >= r.prob:
                    continue
                r.fired += 1
                act, rule = r.action, r
                break
            if act is not None:
                self.events.append((site, act, dict(ctx)))
        if act is None:
            return None
        # injected faults always land in the process flight recorder, so
        # a failure's attached timeline shows the fault that caused it
        telemetry.fault_recorder.note(
            "fault.injected", site=site, action=act, nth=rule.seen, **ctx)
        telemetry.counter("faults.fired", site=site, action=act).inc()
        if act == "delay":
            time.sleep(rule.delay_s)
            return None
        if act == "kill":
            raise InjectedPeerDeath(
                f"injected peer death at {site} (event {rule.seen})")
        if act == "errno":
            raise OSError(rule.err, f"injected transient failure at {site}")
        return act  # site-handled: drop / dup / corrupt / break

    # -- activation -----------------------------------------------------------
    def __enter__(self) -> "FaultPlan":
        global _ACTIVE
        self._prev = _ACTIVE
        _ACTIVE = self
        return self

    def __exit__(self, *exc: Any) -> None:
        global _ACTIVE
        _ACTIVE = self._prev


_ACTIVE: Optional[FaultPlan] = None
_local = threading.local()


def active() -> Optional[FaultPlan]:
    return _ACTIVE


@contextmanager
def use(plan: Optional[FaultPlan]):
    """Activate ``plan`` process-wide for the duration of the block."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = plan
    try:
        yield plan
    finally:
        _ACTIVE = prev


@contextmanager
def suppressed():
    """Mask hooks on this thread (used by sites re-entering the send path
    to apply a dup/corrupt verdict without re-firing the rules)."""
    prev = getattr(_local, "off", False)
    _local.off = True
    try:
        yield
    finally:
        _local.off = prev


def fire(site: str, **ctx: Any) -> Optional[str]:
    """Consult the active plan at a hook site.  Returns a site-handled
    action ("drop"/"dup"/"corrupt"/"break") or None; raises for "kill"
    and "errno"; sleeps inline for "delay"."""
    plan = _ACTIVE
    if plan is None or getattr(_local, "off", False):
        return None
    return plan._fire(site, ctx)


def send_plan(transport: str, kind: bytes, segments: Iterable[Any]):
    """Shared send-site helper.  Returns ``None`` when the frame should
    take the normal (zero-copy) path, or a list of replacement payloads
    (0 = drop, 1 = corrupted, 2 = duplicated) the site must send via its
    own plain path under :func:`suppressed`.  May raise (kill/errno)."""
    act = fire("transport.send", transport=transport, kind=kind)
    if act is None or act not in _SITE_ACTIONS:
        return None
    if act == "drop":
        return []
    payload = b"".join(bytes(s) for s in segments)
    if act == "corrupt":
        buf = bytearray(payload)
        if buf:
            buf[len(buf) // 2] ^= 0xFF
        else:
            buf = bytearray(b"\xff")
        return [bytes(buf)]
    return [payload, payload]  # dup
