"""Framed transports for data pipes: TCP sockets and in-process channels.

Frame layout on the wire: 1-byte kind + uint32 little-endian payload length
+ payload.  Kinds:

    S  schema frame (once per stream; json doc, see wire.base.encode_schema)
    T  raw text (IORedirect-only mode)
    P  typed-parts block (binary values, delimiters retained)
    B  encoded ColumnBlock in the stream's wire format
    V  verification payload (probabilistic runtime check, section 4.1)
    E  end of stream
    M  stripe hello (first frame on a striped member connection; see
       repro.core.stream for the striped envelope layered on top)
    R  resume hello (follows the schema frame when the edge is resumable;
       json ``{"epoch": k, "from": n}`` — the exporter announces it will
       send data frames n, n+1, ... so the importer can dedupe overlap)
    D  epoch header (continuous pipes, repro.core.subscribe): json
       ``{"epoch": e, "head": h, "kind": "delta"|"snapshot", "blocks": k,
       "rows": r, "ts": t}`` announcing that the next k B-frames carry
       one committed epoch of a published relation

Scatter-gather send path: :meth:`Transport.send_frames` takes the payload
as a sequence of buffer views (a :class:`~repro.core.iobuf.SegmentList`)
and puts header + segments on the wire with vectored ``socket.sendmsg`` --
no intermediate concatenation.  :meth:`send_frame` remains as the
single-buffer convenience wrapper.

``LinkSim`` emulates a WAN link for the fig. 15 compression study.  Both
transports charge the *full framed size* (header + payload) to the link.
Sleeping is deficit-based and coalesced per transport: owed delay
accumulates and is slept off only once it crosses ``LinkSim.min_sleep_s``,
with actual (over)sleep measured and credited back -- many small frames no
longer oversleep by a scheduler quantum each.
"""

from __future__ import annotations

import queue
import socket
import struct
import threading
import time
from dataclasses import dataclass
from typing import Iterable, Optional, Tuple

from . import faults
from .iobuf import Buffer, _seg_len

__all__ = [
    "FRAME_SCHEMA",
    "FRAME_TEXT",
    "FRAME_PARTS",
    "FRAME_BLOCK",
    "FRAME_VERIFY",
    "FRAME_EOF",
    "FRAME_STRIPE",
    "FRAME_RESUME",
    "FRAME_EPOCH",
    "LinkSim",
    "Transport",
    "SocketTransport",
    "ChannelTransport",
    "Channel",
    "listen_socket",
]

FRAME_SCHEMA = b"S"
FRAME_TEXT = b"T"
FRAME_PARTS = b"P"
FRAME_BLOCK = b"B"
FRAME_VERIFY = b"V"
FRAME_EOF = b"E"
FRAME_STRIPE = b"M"
FRAME_RESUME = b"R"
FRAME_EPOCH = b"D"

_HEADER = struct.Struct("<cI")

# iovecs per sendmsg call: the platform limit when it exposes one (Linux:
# 1024), else the POSIX floor of 16
try:
    import os as _os

    _iov = _os.sysconf("SC_IOV_MAX")
    _IOV_MAX = _iov if _iov > 0 else 1024  # -1 = indeterminate/no limit
except (AttributeError, OSError, ValueError):  # pragma: no cover
    _IOV_MAX = 16


@dataclass
class LinkSim:
    """Simulated link properties applied on send."""

    latency_s: float = 0.0
    bandwidth_bps: float = 0.0  # 0 = unlimited
    min_sleep_s: float = 0.002  # coalesce owed delay below this threshold

    def delay(self, nbytes: int) -> float:
        d = self.latency_s
        if self.bandwidth_bps:
            d += (nbytes * 8.0) / self.bandwidth_bps
        return d


class Transport:
    bytes_sent: int = 0
    frames_sent: int = 0
    link: Optional[LinkSim] = None
    _link_debt: float = 0.0

    def send_frame(self, kind: bytes, payload: Buffer) -> None:
        self.send_frames(kind, (payload,))

    def send_frames(self, kind: bytes, segments: Iterable[Buffer]) -> None:
        """Send one frame whose payload is scattered across ``segments``."""
        raise NotImplementedError

    def recv_frame(self) -> Tuple[bytes, bytes]:
        raise NotImplementedError

    def close(self) -> None:
        pass

    # -- simulated-link accounting (shared by all transports) -------------------
    def _charge_link(self, framed_bytes: int) -> None:
        """Deficit-based coalesced sleep: accumulate owed delay; sleep only
        past the threshold and credit back the measured (over)sleep."""
        link = self.link
        if link is None:
            return
        self._link_debt += link.delay(framed_bytes)
        if self._link_debt >= link.min_sleep_s:
            t0 = time.perf_counter()
            time.sleep(self._link_debt)
            self._link_debt -= time.perf_counter() - t0


class SocketTransport(Transport):
    def __init__(self, sock: socket.socket, link: Optional[LinkSim] = None):
        self.sock = sock
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.link = link
        self._link_debt = 0.0
        self.bytes_sent = 0
        self.frames_sent = 0
        self._rfile = sock.makefile("rb", buffering=1 << 20)

    def send_frames(self, kind: bytes, segments: Iterable[Buffer]) -> None:
        if faults._ACTIVE is not None:
            fp = faults.send_plan("socket", kind, segments)
            if fp is not None:
                with faults.suppressed():
                    for p in fp:
                        self.send_frame(kind, p)
                return
        # flatten to byte-addressable views once; header is its own iovec,
        # so no header+payload concatenation happens anywhere
        iov = []
        payload_len = 0
        for seg in segments:
            n = _seg_len(seg)
            if n == 0:
                continue
            mv = seg if isinstance(seg, memoryview) else memoryview(seg)
            if mv.format != "B" or mv.ndim != 1:
                mv = mv.cast("B")
            iov.append(mv)
            payload_len += n
        iov.insert(0, memoryview(_HEADER.pack(kind, payload_len)))
        total = payload_len + _HEADER.size
        self._charge_link(total)
        self._sendmsg_all(iov, total)
        self.bytes_sent += total
        self.frames_sent += 1

    def _sendmsg_all(self, iov, total: int) -> None:
        """Vectored send with partial-write and IOV_MAX handling."""
        sent_total = 0
        while iov:
            sent = self.sock.sendmsg(iov[:_IOV_MAX])
            sent_total += sent
            # drop fully-sent views, trim a partially-sent head
            while iov and sent >= iov[0].nbytes:
                sent -= iov[0].nbytes
                iov.pop(0)
            if sent and iov:
                iov[0] = iov[0][sent:]
        if sent_total != total:  # pragma: no cover - defensive
            raise IOError(f"short vectored send: {sent_total}/{total}")

    def recv_frame(self) -> Tuple[bytes, bytes]:
        if faults._ACTIVE is not None:
            if faults.fire("transport.recv", transport="socket") == "drop":
                with faults.suppressed():
                    self.recv_frame()  # swallow one frame (receiver-side loss)
        hdr = self._rfile.read(_HEADER.size)
        if not hdr or len(hdr) < _HEADER.size:
            return FRAME_EOF, b""
        kind, ln = _HEADER.unpack(hdr)
        payload = self._rfile.read(ln) if ln else b""
        if payload is None or len(payload) < ln:
            return FRAME_EOF, b""
        return kind, payload

    def close(self) -> None:
        # shutdown BEFORE closing the buffered reader: a receiver thread
        # blocked in _rfile.read() holds the BufferedReader lock, and
        # _rfile.close() would wait on that lock forever.  Shutdown makes
        # the blocked read return EOF, releasing the lock.
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._rfile.close()
        except Exception:
            pass
        self.sock.close()


class Channel:
    """In-process bidirectional rendezvous object (shared-memory analog)."""

    def __init__(self, maxsize: int = 64):
        self.q: "queue.Queue[Tuple[bytes, bytes]]" = queue.Queue(maxsize=maxsize)
        self.closed = threading.Event()


class ChannelTransport(Transport):
    def __init__(self, channel: Channel, link: Optional[LinkSim] = None,
                 owns_channel: bool = True):
        # a shuffle shares one channel across N exporters (the queue is
        # multi-producer-safe); a non-owning writer must not set the closed
        # flag under its still-sending peers -- the importer counts the
        # explicit EOF frames instead (repro.core.stream.FaninTransport)
        self.channel = channel
        self.link = link
        self.owns_channel = owns_channel
        self._link_debt = 0.0
        self.bytes_sent = 0
        self.frames_sent = 0

    def send_frames(self, kind: bytes, segments: Iterable[Buffer]) -> None:
        if faults._ACTIVE is not None:
            fp = faults.send_plan("channel", kind, segments)
            if fp is not None:
                with faults.suppressed():
                    for p in fp:
                        self.send_frame(kind, p)
                return
        # the queue hands the payload to another thread that may consume it
        # after our pooled buffers are recycled, so materialize exactly once
        segs = list(segments)
        if len(segs) == 1:
            payload = bytes(segs[0])
        else:
            payload = b"".join(bytes(s) for s in segs)
        # charge the framed size (header included), matching SocketTransport
        self._charge_link(len(payload) + _HEADER.size)
        # a dead importer closes the channel; blocking forever on a full
        # queue nobody drains would wedge the exporter (the socket analog
        # gets EPIPE from the kernel -- give the channel the same contract).
        # Frames still enqueue while there is room, matching the kernel
        # socket buffer absorbing writes after the peer's close.
        while True:
            try:
                self.channel.q.put((kind, payload), timeout=0.05)
                break
            except queue.Full:
                if self.channel.closed.is_set():
                    raise BrokenPipeError("channel peer closed") from None
        self.bytes_sent += len(payload) + _HEADER.size
        self.frames_sent += 1

    def recv_frame(self) -> Tuple[bytes, bytes]:
        if faults._ACTIVE is not None:
            if faults.fire("transport.recv", transport="channel") == "drop":
                with faults.suppressed():
                    self.recv_frame()  # swallow one frame
        # wake up on channel close even if the peer died without an EOF
        # frame (the socket analog gets this for free from the FIN);
        # queued frames are still drained before the synthetic EOF
        while True:
            try:
                return self.channel.q.get(timeout=0.2)
            except queue.Empty:
                if self.channel.closed.is_set():
                    return FRAME_EOF, b""

    def close(self) -> None:
        if self.owns_channel:
            self.channel.closed.set()


def listen_socket(host: str = "127.0.0.1") -> socket.socket:
    s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    s.bind((host, 0))
    s.listen(16)
    return s
