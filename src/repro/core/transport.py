"""Framed transports for data pipes: TCP sockets and in-process channels.

Frame layout on the wire: 1-byte kind + uint32 little-endian payload length
+ payload.  Kinds:

    S  schema frame (once per stream; json doc, see wire.base.encode_schema)
    T  raw text (IORedirect-only mode)
    P  typed-parts block (binary values, delimiters retained)
    B  encoded ColumnBlock in the stream's wire format
    V  verification payload (probabilistic runtime check, section 4.1)
    E  end of stream

``LinkSim`` emulates a WAN link for the fig. 15 compression study: each
frame send sleeps ``latency + len/bandwidth`` (the paper injected 40 ms into
the adapter; we model the resulting per-message cost directly since both
ends share one host here).
"""

from __future__ import annotations

import queue
import socket
import struct
import threading
import time
from dataclasses import dataclass
from typing import Optional, Tuple

__all__ = [
    "FRAME_SCHEMA",
    "FRAME_TEXT",
    "FRAME_PARTS",
    "FRAME_BLOCK",
    "FRAME_VERIFY",
    "FRAME_EOF",
    "LinkSim",
    "Transport",
    "SocketTransport",
    "ChannelTransport",
    "Channel",
    "listen_socket",
]

FRAME_SCHEMA = b"S"
FRAME_TEXT = b"T"
FRAME_PARTS = b"P"
FRAME_BLOCK = b"B"
FRAME_VERIFY = b"V"
FRAME_EOF = b"E"

_HEADER = struct.Struct("<cI")


@dataclass
class LinkSim:
    """Simulated link properties applied on send."""

    latency_s: float = 0.0
    bandwidth_bps: float = 0.0  # 0 = unlimited

    def delay(self, nbytes: int) -> float:
        d = self.latency_s
        if self.bandwidth_bps:
            d += (nbytes * 8.0) / self.bandwidth_bps
        return d


class Transport:
    def send_frame(self, kind: bytes, payload: bytes) -> None:
        raise NotImplementedError

    def recv_frame(self) -> Tuple[bytes, bytes]:
        raise NotImplementedError

    def close(self) -> None:
        pass

    bytes_sent: int = 0
    frames_sent: int = 0


class SocketTransport(Transport):
    def __init__(self, sock: socket.socket, link: Optional[LinkSim] = None):
        self.sock = sock
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.link = link
        self.bytes_sent = 0
        self.frames_sent = 0
        self._rfile = sock.makefile("rb", buffering=1 << 20)

    def send_frame(self, kind: bytes, payload: bytes) -> None:
        if self.link is not None:
            d = self.link.delay(len(payload) + _HEADER.size)
            if d > 0:
                time.sleep(d)
        self.sock.sendall(_HEADER.pack(kind, len(payload)) + payload)
        self.bytes_sent += len(payload) + _HEADER.size
        self.frames_sent += 1

    def recv_frame(self) -> Tuple[bytes, bytes]:
        hdr = self._rfile.read(_HEADER.size)
        if not hdr or len(hdr) < _HEADER.size:
            return FRAME_EOF, b""
        kind, ln = _HEADER.unpack(hdr)
        payload = self._rfile.read(ln) if ln else b""
        if payload is None or len(payload) < ln:
            return FRAME_EOF, b""
        return kind, payload

    def close(self) -> None:
        try:
            self._rfile.close()
        except Exception:
            pass
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.sock.close()


class Channel:
    """In-process bidirectional rendezvous object (shared-memory analog)."""

    def __init__(self, maxsize: int = 64):
        self.q: "queue.Queue[Tuple[bytes, bytes]]" = queue.Queue(maxsize=maxsize)
        self.closed = threading.Event()


class ChannelTransport(Transport):
    def __init__(self, channel: Channel, link: Optional[LinkSim] = None):
        self.channel = channel
        self.link = link
        self.bytes_sent = 0
        self.frames_sent = 0

    def send_frame(self, kind: bytes, payload: bytes) -> None:
        if self.link is not None:
            d = self.link.delay(len(payload) + _HEADER.size)
            if d > 0:
                time.sleep(d)
        self.channel.q.put((kind, payload))
        self.bytes_sent += len(payload) + _HEADER.size
        self.frames_sent += 1

    def recv_frame(self) -> Tuple[bytes, bytes]:
        kind, payload = self.channel.q.get()
        return kind, payload

    def close(self) -> None:
        self.channel.closed.set()


def listen_socket(host: str = "127.0.0.1") -> socket.socket:
    s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    s.bind((host, 0))
    s.listen(16)
    return s
