"""N→M repartitioning shuffle: route ColumnBlocks from N exporter workers
to M importer workers by key.

The paper's directory design pairs parallel workers 1:1 (section 4.2) —
enough to move a table, but not to *repartition* it: the moment source and
destination disagree on worker count or placement key, every exporter must
feed every importer.  This module supplies the exporter half of that
fabric: a :class:`Partitioner` decides, per row, which importer a row
belongs to, and :class:`ShuffleWriter` — a drop-in for
:class:`~repro.core.datapipe.DataPipeOutput` behind the same reserved-name
``open`` — fans one exporter's output across all M import endpoints
(looked up with :meth:`WorkerDirectory.query_all`, which does not pop).
The import half is :class:`~repro.core.stream.FaninTransport`: each of the
M importers merges the N exporter streams it receives.

Partition specs (``PipeConfig.partition``)::

    "hash"            hash of column 0 (the paper benchmark's unique key)
    "hash:<col>"      hash of the named (or zero-based-index) column
    "range"           range on column 0; bounds come preset from the
                      planner (``PipeConfig.partition_bounds`` — global
                      quantiles sampled once at compile time) or, absent
                      that, from each exporter's first block quantiles
    "range:<col>"     same, named/indexed column
    "rr"              round-robin by row position (no key)

Hashing is a splitmix64 finalizer over the key's 64-bit pattern — the
same function vectorized (numpy ``uint64``) for the block fast path and
scalar for the row path, so both routes place a given key identically.
Floats hash their IEEE bit pattern; strings hash a crc32 of their utf-8
bytes.  Ints/bools use their two's-complement pattern.

Semantics and limits:

* row order *within* one (exporter, importer) stream is preserved; order
  across streams is undefined (a shuffled relation is a bag — verify-
  first-n is disabled on shuffle members for the same reason);
* without preset ``partition_bounds``, range bounds are computed per
  exporter from its first block — approximate when exporters see skewed
  slices; the planner (``repro.core.plan``) samples global quantiles at
  compile time and stamps them into every exporter's config;
* the shm ring is single-producer, so a *shared*-rendezvous shuffle runs
  over ``socket`` or ``channel``; importers that register **slotted**
  fan-in endpoints (one private rendezvous group per exporter, claimed
  via :meth:`WorkerDirectory.next_sender`) lift that limit — each
  (exporter, importer) pair gets its own connection set, which is also
  how ``streams`` stripes each shuffle member pipe across N connections.
  The shared-shm refusal applies to fan-*in* only: fan-*out* over one
  shared segment is the broadcast ring (one writer, R reader cursors;
  ``repro.core.shm_ring``), which the planner compiles fan-out edges
  onto — but it is not a shuffle member (it has no partitioning).
"""

from __future__ import annotations

import threading
import zlib
from dataclasses import replace
from typing import Any, List, Optional, Sequence

import numpy as np

from .astring import AString
from .datapipe import DataPipeOutput, PipeConfig, PipeStats, parse_reserved
from .directory import DirectoryLike, get_directory
from .types import ColType, ColumnBlock

__all__ = [
    "Partitioner",
    "HashPartitioner",
    "RangePartitioner",
    "RoundRobinPartitioner",
    "parse_partition",
    "compute_range_bounds",
    "split_block",
    "ShuffleWriter",
]

_M64 = (1 << 64) - 1


def _mix64(x: int) -> int:
    """splitmix64 finalizer (scalar twin of :func:`_mix64_np`)."""
    x &= _M64
    x ^= x >> 33
    x = (x * 0xFF51AFD7ED558CCD) & _M64
    x ^= x >> 33
    x = (x * 0xC4CEB9FE1A85EC53) & _M64
    x ^= x >> 33
    return x


def _mix64_np(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64, copy=True)
    with np.errstate(over="ignore"):
        x ^= x >> np.uint64(33)
        x *= np.uint64(0xFF51AFD7ED558CCD)
        x ^= x >> np.uint64(33)
        x *= np.uint64(0xC4CEB9FE1A85EC53)
        x ^= x >> np.uint64(33)
    return x


def _hash_value(v: Any) -> int:
    """64-bit hash of one cell, consistent with the vectorized path."""
    if isinstance(v, AString):
        v = v.sole_value
    if isinstance(v, (bool, np.bool_)):
        return _mix64(int(v))
    if isinstance(v, (int, np.integer)):
        return _mix64(int(v) & _M64)
    if isinstance(v, (float, np.floating)):
        bits = np.float64(v).view(np.uint64)
        return _mix64(int(bits))
    s = str(v)
    return _mix64(zlib.crc32(s.encode("utf-8", "surrogatepass")))


def _hash_column(col: Any, ctype: ColType) -> np.ndarray:
    if ctype is ColType.STRING:
        return np.fromiter(
            (_mix64(zlib.crc32(str(s).encode("utf-8", "surrogatepass")))
             for s in col),
            dtype=np.uint64, count=len(col))
    arr = np.asarray(col)
    if ctype in (ColType.FLOAT32, ColType.FLOAT64):
        # hash the float64 bit pattern (float32 widens exactly), matching
        # the scalar row path which sees python floats
        return _mix64_np(arr.astype(np.float64).view(np.uint64))
    return _mix64_np(arr.astype(np.int64).astype(np.uint64))


def _resolve_key(key: Any, block: ColumnBlock) -> int:
    if isinstance(key, int):
        return key
    try:
        return block.schema.index_of(str(key))
    except KeyError:
        raise KeyError(
            f"partition key {key!r} not in schema {block.schema!r}") from None


class Partitioner:
    """Maps rows to one of ``m`` importer workers."""

    def indices(self, block: ColumnBlock, m: int) -> np.ndarray:
        """Partition id per row (the block fast path)."""
        raise NotImplementedError

    def part_of_row(self, key_cell: Any, m: int) -> int:
        """Partition id of one row given its key cell (the row path)."""
        raise NotImplementedError


class HashPartitioner(Partitioner):
    def __init__(self, key: Any = 0):
        self.key = key

    def indices(self, block: ColumnBlock, m: int) -> np.ndarray:
        k = _resolve_key(self.key, block)
        h = _hash_column(block.columns[k], block.schema[k].type)
        return (h % np.uint64(m)).astype(np.int64)

    def part_of_row(self, key_cell: Any, m: int) -> int:
        return _hash_value(key_cell) % m


class RoundRobinPartitioner(Partitioner):
    """Position-based spread; stateful so consecutive blocks keep cycling."""

    def __init__(self):
        self._count = 0

    def indices(self, block: ColumnBlock, m: int) -> np.ndarray:
        n = len(block)
        out = (np.arange(self._count, self._count + n) % m).astype(np.int64)
        self._count += n
        return out

    def part_of_row(self, key_cell: Any, m: int) -> int:
        p = self._count % m
        self._count += 1
        return p


class RangePartitioner(Partitioner):
    """Range split on a key column.

    With preset ``bounds`` (the planner's global compile-time quantiles —
    ``m - 1`` split points, numeric or string) every exporter places every
    row identically, and the row-serialized path works too.  Without
    bounds each exporter falls back to fixing them from its *own* first
    block's quantiles — approximate under skewed input slices, and block
    export only."""

    def __init__(self, key: Any = 0, bounds: Optional[Sequence[Any]] = None):
        self.key = key
        self._bounds: Optional[np.ndarray] = None
        self._str_bounds: Optional[List[str]] = None
        self.preset = bounds is not None
        if bounds is not None:
            vals = list(bounds)
            if vals and isinstance(vals[0], str):
                self._str_bounds = [str(v) for v in vals]
            else:
                self._bounds = np.asarray(vals, dtype=np.float64)

    def indices(self, block: ColumnBlock, m: int) -> np.ndarray:
        k = _resolve_key(self.key, block)
        col = block.columns[k]
        if block.schema[k].type is ColType.STRING:
            vals = [str(s) for s in col]
            if self._str_bounds is None:
                srt = sorted(vals)
                self._str_bounds = [srt[len(srt) * i // m]
                                    for i in range(1, m)] if srt else []
            import bisect

            return np.fromiter(
                (min(bisect.bisect_right(self._str_bounds, v), m - 1)
                 for v in vals),
                dtype=np.int64, count=len(vals))
        arr = np.asarray(col, dtype=np.float64)
        if self._bounds is None:
            qs = [i / m for i in range(1, m)]
            self._bounds = (np.quantile(arr, qs) if len(arr)
                            else np.zeros(m - 1))
        idx = np.searchsorted(self._bounds, arr, side="right").astype(np.int64)
        return np.minimum(idx, m - 1)

    def part_of_row(self, key_cell: Any, m: int) -> int:
        if not self.preset:
            raise ValueError(
                "range partitioning without preset bounds needs block "
                "export (bounds come from block quantiles); compile the "
                "transfer through a plan, or use hash/rr for "
                "row-serialized modes")
        import bisect

        if isinstance(key_cell, AString):
            key_cell = key_cell.sole_value
        if self._str_bounds is not None:
            return min(bisect.bisect_right(self._str_bounds, str(key_cell)),
                       m - 1)
        try:
            v = float(key_cell)
        except (TypeError, ValueError):
            raise ValueError(
                f"range key {key_cell!r} is not numeric but the preset "
                f"bounds are") from None
        return min(int(np.searchsorted(self._bounds, v, side="right")), m - 1)


def parse_partition(spec: str,
                    bounds: Optional[Sequence[Any]] = None) -> Partitioner:
    """``hash[:col] | range[:col] | rr`` → a Partitioner instance.
    ``bounds`` presets the range split points (planner-computed global
    quantiles); it is ignored for the keyless/hash kinds."""
    kind, _, key = str(spec).partition(":")
    kind = kind.strip().lower()
    key_val: Any = key.strip() if key.strip() else 0
    if isinstance(key_val, str) and key_val.lstrip("-").isdigit():
        key_val = int(key_val)
    if kind == "hash":
        return HashPartitioner(key_val)
    if kind == "range":
        return RangePartitioner(key_val, bounds=bounds)
    if kind in ("rr", "roundrobin", "round-robin"):
        return RoundRobinPartitioner()
    raise ValueError(
        f"unknown partition spec {spec!r}; have hash[:col], range[:col], rr")


def compute_range_bounds(block: ColumnBlock, key: Any, m: int) -> List[Any]:
    """Global range split points for ``m`` partitions: ``m - 1`` quantile
    bounds of the key column over the *whole* relation.  The planner calls
    this once at compile time and stamps the result into every exporter's
    ``PipeConfig.partition_bounds``, so N exporters agree on the split no
    matter how skewed their slices are."""
    if m <= 1:
        return []
    k = _resolve_key(key, block)
    col = block.columns[k]
    if block.schema[k].type is ColType.STRING:
        srt = sorted(str(s) for s in col)
        return ([srt[len(srt) * i // m] for i in range(1, m)]
                if srt else [""] * (m - 1))
    arr = np.asarray(col, dtype=np.float64)
    if not len(arr):
        return [0.0] * (m - 1)
    qs = [i / m for i in range(1, m)]
    return [float(b) for b in np.quantile(arr, qs)]


def split_block(block: ColumnBlock, idx: np.ndarray, m: int) -> List[ColumnBlock]:
    """Split ``block`` into ``m`` sub-blocks by per-row partition id.
    Fixed-width columns split with one boolean gather per partition;
    string columns stay python lists."""
    out: List[ColumnBlock] = []
    np_cols = [
        None if f.type is ColType.STRING else np.asarray(c)
        for f, c in zip(block.schema, block.columns)
    ]
    obj_cols = [
        np.asarray(c, dtype=object) if f.type is ColType.STRING else None
        for f, c in zip(block.schema, block.columns)
    ]
    for p in range(m):
        mask = idx == p
        cols: List[Any] = []
        for j, f in enumerate(block.schema):
            if f.type is ColType.STRING:
                cols.append(obj_cols[j][mask].tolist())
            else:
                cols.append(np_cols[j][mask])
        out.append(ColumnBlock(block.schema, cols))
    return out


class ShuffleWriter:
    """Exporter end of the N→M shuffle: one writer that fans a worker's
    output across all M import endpoints, row-routed by the partitioner.

    Substitutable for :class:`DataPipeOutput` behind ``pipegen_open``:
    exposes ``write``/``writelines``/``flush``/``close`` plus the typed
    fast path (``accepts_blocks``/``write_block``).  Typed blocks split
    vectorized; serialized rows (text/parts/assembler modes) are routed
    one row at a time on the key cell, with the first value part of the
    row as key (matching the members' own row parsing).
    """

    def __init__(
        self,
        filename: str,
        config: Optional[PipeConfig] = None,
        directory: Optional[DirectoryLike] = None,
    ):
        rn = parse_reserved(filename)
        if rn is None:
            raise ValueError(f"{filename!r} is not a reserved pipe name")
        self.reserved = rn
        self.config = config or PipeConfig()
        if not self.config.partition:
            raise ValueError("ShuffleWriter needs PipeConfig.partition")
        self.partitioner = parse_partition(
            self.config.partition, bounds=self.config.partition_bounds)
        directory = directory or get_directory()
        endpoints = directory.query_all(
            rn.dataset, rn.query_id, timeout=self.config.connect_timeout)
        if not endpoints:
            raise TimeoutError(f"no import workers for shuffle {rn.dataset!r}")
        # slotted rendezvous (importer registered one private per-exporter
        # slot group — the striped and/or shm wiring): claim one sender
        # index for this exporter and talk to its slot on every importer
        if any(ep.shared and ep.is_group for ep in endpoints):
            sender = directory.next_sender(rn.dataset, rn.query_id)
            resolved = []
            for ep in endpoints:
                if not (ep.shared and ep.is_group):
                    raise IOError(
                        "shuffle importers disagree on the rendezvous "
                        "wiring (slotted vs shared)")
                if sender >= len(ep.members):
                    raise ValueError(
                        f"shuffle declared {len(ep.members)} exporter "
                        f"slots but this is exporter #{sender + 1}")
                resolved.append(ep.members[sender])
            endpoints = resolved
        elif any(ep.is_shm and ep.shared and not ep.broadcast
                 for ep in endpoints):
            raise ValueError(
                "a shared shm ring cannot take multiple exporters "
                "(single-producer); the importer must register slotted "
                "endpoints (it does when fanin > 1 and transport='shm')")
        elif any(ep.broadcast for ep in endpoints):
            # fan-OUT over shared shm is legal (one writer, R reader
            # cursors — the planner's broadcast path), but it is not a
            # shuffle: a partitioned transfer sends each importer a
            # different row subset, a broadcast ring delivers every frame
            # to every reader
            raise ValueError(
                "shuffle members cannot be broadcast rings; fan-out over "
                "shared shm compiles through the planner's broadcast "
                "groups (one export per fan-out), not the partitioned "
                "shuffle")
        # members are plain 1:1 pipes: no nested partitioning, no verify
        # (row order across sources is undefined), striping composes at the
        # member level whenever the importer's slot is a group endpoint
        member_cfg = replace(self.config, partition=None, fanin=1,
                             verify_first_n=0)
        self._members: List[DataPipeOutput] = []
        try:
            for ep in endpoints:
                self._members.append(
                    DataPipeOutput(filename, config=member_cfg, endpoint=ep))
        except BaseException:
            for mem in self._members:
                try:
                    mem.close()
                except Exception:
                    pass
            raise
        self.m = len(self._members)
        self.closed = False
        self.stats = PipeStats()
        # row-path state (mirrors DataPipeOutput._write_parts / text buffer)
        self._cur_parts: List[Any] = []
        self._text_tail = ""

    # -- typed fast path ---------------------------------------------------------
    def accepts_blocks(self) -> bool:
        return not self.closed and self._members[0].accepts_blocks()

    def write_block(
        self,
        block: ColumnBlock,
        header: Optional[Sequence[str]] = None,
        delimiter: Optional[str] = None,
    ) -> int:
        if self.closed:
            raise ValueError("write to closed shuffle pipe")
        idx = self.partitioner.indices(block, self.m)
        # empty sub-blocks still go out: the schema frame travels, so every
        # importer unblocks and learns the relation even with heavy skew
        for member, sub in zip(self._members, split_block(block, idx, self.m)):
            member.write_block(sub, header=header, delimiter=delimiter)
        return len(block)

    # -- row path (text / parts / assembler modes) -------------------------------
    def write(self, s: Any) -> int:
        if self.closed:
            raise ValueError("write to closed shuffle pipe")
        if self.config.mode == "text":
            return self._write_text(s)
        parts = s.parts if isinstance(s, AString) else (str(s),)
        for p in parts:
            if isinstance(p, str) and p.endswith("\n"):
                if p[:-1]:
                    self._cur_parts.append(p[:-1])
                self._route_row(self._cur_parts + ["\n"])
                self._cur_parts = []
            else:
                self._cur_parts.append(p)
        return len(parts)

    def writelines(self, lines: Sequence[Any]) -> None:
        for l in lines:
            self.write(l)

    def _route_row(self, parts: List[Any]) -> None:
        """One complete serialized row → the member its key hashes to.
        The key is the row's first *value* part (leading empty literals
        from ``AString.literal("")`` seeds are skipped)."""
        key = next((p for p in parts if not (isinstance(p, str) and p == "")),
                   "")
        p = self.partitioner.part_of_row(key, self.m)
        self._members[p].write(AString(parts))

    def _write_text(self, s: Any) -> int:
        text = str(s)
        self._text_tail += text
        delim = self.config.delimiter or ","
        while True:
            cut = self._text_tail.find("\n")
            if cut < 0:
                break
            line, self._text_tail = (self._text_tail[: cut + 1],
                                     self._text_tail[cut + 1:])
            key = line[:-1].split(delim, 1)[0]
            p = self.partitioner.part_of_row(key, self.m)
            self._members[p].write(line)
        return len(text)

    def flush(self) -> None:
        for member in self._members:
            member.flush()

    def close(self) -> None:
        if self.closed:
            return
        errs: List[BaseException] = []
        try:
            if self._cur_parts:
                self._route_row(self._cur_parts + ["\n"])
                self._cur_parts = []
            if self._text_tail:
                tail, self._text_tail = self._text_tail, ""
                p = self.partitioner.part_of_row(
                    tail.split(self.config.delimiter or ",", 1)[0], self.m)
                self._members[p].write(tail)
        finally:
            self.closed = True
            for member in self._members:
                try:
                    member.close()
                except BaseException as e:  # noqa: BLE001 - first re-raised
                    errs.append(e)
                self.stats.merge(member.stats)
        if errs:
            raise errs[0]

    def __enter__(self) -> "ShuffleWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
