"""Worker directory (paper section 4.2).

Pairs parallel import workers with parallel export workers:

* each importing worker registers an endpoint (host, port) -- or an
  in-process Channel -- under a (dataset, query_id) key and then blocks in
  ``accept`` waiting for its exporter;
* each exporting worker calls :meth:`query`, which blocks until an entry is
  available, pops it, and connects.

N:M mismatches follow the paper:

* importers > exporters: once the declared exporter count has been matched,
  the directory opens a *stub* connection to each orphaned importer that
  immediately signals end-of-file, so the extra importers idle gracefully;
* exporters > importers: the paper leaves this as future work; we raise by
  default and offer an explicit beyond-paper ``multiplex`` mode in which
  surplus exporters round-robin onto existing importer endpoints (importers
  then merge multiple streams).

Beyond the paper's 1:1 pairing, two fabric extensions:

* **multi-endpoint registrations** — an importer striping its pipe across
  N member connections registers one :class:`Endpoint` whose ``members``
  carry the N rendezvous points; ``query`` pops the whole group, so the
  exporter wires a striped sender (``repro.core.stream``) in one match;
* **shuffle lookups** — :meth:`WorkerDirectory.query_all` returns *every*
  registered importer endpoint for a query without popping, once the
  declared importer count has registered.  N exporters each connect to
  all M importers (the N→M repartitioning of ``repro.core.fabric``);
  importers merge the N streams and the entries are never consumed, so
  the stub machinery stays out of the way (no exporter count is declared
  on this path).

Hygiene: every registration is stamped with the registrant's pid.  Entries
whose registrant died (unclean worker exit) are garbage-collected on the
query paths and on :meth:`reset` — including unlinking any shared-memory
ring segments they left behind — so a crashed importer cannot poison later
transfers for the same dataset with stale endpoints.

Per-query identifiers disambiguate concurrent transfers between the same
pair of engines.  A TCP ``DirectoryServer``/``DirectoryClient`` pair extends
the same API across processes (used by the multi-process examples).
"""

from __future__ import annotations

import json
import os
import queue
import socket
import threading
import time
from dataclasses import dataclass, field, replace as _dc_replace
from typing import Any, Dict, List, Optional, Tuple, Union

from . import faults
from . import telemetry
from .transport import (
    FRAME_EOF,
    Channel,
    ChannelTransport,
    SocketTransport,
)

__all__ = [
    "Endpoint",
    "WorkerDirectory",
    "DirectoryServer",
    "DirectoryClient",
    "LeaseRenewer",
    "live_renewers",
    "get_directory",
    "set_directory",
]


@dataclass(frozen=True)
class Endpoint:
    """An importer's rendezvous point.

    ``members`` makes this a *multi-endpoint registration*: the importer
    stripes its pipe across ``len(members)`` connections and the exporter
    must connect to every member, in order (``repro.core.stream``).
    ``shared`` marks a rendezvous that multiple exporters connect to
    concurrently (the shuffle's fan-in over one in-process channel), so a
    finishing exporter must not tear it down under its peers.
    ``broadcast`` marks a shm *broadcast ring*: one writer, ``broadcast``
    reader cursor slots — the exporter sends every frame once and R
    colocated importers consume it from the same segment.  ``pid`` is
    the registrant, stamped by the directory for dead-worker GC.

    ``resume_seq``/``resume_epoch`` carry the importer's acknowledged
    data-frame watermark into a re-registration after a failed attempt:
    the exporter that pops this endpoint restarts its stream from
    ``resume_seq`` (and says so in a RESUME hello) instead of frame 0.
    ``lease_deadline`` is stamped by the directory (its own monotonic
    clock) when leases are enabled; an entry whose lease expires without
    a renewal is GC'd exactly like a dead registrant.
    """

    host: str = ""
    port: int = 0
    channel: Optional[Channel] = None  # in-process fast path
    shm_name: str = ""                 # shared-memory ring (cross-process)
    shm_capacity: int = 0
    members: Tuple["Endpoint", ...] = ()  # striped group (one per stream)
    shared: bool = False               # multiple exporters attach (shuffle)
    broadcast: int = 0                 # shm fan-out: reader slot count
    pid: int = 0                       # registrant, for dead-worker GC
    resume_seq: int = 0                # acked data frames (resumed edges)
    resume_epoch: int = 0              # attempt number of this registration
    lease_deadline: float = 0.0        # directory-stamped TTL (0 = no lease)
    trace: str = ""                    # importer's "trace_id:span_id" ctx
    bepoch: int = 0                    # broker incarnation that granted it

    @property
    def is_channel(self) -> bool:
        return self.channel is not None

    @property
    def is_shm(self) -> bool:
        return bool(self.shm_name)

    @property
    def is_group(self) -> bool:
        return bool(self.members)


@dataclass
class _QueryState:
    entries: List[Endpoint] = field(default_factory=list)
    popped: int = 0
    registered: int = 0
    export_workers: Optional[int] = None  # declared via db://X?workers=N
    import_workers: Optional[int] = None
    stubbed: bool = False
    senders: int = 0  # slot indexes handed out (striped/shm shuffles)
    # broadcast fan-out rendezvous: R importers share one shm ring.  The
    # first joiner creates the ring (slot 0) and publishes its endpoint;
    # later joiners block on the publication and attach slots 1..R-1.
    bc_total: int = 0       # declared reader count
    bc_joined: int = 0      # slots handed out so far
    bc_ep: Optional[Endpoint] = None  # published ring endpoint


class WorkerDirectory:
    """In-process, thread-safe worker directory.

    ``lease_ttl`` (seconds) puts every registration on a lease: the
    directory stamps a deadline at register time, live peers extend it
    with :meth:`renew`, and :meth:`_gc_dead_locked` treats an expired
    lease exactly like a dead registrant — the entry is dropped and its
    shm segment/doorbell fifos are released.  Leases catch what the pid
    probe cannot: hung-but-alive registrants, and (behind a
    DirectoryServer) registrants on hosts where a local pid probe is
    meaningless."""

    def __init__(self, multiplex: bool = False,
                 lease_ttl: Optional[float] = None):
        self._lock = threading.Condition()
        self._queries: Dict[Tuple[str, str], _QueryState] = {}
        self.multiplex = multiplex
        self.lease_ttl = lease_ttl
        self._all_popped: Dict[Tuple[str, str], List[Endpoint]] = {}
        self._names: Dict[str, Dict[str, Any]] = {}  # named publications
        self._closing = False
        # broker fencing epoch: 0 = plain directory (no fencing).  A
        # broker stamps its incarnation here; every registration then
        # carries it (Endpoint.bepoch) and the DirectoryServer rejects
        # RPCs pinned to a different incarnation.
        self.epoch = 0
        # state-delta hook: callable(kind, doc) invoked OUTSIDE the lock
        # after each journalable mutation (the broker's journal feed)
        self.observer: Optional[Any] = None

    def _notify(self, kind: str, doc: Dict[str, Any]) -> None:
        obs = self.observer
        if obs is not None:
            try:
                obs(kind, doc)
            except Exception:  # pragma: no cover - journal must never wedge RPCs
                pass

    def interrupt(self) -> None:
        """Permanently wake every blocked rendezvous wait so it raises
        ``TimeoutError`` now instead of running out its full timeout —
        a DirectoryServer/broker shutting down must be able to join its
        bounded handler pool without waiting out 30 s query waits."""
        with self._lock:
            self._closing = True
            self._lock.notify_all()

    def resume(self) -> None:
        """Undo :meth:`interrupt` — a restarted broker reuses its
        directory object, and new rendezvous must be able to block."""
        with self._lock:
            self._closing = False

    def _check_closing_locked(self) -> None:
        if self._closing:
            raise TimeoutError("worker directory is shutting down")

    def _state(self, dataset: str, query_id: str) -> _QueryState:
        return self._queries.setdefault((dataset, query_id), _QueryState())

    def _stamp_lease(self, endpoint: Endpoint,
                     lease_s: Optional[float]) -> Endpoint:
        ttl = lease_s if lease_s else self.lease_ttl
        if ttl:
            endpoint = _dc_replace(
                endpoint, lease_deadline=time.monotonic() + ttl)
        return endpoint

    # -- importer side ---------------------------------------------------------
    def register(
        self,
        dataset: str,
        endpoint: Endpoint,
        query_id: str = "0",
        import_workers: Optional[int] = None,
        lease_s: Optional[float] = None,
    ) -> None:
        _rpc_fault("register")
        if endpoint.pid == 0:
            endpoint = _dc_replace(endpoint, pid=os.getpid())
        if self.epoch and endpoint.bepoch != self.epoch:
            endpoint = _dc_replace(endpoint, bepoch=self.epoch)
        endpoint = self._stamp_lease(endpoint, lease_s)
        with self._lock:
            st = self._state(dataset, query_id)
            st.entries.append(endpoint)
            st.registered += 1
            if import_workers is not None:
                st.import_workers = import_workers
            self._lock.notify_all()
            self._maybe_stub_locked(dataset, query_id)
        if self.observer is not None and not _has_channel(endpoint):
            self._notify("register", {
                "dataset": dataset, "query_id": query_id,
                "import_workers": import_workers, "lease_s": lease_s,
                "ep": _ep_to_doc(endpoint)})

    # -- exporter side ---------------------------------------------------------
    def query(
        self,
        dataset: str,
        query_id: str = "0",
        export_workers: Optional[int] = None,
        timeout: float = 30.0,
    ) -> Endpoint:
        """Blocks until an importer endpoint is available, then pops it."""
        _rpc_fault("query")
        deadline = time.monotonic() + timeout
        with self._lock:
            st = self._state(dataset, query_id)
            if export_workers is not None:
                st.export_workers = export_workers
            self._gc_dead_locked(st)
            while not st.entries:
                self._check_closing_locked()
                if (
                    self.multiplex
                    and st.export_workers is not None
                    and st.popped >= (st.import_workers or 0) > 0
                ):
                    # beyond-paper: surplus exporter reuses an earlier endpoint
                    pool = self._all_popped.get((dataset, query_id), [])
                    if pool:
                        ep = pool[st.popped % len(pool)]
                        st.popped += 1
                        return ep
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"no import worker registered for {dataset!r} "
                        f"(query {query_id!r}) within timeout"
                    )
                self._lock.wait(remaining)
                self._gc_dead_locked(st)
            ep = st.entries.pop(0)
            st.popped += 1
            self._all_popped.setdefault((dataset, query_id), []).append(ep)
            self._maybe_stub_locked(dataset, query_id)
        if self.observer is not None and not _has_channel(ep):
            self._notify("pop", {"dataset": dataset, "query_id": query_id,
                                 "ep": _ep_to_doc(ep)})
        return ep

    def query_all(
        self,
        dataset: str,
        query_id: str = "0",
        timeout: float = 30.0,
    ) -> List[Endpoint]:
        """Every importer endpoint for a shuffle, *without* popping.

        Blocks until the declared importer count (``import_workers`` from
        the registrations) has registered, then returns the whole set; the
        entries stay, so each of the N exporters gets the same M endpoints
        and connects to all of them.
        """
        deadline = time.monotonic() + timeout
        with self._lock:
            st = self._state(dataset, query_id)
            while True:
                self._check_closing_locked()
                self._gc_dead_locked(st)
                want = st.import_workers
                if want is not None and len(st.entries) >= want:
                    return list(st.entries)
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"shuffle on {dataset!r} (query {query_id!r}): "
                        f"{len(st.entries)} of {want or '?'} import workers "
                        f"registered within timeout"
                    )
                self._lock.wait(remaining)

    # -- broadcast fan-out (one shm ring, R reader slots) ------------------------
    def join_broadcast(
        self,
        dataset: str,
        query_id: str = "0",
        readers: int = 0,
        timeout: float = 30.0,
    ) -> Tuple[int, Optional[Endpoint]]:
        """Claim a reader slot of the broadcast ring for this transfer.

        The first joiner gets ``(0, None)``: it must create the ring and
        :meth:`publish_broadcast` its endpoint.  Later joiners block until
        publication and get ``(slot, endpoint)``.  Every joiner must
        declare the same ``readers`` count (the ring's slot table size).
        A joiner that times out waiting for the publication returns its
        slot, so a retried transfer is not starved of slots; a *creator*
        that dies between join and publish is not recoverable under the
        same (dataset, query) — use fresh query ids per attempt (the plan
        executor always does).
        """
        deadline = time.monotonic() + timeout
        with self._lock:
            st = self._state(dataset, query_id)
            if st.bc_total == 0:
                st.bc_total = readers
            elif readers and st.bc_total != readers:
                raise IOError(
                    f"broadcast on {dataset!r} (query {query_id!r}): "
                    f"readers disagree on the slot count "
                    f"({st.bc_total} vs {readers})")
            slot = st.bc_joined
            if slot >= st.bc_total:
                raise IOError(
                    f"broadcast on {dataset!r} (query {query_id!r}): "
                    f"all {st.bc_total} reader slots already claimed")
            st.bc_joined += 1
            if slot == 0:
                return 0, None
            while st.bc_ep is None:
                self._check_closing_locked()
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    # give the slot back for a retry — but only if it is
                    # the most recently issued one (returning an inner
                    # index could hand it out again while a later-slotted
                    # joiner is still waiting on the same publication)
                    if slot == st.bc_joined - 1:
                        st.bc_joined -= 1
                    raise TimeoutError(
                        f"broadcast ring for {dataset!r} (query "
                        f"{query_id!r}) was not published within timeout")
                self._lock.wait(remaining)
            return slot, st.bc_ep

    def publish_broadcast(
        self,
        dataset: str,
        endpoint: Endpoint,
        query_id: str = "0",
        import_workers: Optional[int] = None,
    ) -> None:
        """Publish the broadcast ring's endpoint: wakes the waiting
        joiners *and* registers it as a normal entry so the (single)
        exporter's :meth:`query` finds it."""
        if endpoint.pid == 0:
            endpoint = _dc_replace(endpoint, pid=os.getpid())
        with self._lock:
            st = self._state(dataset, query_id)
            st.bc_ep = endpoint
            self._lock.notify_all()
        self.register(dataset, endpoint, query_id,
                      import_workers=import_workers)

    def next_sender(self, dataset: str, query_id: str = "0") -> int:
        """Claim the next exporter *slot index* for a slotted shuffle.

        Importers that register slotted fan-in endpoints (a ``shared``
        group whose members are per-exporter rendezvous slots) need every
        exporter to pick a distinct slot; this hands out 0, 1, 2, …
        atomically per (dataset, query).
        """
        with self._lock:
            st = self._state(dataset, query_id)
            idx = st.senders
            st.senders += 1
            return idx

    # -- named publications (continuous pipes, repro.core.subscribe) --------------
    def _name_dead_locked(self, rec: Dict[str, Any], now: float) -> bool:
        if rec["lease_deadline"] and now > rec["lease_deadline"]:
            return True
        pid = rec["pid"]
        if pid <= 0 or pid == os.getpid():
            return False
        from .shm_ring import _pid_alive

        return not _pid_alive(pid)

    def publish_name(self, name: str, doc: Dict[str, Any],
                     lease_s: Optional[float] = None) -> None:
        """Register (or re-register, healing a crash) the publication
        ``name``.  ``doc`` is the publisher's JSON-serializable rendezvous
        record — subscribers :meth:`lookup_name` it to learn which
        (dataset, query) to register their endpoints under.  Like every
        registration it is pid-stamped and, with a lease, GC'd when the
        publisher stops renewing."""
        _rpc_fault("publish_name")
        ttl = lease_s if lease_s else self.lease_ttl
        rec = {"doc": dict(doc),
               "pid": int(doc.get("pid") or os.getpid()),
               "lease_deadline": (time.monotonic() + ttl) if ttl else 0.0}
        with self._lock:
            self._names[name] = rec
            self._lock.notify_all()
        self._notify("publish_name", {"name": name, "doc": dict(doc),
                                      "pid": rec["pid"], "lease_s": lease_s})

    def lookup_name(self, name: str, timeout: float = 30.0) -> Dict[str, Any]:
        """Block until the publication ``name`` exists (with a live,
        unexpired publisher), then return its doc."""
        _rpc_fault("lookup_name")
        deadline = time.monotonic() + timeout
        with self._lock:
            while True:
                self._check_closing_locked()
                rec = self._names.get(name)
                if (rec is not None
                        and self._name_dead_locked(rec, time.monotonic())):
                    del self._names[name]
                    rec = None
                if rec is not None:
                    return dict(rec["doc"])
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"no publication named {name!r} within timeout")
                self._lock.wait(remaining)

    def unpublish_name(self, name: str, pid: Optional[int] = None) -> bool:
        """Withdraw ``name`` (publisher-owned: a different pid's entry is
        left alone, so a restarted publisher's re-publication is never
        torn down by its dead predecessor's close path)."""
        pid = pid or os.getpid()
        removed = False
        with self._lock:
            rec = self._names.get(name)
            if rec is not None and rec["pid"] == pid:
                del self._names[name]
                removed = True
        if removed:
            self._notify("unpublish_name", {"name": name, "pid": pid})
        return removed

    def renew_name(self, name: str, pid: Optional[int] = None,
                   lease_s: Optional[float] = None) -> int:
        """Lease heartbeat for a named publication; same contract as
        :meth:`renew` — 0 strictly means the entry is gone (expired, GC'd,
        or replaced by another publisher) and the caller must re-publish."""
        _rpc_fault("renew_name")
        pid = pid or os.getpid()
        ttl = lease_s if lease_s else self.lease_ttl
        if not ttl:
            return 0
        with self._lock:
            rec = self._names.get(name)
            if rec is None or rec["pid"] != pid:
                return 0
            if self._name_dead_locked(rec, time.monotonic()):
                del self._names[name]
                return 0
            rec["lease_deadline"] = time.monotonic() + ttl
            return 1

    def list_names(self) -> Dict[str, Dict[str, Any]]:
        """Live publications (dead/expired publishers GC'd on the way)."""
        with self._lock:
            now = time.monotonic()
            for n in [n for n, rec in self._names.items()
                      if self._name_dead_locked(rec, now)]:
                del self._names[n]
            return {n: dict(rec["doc"]) for n, rec in self._names.items()}

    # -- stub handling (importers > exporters) ----------------------------------
    def _maybe_stub_locked(self, dataset: str, query_id: str) -> None:
        st = self._state(dataset, query_id)
        if st.export_workers is None or st.stubbed:
            return
        if st.popped >= st.export_workers and st.entries:
            self._gc_dead_locked(st)  # never stub a dead importer's endpoint
            want = st.import_workers
            if want is None or st.registered >= want:
                orphans = list(st.entries)
                st.entries.clear()
                st.stubbed = True
                for ep in orphans:
                    threading.Thread(
                        target=_send_stub_eof, args=(ep,), daemon=True
                    ).start()

    # -- leases ------------------------------------------------------------------
    def renew(self, dataset: str, query_id: str = "0",
              pid: Optional[int] = None,
              lease_s: Optional[float] = None) -> int:
        """Extend the lease on every entry ``pid`` registered under
        (dataset, query).  Returns the number of registrations touched.
        0 strictly means *the lease already expired and was GC'd — the
        caller must re-register*: an endpoint that was popped by its
        exporter (rendezvous already happened, nothing left to keep
        alive) counts as touched, so heartbeaters can treat 0 as fatal
        without racing the pop."""
        _rpc_fault("renew")
        pid = pid or os.getpid()
        ttl = lease_s if lease_s else self.lease_ttl
        if not ttl:
            return 0
        deadline = time.monotonic() + ttl
        renewed = 0
        with self._lock:
            st = self._queries.get((dataset, query_id))
            if st is None:
                # no live query state, but the endpoint may have been
                # popped (rendezvous done) — including by a pre-crash
                # incarnation whose journal restored only the popped pool
                for ep in self._all_popped.get((dataset, query_id), ()):
                    if ep.pid == pid:
                        return 1
                return 0
            for i, ep in enumerate(st.entries):
                if ep.pid == pid and ep.lease_deadline:
                    st.entries[i] = _dc_replace(ep, lease_deadline=deadline)
                    renewed += 1
            if (st.bc_ep is not None and st.bc_ep.pid == pid
                    and st.bc_ep.lease_deadline):
                st.bc_ep = _dc_replace(st.bc_ep, lease_deadline=deadline)
                renewed += 1
            if renewed == 0:
                for ep in self._all_popped.get((dataset, query_id), ()):
                    if ep.pid == pid:
                        return 1  # popped: the transfer is past rendezvous
        if renewed and self.observer is not None:
            self._notify("renew", {"dataset": dataset, "query_id": query_id,
                                   "pid": pid, "lease_s": lease_s})
        return renewed

    def sweep(self, orphan_min_age_s: float = 30.0) -> List[str]:
        """Lease/death sweep across every query state, then the shm crash
        sweep: segments whose every registered pid is dead, and doorbell
        fifos whose segment is gone, are unlinked even when no directory
        entry ever pointed at them (a worker can die between ring
        creation and registration).  Returns the swept shm/fifo names."""
        with self._lock:
            for st in self._queries.values():
                self._gc_dead_locked(st)
            now = time.monotonic()
            for n in [n for n, rec in self._names.items()
                      if self._name_dead_locked(rec, now)]:
                del self._names[n]
        from .shm_ring import sweep_orphans

        return sweep_orphans(min_age_s=orphan_min_age_s)

    # -- dead-worker hygiene -----------------------------------------------------
    @staticmethod
    def _entry_dead(ep: Endpoint, now: float) -> bool:
        if ep.lease_deadline and now > ep.lease_deadline:
            return True
        return not _registrant_alive(ep)

    def _gc_dead_locked(self, st: _QueryState) -> None:
        """Drop entries registered by processes that no longer exist (or
        whose lease expired) and release the transport resources (shm
        segments *and* doorbell fifos) they leaked.  The published
        broadcast endpoint is swept too: a dead creator's ring must not
        be handed to later joiners, nor leak its segment."""
        now = time.monotonic()
        dead = [ep for ep in st.entries if self._entry_dead(ep, now)]
        if dead:
            st.entries[:] = [ep for ep in st.entries
                             if not self._entry_dead(ep, now)]
            st.registered -= len(dead)
            for ep in dead:
                _release_endpoint(ep)
        if st.bc_ep is not None and self._entry_dead(st.bc_ep, now):
            bc = st.bc_ep
            st.bc_ep = None  # waiting joiners now time out loudly
            if not any(e is bc or (bc.is_shm and e.shm_name == bc.shm_name)
                       for e in dead):
                _release_endpoint(bc)

    # -- bookkeeping -------------------------------------------------------------
    def reset(self, dataset: Optional[str] = None) -> None:
        with self._lock:
            if dataset is None:
                keys = list(self._queries)
            else:
                keys = [k for k in self._queries if k[0] == dataset]
            for k in keys:
                # GC before forgetting: endpoints of dead registrants would
                # otherwise leak their shm segments permanently
                for ep in self._queries[k].entries:
                    if not _registrant_alive(ep):
                        _release_endpoint(ep)
                del self._queries[k]
            for k in [k for k in self._all_popped
                      if dataset is None or k[0] == dataset]:
                del self._all_popped[k]

    # -- journal snapshot (broker checkpoints) -----------------------------------
    def export_state(self) -> Dict[str, Any]:
        """JSON-serializable snapshot of the journalable directory state:
        live registrations, popped endpoints (so post-recovery renews of
        completed rendezvous keep returning 1), and named publications.
        Channel endpoints are process-local by definition and skipped —
        they cannot survive the process they point into."""
        entries: List[Dict[str, Any]] = []
        popped: List[Dict[str, Any]] = []
        with self._lock:
            for (ds, qid), st in self._queries.items():
                for ep in st.entries:
                    if not _has_channel(ep):
                        entries.append({"dataset": ds, "query_id": qid,
                                        "import_workers": st.import_workers,
                                        "ep": _ep_to_doc(ep)})
            for (ds, qid), pool in self._all_popped.items():
                for ep in pool:
                    if not _has_channel(ep):
                        popped.append({"dataset": ds, "query_id": qid,
                                       "ep": _ep_to_doc(ep)})
            names = {n: {"doc": dict(rec["doc"]), "pid": rec["pid"]}
                     for n, rec in self._names.items()}
        return {"entries": entries, "popped": popped, "names": names}

    def restore_state(self, state: Dict[str, Any]) -> None:
        """Re-pin a journal-recovered snapshot: registrations come back
        with *fresh* leases (their registrants get a full TTL to notice
        the new incarnation and start renewing), popped endpoints go
        back to the popped pool, publications are re-published."""
        for rec in state.get("entries", []):
            self.register(rec["dataset"], _ep_from_doc(rec["ep"]),
                          rec.get("query_id", "0"),
                          import_workers=rec.get("import_workers"))
        with self._lock:
            for rec in state.get("popped", []):
                key = (rec["dataset"], rec.get("query_id", "0"))
                self._all_popped.setdefault(key, []).append(
                    _ep_from_doc(rec["ep"]))
        for name, rec in (state.get("names") or {}).items():
            doc = dict(rec.get("doc") or {})
            doc.setdefault("pid", rec.get("pid", 0))
            self.publish_name(name, doc)


def _rpc_fault(op: str) -> None:
    """Fault hook shared by the in-process directory and the RPC client:
    a "drop" rule makes the operation vanish mid-flight."""
    if faults._ACTIVE is not None:
        if faults.fire("directory.rpc", op=op) == "drop":
            raise ConnectionResetError(f"injected: directory {op} dropped")


def _has_channel(ep: Endpoint) -> bool:
    """True when the endpoint (or any striped member) is an in-process
    channel — non-serializable, so never journaled or sent over RPC."""
    return ep.is_channel or any(_has_channel(m) for m in ep.members)


def _registrant_alive(ep: Endpoint) -> bool:
    if ep.pid <= 0 or ep.pid == os.getpid():
        return True
    from .shm_ring import _pid_alive

    return _pid_alive(ep.pid)


def _release_endpoint(ep: Endpoint) -> None:
    """Free what a dead registrant left behind (recursing into striped
    groups): shm segments are unlinked so the name cannot poison a later
    query; sockets/channels need nothing (the OS/GC reclaimed them)."""
    for m in ep.members:
        _release_endpoint(m)
    if ep.is_shm:
        from .shm_ring import ShmRing

        ShmRing.cleanup(ep.shm_name)


def _send_stub_eof(ep: Endpoint) -> None:
    """Open a stub connection that immediately signals end-of-file."""
    try:
        if ep.is_group:
            for m in ep.members:
                _send_stub_eof(m)
        elif ep.is_channel:
            ChannelTransport(ep.channel).send_frame(FRAME_EOF, b"")
        elif ep.is_shm:
            from .shm_ring import ShmRingTransport, attach_ring

            t = ShmRingTransport(attach_ring(ep.shm_name))
            t.send_frame(FRAME_EOF, b"")
            t.close()
        else:
            s = socket.create_connection((ep.host, ep.port), timeout=5.0)
            SocketTransport(s).send_frame(FRAME_EOF, b"")
            s.close()
    except OSError:
        pass


# -- lease renewal, owned by long-lived handles ----------------------------------

_RENEWERS_LOCK = threading.Lock()
_RENEWERS: set = set()


class LeaseRenewer:
    """One lease-heartbeat thread, owned by the handle that holds the
    registration.

    The renewal loop used to be an inline daemon inside
    ``DataPipeInput.__init__`` — scoped (by accident of ownership) to a
    single transfer.  Long-lived subscription rings need renewal until
    explicit unsubscribe, so the renewer is a first-class object: the
    owning handle (``DataPipeInput``, ``Subscription``, ``Publication``)
    creates it, and its ``close()`` calls :meth:`stop`, which *joins* the
    thread.  :func:`live_renewers` counts running loops so tests can
    assert no renewal leak after close.

    ``renew`` is a callable ``(lease_s) -> int`` with the directory's
    renew contract: 0 strictly means the lease expired and the entry was
    GC'd — the loop then sets :attr:`lost`, fires ``on_lost`` once, and
    exits (heartbeating a nonexistent entry forever helps nobody)."""

    def __init__(self, renew: Any, lease_s: float,
                 on_lost: Optional[Any] = None,
                 name: str = "pipegen-lease-renew"):
        self._renew = renew
        self.lease_s = float(lease_s)
        self._on_lost = on_lost
        self._stop = threading.Event()
        self.lost = threading.Event()
        self._thread = threading.Thread(target=self._loop, name=name,
                                        daemon=True)

    def start(self) -> "LeaseRenewer":
        with _RENEWERS_LOCK:
            _RENEWERS.add(self)
        self._thread.start()
        return self

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()

    def _loop(self) -> None:
        period = max(0.05, self.lease_s / 3.0)
        try:
            while not self._stop.wait(period):
                try:
                    n = self._renew(self.lease_s)
                except Exception:
                    return  # directory gone: let the lease lapse
                if n == 0:
                    self.lost.set()
                    cb = self._on_lost
                    if cb is not None:
                        try:
                            cb()
                        except Exception:  # pragma: no cover - callback bug
                            pass
                    return
        finally:
            with _RENEWERS_LOCK:
                _RENEWERS.discard(self)

    def stop(self, join: bool = True, timeout: float = 5.0) -> None:
        self._stop.set()
        if (join and self._thread.ident is not None
                and self._thread is not threading.current_thread()):
            self._thread.join(timeout)


def live_renewers() -> int:
    """Running lease-renewal loops in this process (leak assertions)."""
    with _RENEWERS_LOCK:
        return len(_RENEWERS)


# -- cross-process directory ----------------------------------------------------


def _ep_to_doc(ep: Endpoint) -> dict:
    assert not ep.is_channel, "channels cannot cross processes"
    return {
        "host": ep.host,
        "port": ep.port,
        "shm_name": ep.shm_name,
        "shm_capacity": ep.shm_capacity,
        "shared": ep.shared,
        "broadcast": ep.broadcast,
        "pid": ep.pid,
        "resume_seq": ep.resume_seq,
        "resume_epoch": ep.resume_epoch,
        "trace": ep.trace,
        "bepoch": ep.bepoch,
        "members": [_ep_to_doc(m) for m in ep.members],
    }


def _ep_from_doc(doc: dict) -> Endpoint:
    return Endpoint(
        doc.get("host", ""),
        int(doc.get("port", 0)),
        shm_name=doc.get("shm_name", ""),
        shm_capacity=int(doc.get("shm_capacity", 0)),
        shared=bool(doc.get("shared", False)),
        broadcast=int(doc.get("broadcast", 0)),
        pid=int(doc.get("pid", 0)),
        resume_seq=int(doc.get("resume_seq", 0)),
        resume_epoch=int(doc.get("resume_epoch", 0)),
        trace=str(doc.get("trace", "")),
        bepoch=int(doc.get("bepoch", 0)),
        members=tuple(_ep_from_doc(m) for m in doc.get("members", [])),
    )


class DirectoryServer:
    """Tiny JSON-lines TCP server exposing register/query across processes.

    With ``lease_ttl`` set, registrations are leased and a background
    reaper runs :meth:`WorkerDirectory.sweep` every ``sweep_every``
    seconds (default ttl/2): expired/dead entries are GC'd and orphaned
    shm segments and doorbell fifos crash-swept, so a SIGKILL'd worker's
    leavings disappear within about one TTL instead of accumulating.

    **Handler threads are bounded.**  The accept loop reads each request
    inline (requests are one short JSON line from local peers) and
    answers non-blocking ops — register/renew/publish/next_sender —
    right there; only ops that can legitimately *wait* on the directory
    (query/query_all/join_broadcast) are handed to a fixed pool of
    ``handlers`` worker threads.  The split is what makes a small pool
    deadlock-free: the ops a blocked query is waiting FOR never queue
    behind blocked queries.  An RPC burst therefore costs zero thread
    spawns (the seed spawned one untracked daemon thread per
    connection), and :meth:`stop` can actually join every handle —
    :meth:`WorkerDirectory.interrupt` wakes parked waits first."""

    _BLOCKING_OPS = frozenset({"query", "query_all", "join_broadcast",
                               "lookup_name"})

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 lease_ttl: Optional[float] = None,
                 sweep_every: Optional[float] = None,
                 handlers: int = 8,
                 directory: Optional[WorkerDirectory] = None):
        self.directory = directory or WorkerDirectory(lease_ttl=lease_ttl)
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(64)
        self.host, self.port = self._sock.getsockname()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._serve, daemon=True)
        self._sweep_every = sweep_every or (lease_ttl / 2 if lease_ttl
                                            else None)
        self._reaper: Optional[threading.Thread] = None
        self.handlers = max(1, int(handlers))
        self._work: "queue.Queue" = queue.Queue()
        self._pool: List[threading.Thread] = []
        # introspection: a zero-arg callable returning a JSON-serializable
        # dict, answered by the "stats" op (the broker installs its own
        # stats() here; repro.tools.pipetop polls it)
        self.stats_provider: Optional[Any] = None
        # admission gate: a callable(req) -> resp dict answering the
        # admit/admit_poll/release ops (the broker installs its
        # reservation-based remote admission here).  All three are
        # non-blocking on the broker side — queued admissions are held
        # as reservations the client polls, never as parked handler
        # threads — so they ride the fast inline lane and a burst of
        # 200 queued plans cannot starve the pool that query waits on.
        self.admission_provider: Optional[Any] = None

    def start(self) -> "DirectoryServer":
        for i in range(self.handlers):
            t = threading.Thread(target=self._worker, daemon=True,
                                 name=f"pgdir-handler-{i}")
            t.start()
            self._pool.append(t)
        self._thread.start()
        if self._sweep_every:
            self._reaper = threading.Thread(target=self._reap, daemon=True)
            self._reaper.start()
        return self

    def _reap(self) -> None:
        while not self._stop.wait(self._sweep_every):
            try:
                self.directory.sweep()
            except Exception:  # pragma: no cover - sweep must never kill us
                pass

    def stop(self) -> None:
        self._stop.set()
        self.directory.interrupt()  # unblock parked query waits
        try:
            # close() alone does NOT wake a thread already parked in
            # accept() — the kernel keeps the open file description (and
            # the LISTEN port!) alive until the syscall returns, so the
            # join below would time out and a same-port restart would
            # die with EADDRINUSE.  shutdown() aborts the parked accept.
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        for _ in self._pool:
            self._work.put(None)
        threads = [self._thread] + self._pool
        if self._reaper is not None:
            threads.append(self._reaper)
        for t in threads:
            if t.ident is not None:  # never started: nothing to join
                t.join(timeout=5.0)
        while True:  # orphan any conns still queued behind the sentinels
            try:
                item = self._work.get_nowait()
            except queue.Empty:
                break
            if item is not None:
                _close_quietly(item[0])

    def _serve(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return
            # read inline: one short line from a local peer.  The timeout
            # keeps a wedged client from stalling the accept loop.
            try:
                conn.settimeout(5.0)
                f = conn.makefile("rwb")
                line = f.readline()
                req = json.loads(line) if line else None
            except (OSError, json.JSONDecodeError):
                req = None
            if req is None or "op" not in req:
                _close_quietly(conn)
                continue
            conn.settimeout(None)
            if req["op"] in self._BLOCKING_OPS:
                self._work.put((conn, f, req))
            else:
                self._dispatch(conn, f, req)

    def _worker(self) -> None:
        while True:
            item = self._work.get()
            if item is None:
                return
            self._dispatch(*item)

    def _dispatch(self, conn: socket.socket, f, req: dict) -> None:
        # fencing: a client pinned to a dead incarnation's epoch is told
        # so loudly — its leases, tickets, and registrations died with
        # that incarnation, and acting on its RPCs as if nothing
        # happened is how zombie tickets double-spend budgets.  The
        # reject carries the live epoch so the client can re-attach.
        depoch = getattr(self.directory, "epoch", 0)
        bepoch = int(req.get("bepoch") or 0)
        if depoch and bepoch and bepoch != depoch:
            telemetry.counter("broker.rejects", reason="stale_epoch").inc()
            resp = {"ok": False, "stale_epoch": True, "bepoch": depoch,
                    "error": (f"stale broker epoch {bepoch} "
                              f"(live incarnation is {depoch})")}
            try:
                f.write(json.dumps(resp).encode() + b"\n")
                f.flush()
            except OSError:
                pass
            _close_quietly(conn)
            return
        try:
            if req["op"] == "register":
                self.directory.register(
                    req["dataset"],
                    _ep_from_doc(req),
                    req.get("query_id", "0"),
                    req.get("import_workers"),
                    lease_s=req.get("lease_s"),
                )
                resp = {"ok": True}
            elif req["op"] == "renew":
                n = self.directory.renew(
                    req["dataset"],
                    req.get("query_id", "0"),
                    pid=req.get("pid"),
                    lease_s=req.get("lease_s"),
                )
                resp = {"ok": True, "renewed": n}
            elif req["op"] == "query":
                try:
                    ep = self.directory.query(
                        req["dataset"],
                        req.get("query_id", "0"),
                        req.get("export_workers"),
                        timeout=float(req.get("timeout", 30.0)),
                    )
                except TimeoutError as e:
                    resp = {"ok": False, "error": str(e)}
                else:
                    # Popping an endpoint over RPC is a handoff, and the
                    # requester can die between asking and hearing the
                    # answer (SIGKILL mid-rendezvous leaves its last query
                    # parked in a handler).  Without an ack the pop would
                    # consume a registration that no live process ever
                    # sees — so require a one-line ack and put the
                    # endpoint back if it never comes.
                    self._reply_query(conn, f, req, ep)
                    return
            elif req["op"] == "query_all":
                try:
                    eps = self.directory.query_all(
                        req["dataset"],
                        req.get("query_id", "0"),
                        timeout=float(req.get("timeout", 30.0)),
                    )
                    resp = {"ok": True,
                            "endpoints": [_ep_to_doc(e) for e in eps]}
                except TimeoutError as e:
                    resp = {"ok": False, "error": str(e)}
            elif req["op"] == "join_broadcast":
                try:
                    slot, ep = self.directory.join_broadcast(
                        req["dataset"],
                        req.get("query_id", "0"),
                        int(req.get("readers", 0)),
                        timeout=float(req.get("timeout", 30.0)),
                    )
                    resp = {"ok": True, "slot": slot,
                            "endpoint": _ep_to_doc(ep) if ep else None}
                except (TimeoutError, IOError) as e:
                    resp = {"ok": False, "error": str(e)}
            elif req["op"] == "publish_broadcast":
                self.directory.publish_broadcast(
                    req["dataset"],
                    _ep_from_doc(req["endpoint"]),
                    req.get("query_id", "0"),
                    req.get("import_workers"),
                )
                resp = {"ok": True}
            elif req["op"] == "next_sender":
                resp = {"ok": True,
                        "sender": self.directory.next_sender(
                            req["dataset"], req.get("query_id", "0"))}
            elif req["op"] == "publish_name":
                self.directory.publish_name(
                    req["name"], req.get("doc") or {},
                    lease_s=req.get("lease_s"),
                )
                resp = {"ok": True}
            elif req["op"] == "lookup_name":
                try:
                    doc = self.directory.lookup_name(
                        req["name"], timeout=float(req.get("timeout", 30.0)))
                    resp = {"ok": True, "doc": doc}
                except TimeoutError as e:
                    resp = {"ok": False, "error": str(e)}
            elif req["op"] == "unpublish_name":
                resp = {"ok": True,
                        "removed": self.directory.unpublish_name(
                            req["name"], pid=req.get("pid"))}
            elif req["op"] == "renew_name":
                resp = {"ok": True,
                        "renewed": self.directory.renew_name(
                            req["name"], pid=req.get("pid"),
                            lease_s=req.get("lease_s"))}
            elif req["op"] == "list_names":
                resp = {"ok": True, "names": self.directory.list_names()}
            elif req["op"] == "stats":
                provider = self.stats_provider
                resp = {"ok": True,
                        "stats": provider() if provider is not None else {}}
            elif req["op"] in ("admit", "admit_poll", "release"):
                provider = self.admission_provider
                if provider is None:
                    resp = {"ok": False,
                            "error": "no broker admission behind this "
                                     "directory"}
                else:
                    resp = provider(req)
            else:
                resp = {"ok": False, "error": f"bad op {req['op']!r}"}
        except OSError:
            _close_quietly(conn)
            return
        except Exception as e:  # a bad request must not kill a pooled worker
            resp = {"ok": False, "error": f"{type(e).__name__}: {e}"}
        if depoch and "bepoch" not in resp:
            resp["bepoch"] = depoch  # clients pin the live incarnation
        try:
            f.write(json.dumps(resp).encode() + b"\n")
            f.flush()
        except OSError:
            pass
        finally:
            _close_quietly(conn)

    # how long a popped endpoint may sit un-acked before it is handed back
    QUERY_ACK_S = 2.0

    def _reply_query(self, conn: socket.socket, f, req: dict,
                     ep: Endpoint) -> None:
        """Deliver a popped endpoint with an ack handshake: write the
        response, wait briefly for the client's ``ack`` line, and if the
        client vanished (dead socket, EOF, silence) re-register the
        endpoint so the next live query can still claim it."""
        acked = False
        depoch = getattr(self.directory, "epoch", 0)
        try:
            f.write(json.dumps(
                {"ok": True, "bepoch": depoch,
                 **_ep_to_doc(ep)}).encode() + b"\n")
            f.flush()
            conn.settimeout(self.QUERY_ACK_S)
            acked = f.readline().strip() == b"ack"
        except OSError:
            acked = False
        finally:
            _close_quietly(conn)
        if not acked:
            try:
                self.directory.register(
                    req["dataset"], ep, req.get("query_id", "0"))
            except Exception:  # directory shutting down: nothing to heal
                pass


def _close_quietly(conn: socket.socket) -> None:
    try:
        conn.close()
    except OSError:  # pragma: no cover
        pass


class DirectoryClient:
    """Client with the WorkerDirectory API, speaking to a DirectoryServer.

    Beyond the plain RPC shim, this is the *degraded-mode ladder* of the
    control plane's failure model (see docs/architecture.md):

    1. **Retry** — idempotent ops (renew, stats, register, name ops) get
       one bounded reconnect-and-retry on ECONNRESET/EPIPE, so a broker
       restart mid-RPC surfaces as recovery, not a raw socket error.
    2. **Degrade** — with ``degraded_ok=True``, persistent broker death
       steps the client down instead of failing the plan: new rendezvous
       go to a process-local fallback :class:`WorkerDirectory` (the
       pre-broker per-transfer model), renews of broker-held leases are
       *suspended* (return 1, keeping in-flight frames alive), and
       admission becomes a no-op — all under a ``broker.degraded`` gauge.
    3. **Re-attach** — the client probes the broker every
       ``probe_every`` seconds; the first RPC that lands pins the new
       incarnation's epoch, re-uploads names published while degraded,
       and clears the gauge.  Edges that started on the fallback stay
       *sticky* to it (per (dataset, query) / per name), so a mid-edge
       re-attach cannot split a rendezvous across two directories.

    Epoch fencing: every response from a broker-backed server carries
    its incarnation (``bepoch``); the client pins it into subsequent
    requests.  A ``stale_epoch`` reject means the broker restarted —
    the client adopts the new epoch and retries the op once.
    """

    # safe to re-send after a connection died mid-flight: either
    # naturally idempotent or an at-least-once upsert
    _RETRYABLE_OPS = frozenset({
        "renew", "stats", "register", "renew_name", "publish_name",
        "unpublish_name", "list_names", "publish_broadcast",
        "admit_poll", "release"})

    def __init__(self, host: str, port: int, degraded_ok: bool = False,
                 rpc_retries: int = 1, probe_every: float = 1.0):
        self.addr = (host, port)
        self.degraded_ok = degraded_ok
        self.rpc_retries = max(0, int(rpc_retries))
        self.probe_every = float(probe_every)
        self.epoch = 0          # pinned broker incarnation (0 = unpinned)
        self.degraded = False
        self.reattaches = 0     # recoveries: degraded -> broker regained
        self._fallback: Optional[WorkerDirectory] = None
        self._probe_at = 0.0    # next broker probe while degraded
        self._state_lock = threading.Lock()

    # -- transport ------------------------------------------------------------
    def _rpc_once(self, req: dict, ack: bool = False) -> dict:
        if self.epoch:
            req = {**req, "bepoch": self.epoch}
        s = socket.create_connection(self.addr, timeout=60.0)
        try:
            f = s.makefile("rwb")
            f.write(json.dumps(req).encode() + b"\n")
            f.flush()
            line = f.readline()
            if not line:
                raise ConnectionResetError(
                    "directory server closed the connection mid-RPC")
            resp = json.loads(line)
            if ack and resp.get("ok"):
                # endpoint-pop handoff: confirm receipt so the server
                # knows the endpoint reached a live process (no ack ->
                # restitution)
                try:
                    f.write(b"ack\n")
                    f.flush()
                except OSError:
                    pass
            return resp
        finally:
            _close_quietly(s)

    def _rpc(self, req: dict, ack: bool = False) -> dict:
        op = req.get("op", "?")
        _rpc_fault(op)
        if faults._ACTIVE is not None:
            try:
                injected = faults.fire("broker.rpc", op=op)
            except faults.InjectedPeerDeath as e:
                # broker_crash rule: the control plane just "died" under
                # this RPC — walk the same ladder a real death would
                if not self.degraded_ok:
                    raise
                self._enter_degraded(e)
                return self._fallback_call(req)
            if injected == "stale":
                # broker_restart rule: answer as a new incarnation would
                return self._on_response(
                    req, {"ok": False, "stale_epoch": True,
                          "bepoch": self.epoch + 1}, ack)
        if self._fallback_owns(req):
            return self._fallback_call(req)
        if self.degraded and not self._should_probe():
            return self._fallback_call(req)
        attempts = 1 + (self.rpc_retries if op in self._RETRYABLE_OPS else 0)
        err: Optional[BaseException] = None
        resp: dict = {}
        for _ in range(attempts):
            try:
                resp = self._rpc_once(req, ack)
                err = None
                break
            except (OSError, ValueError) as e:  # conn reset/refused, torn JSON
                err = e
                telemetry.counter("broker.rpc_errors", op=op).inc()
        if err is not None:
            if not self.degraded_ok:
                raise err
            self._enter_degraded(err)
            return self._fallback_call(req)
        return self._on_response(req, resp, ack)

    def _on_response(self, req: dict, resp: dict, ack: bool) -> dict:
        if resp.get("stale_epoch"):
            # the broker restarted under us: adopt the live incarnation
            # and replay the op.  Two rounds bound the recovery: the
            # first reject can carry a since-superseded epoch (a crash
            # loop, an injected restart), the second is authoritative —
            # anything past that is epoch ping-pong, give up loudly.
            telemetry.counter("broker.stale_epoch_seen").inc()
            for _ in range(2):
                with self._state_lock:
                    self.epoch = (int(resp.get("bepoch") or 0)
                                  or self.epoch + 1)
                try:
                    resp = self._rpc_once(req, ack)
                except (OSError, ValueError) as e:
                    if not self.degraded_ok:
                        raise
                    self._enter_degraded(e)
                    return self._fallback_call(req)
                if not resp.get("stale_epoch"):
                    break
            if resp.get("stale_epoch"):
                with self._state_lock:
                    self.epoch = int(resp.get("bepoch") or 0) or self.epoch
                return resp
        bep = int(resp.get("bepoch") or 0)
        if bep:
            with self._state_lock:
                self.epoch = bep
        if self.degraded:
            self._leave_degraded()
        return resp

    # -- the degraded-mode ladder ----------------------------------------------
    def _should_probe(self) -> bool:
        return time.monotonic() >= self._probe_at

    def _ensure_fallback(self) -> WorkerDirectory:
        with self._state_lock:
            if self._fallback is None:
                self._fallback = WorkerDirectory()
            return self._fallback

    def _enter_degraded(self, err: BaseException) -> None:
        first = False
        with self._state_lock:
            self._probe_at = time.monotonic() + self.probe_every
            if self._fallback is None:
                self._fallback = WorkerDirectory()
            if not self.degraded:
                self.degraded = True
                first = True
        if first:
            telemetry.gauge("broker.degraded").set(1)
            telemetry.counter("broker.degradations",
                              error=type(err).__name__).inc()

    def _leave_degraded(self) -> None:
        with self._state_lock:
            if not self.degraded:
                return
            self.degraded = False
            self.reattaches += 1
            fb = self._fallback
        telemetry.gauge("broker.degraded").set(0)
        telemetry.counter("broker.reattach").inc()
        # best effort: names published while degraded are re-uploaded so
        # other processes can find them at the broker again; rendezvous
        # state stays sticky to the fallback until those edges drain
        if fb is not None:
            try:
                for name, doc in fb.list_names().items():
                    self._rpc_once({"op": "publish_name", "name": name,
                                    "doc": doc})
            except (OSError, ValueError):
                pass

    def _fallback_owns(self, req: dict) -> bool:
        """Stickiness: once an edge (or name) has state on the fallback,
        every later op for it stays there — a rendezvous split across
        the fallback and a re-attached broker would never meet."""
        fb = self._fallback
        if fb is None:
            return False
        op = req.get("op")
        if op in ("query", "query_all", "join_broadcast",
                  "publish_broadcast", "next_sender", "renew", "register"):
            key = (req.get("dataset"), req.get("query_id", "0"))
            with fb._lock:
                return key in fb._queries
        if op in ("lookup_name", "renew_name", "unpublish_name"):
            with fb._lock:
                return req.get("name") in fb._names
        return False

    def _fallback_call(self, req: dict) -> dict:
        """Serve the op from the process-local fallback directory (the
        broker-less per-transfer rendezvous model).  Admission becomes a
        no-op — enforcing a dead broker's budgets would just wedge the
        plans the ladder exists to keep draining."""
        fb = self._ensure_fallback()
        op = req.get("op")
        telemetry.counter("broker.fallback_ops", op=str(op)).inc()
        try:
            if op == "register":
                fb.register(req["dataset"], _ep_from_doc(req),
                            req.get("query_id", "0"),
                            req.get("import_workers"),
                            lease_s=req.get("lease_s"))
                return {"ok": True, "degraded": True}
            if op == "renew":
                n = fb.renew(req["dataset"], req.get("query_id", "0"),
                             pid=req.get("pid"), lease_s=req.get("lease_s"))
                if n == 0 and not self._fallback_owns(req):
                    # the lease lives at the unreachable broker: suspend
                    # enforcement instead of aborting in-flight frames
                    n = 1
                return {"ok": True, "renewed": n, "degraded": True}
            if op == "query":
                ep = fb.query(req["dataset"], req.get("query_id", "0"),
                              req.get("export_workers"),
                              timeout=float(req.get("timeout", 30.0)))
                return {"ok": True, "degraded": True, **_ep_to_doc(ep)}
            if op == "query_all":
                eps = fb.query_all(req["dataset"], req.get("query_id", "0"),
                                   timeout=float(req.get("timeout", 30.0)))
                return {"ok": True, "degraded": True,
                        "endpoints": [_ep_to_doc(e) for e in eps]}
            if op == "join_broadcast":
                slot, ep = fb.join_broadcast(
                    req["dataset"], req.get("query_id", "0"),
                    int(req.get("readers", 0)),
                    timeout=float(req.get("timeout", 30.0)))
                return {"ok": True, "degraded": True, "slot": slot,
                        "endpoint": _ep_to_doc(ep) if ep else None}
            if op == "publish_broadcast":
                fb.publish_broadcast(req["dataset"],
                                     _ep_from_doc(req["endpoint"]),
                                     req.get("query_id", "0"),
                                     req.get("import_workers"))
                return {"ok": True, "degraded": True}
            if op == "next_sender":
                return {"ok": True, "degraded": True,
                        "sender": fb.next_sender(req["dataset"],
                                                 req.get("query_id", "0"))}
            if op == "publish_name":
                fb.publish_name(req["name"], req.get("doc") or {},
                                lease_s=req.get("lease_s"))
                return {"ok": True, "degraded": True}
            if op == "lookup_name":
                doc = fb.lookup_name(req["name"],
                                     timeout=float(req.get("timeout", 30.0)))
                return {"ok": True, "degraded": True, "doc": doc}
            if op == "unpublish_name":
                return {"ok": True, "degraded": True,
                        "removed": fb.unpublish_name(req["name"],
                                                     pid=req.get("pid"))}
            if op == "renew_name":
                n = fb.renew_name(req["name"], pid=req.get("pid"),
                                  lease_s=req.get("lease_s"))
                if n == 0 and not self._fallback_owns(req):
                    n = 1  # suspended: the name lives at the dead broker
                return {"ok": True, "renewed": n, "degraded": True}
            if op == "list_names":
                return {"ok": True, "degraded": True,
                        "names": fb.list_names()}
            if op in ("admit", "admit_poll"):
                return {"ok": True, "degraded": True, "granted": True,
                        "ticket": None}
            if op == "release":
                return {"ok": True, "degraded": True}
            if op == "stats":
                return {"ok": True, "degraded": True, "stats": {}}
            return {"ok": False, "degraded": True,
                    "error": f"bad op {op!r}"}
        except (TimeoutError, IOError) as e:
            return {"ok": False, "degraded": True, "error": str(e)}

    def register(
        self,
        dataset: str,
        endpoint: Endpoint,
        query_id: str = "0",
        import_workers: Optional[int] = None,
        lease_s: Optional[float] = None,
    ) -> None:
        if endpoint.pid == 0:
            endpoint = _dc_replace(endpoint, pid=os.getpid())
        self._rpc(
            {
                "op": "register",
                "dataset": dataset,
                "query_id": query_id,
                "import_workers": import_workers,
                "lease_s": lease_s,
                **_ep_to_doc(endpoint),
            }
        )

    def renew(
        self,
        dataset: str,
        query_id: str = "0",
        pid: Optional[int] = None,
        lease_s: Optional[float] = None,
    ) -> int:
        resp = self._rpc(
            {
                "op": "renew",
                "dataset": dataset,
                "query_id": query_id,
                "pid": pid or os.getpid(),
                "lease_s": lease_s,
            }
        )
        return int(resp.get("renewed", 0))

    def query(
        self,
        dataset: str,
        query_id: str = "0",
        export_workers: Optional[int] = None,
        timeout: float = 30.0,
    ) -> Endpoint:
        resp = self._rpc(
            {
                "op": "query",
                "dataset": dataset,
                "query_id": query_id,
                "export_workers": export_workers,
                "timeout": timeout,
            },
            ack=True,
        )
        if not resp.get("ok"):
            raise TimeoutError(resp.get("error", "directory query failed"))
        return _ep_from_doc(resp)

    def query_all(
        self,
        dataset: str,
        query_id: str = "0",
        timeout: float = 30.0,
    ) -> List[Endpoint]:
        resp = self._rpc(
            {
                "op": "query_all",
                "dataset": dataset,
                "query_id": query_id,
                "timeout": timeout,
            }
        )
        if not resp.get("ok"):
            raise TimeoutError(resp.get("error", "directory query failed"))
        return [_ep_from_doc(d) for d in resp.get("endpoints", [])]

    def join_broadcast(
        self,
        dataset: str,
        query_id: str = "0",
        readers: int = 0,
        timeout: float = 30.0,
    ) -> Tuple[int, Optional[Endpoint]]:
        resp = self._rpc(
            {
                "op": "join_broadcast",
                "dataset": dataset,
                "query_id": query_id,
                "readers": readers,
                "timeout": timeout,
            }
        )
        if not resp.get("ok"):
            raise TimeoutError(resp.get("error", "broadcast join failed"))
        doc = resp.get("endpoint")
        return int(resp["slot"]), _ep_from_doc(doc) if doc else None

    def publish_broadcast(
        self,
        dataset: str,
        endpoint: Endpoint,
        query_id: str = "0",
        import_workers: Optional[int] = None,
    ) -> None:
        if endpoint.pid == 0:
            endpoint = _dc_replace(endpoint, pid=os.getpid())
        self._rpc(
            {
                "op": "publish_broadcast",
                "dataset": dataset,
                "query_id": query_id,
                "import_workers": import_workers,
                "endpoint": _ep_to_doc(endpoint),
            }
        )

    def publish_name(self, name: str, doc: Dict[str, Any],
                     lease_s: Optional[float] = None) -> None:
        doc = dict(doc)
        doc.setdefault("pid", os.getpid())
        self._rpc({"op": "publish_name", "name": name, "doc": doc,
                   "lease_s": lease_s})

    def lookup_name(self, name: str, timeout: float = 30.0) -> Dict[str, Any]:
        resp = self._rpc(
            {"op": "lookup_name", "name": name, "timeout": timeout})
        if not resp.get("ok"):
            raise TimeoutError(resp.get("error", "directory lookup failed"))
        return resp.get("doc") or {}

    def unpublish_name(self, name: str, pid: Optional[int] = None) -> bool:
        resp = self._rpc({"op": "unpublish_name", "name": name,
                          "pid": pid or os.getpid()})
        return bool(resp.get("removed"))

    def renew_name(self, name: str, pid: Optional[int] = None,
                   lease_s: Optional[float] = None) -> int:
        resp = self._rpc({"op": "renew_name", "name": name,
                          "pid": pid or os.getpid(), "lease_s": lease_s})
        return int(resp.get("renewed", 0))

    def list_names(self) -> Dict[str, Dict[str, Any]]:
        resp = self._rpc({"op": "list_names"})
        if not resp.get("ok"):
            raise IOError(resp.get("error", "directory list_names failed"))
        return resp.get("names") or {}

    def stats(self) -> dict:
        """Snapshot the server's stats provider (the broker's ``stats()``
        when one is installed; ``{}`` on a plain directory server)."""
        resp = self._rpc({"op": "stats"})
        if not resp.get("ok"):
            raise IOError(resp.get("error", "directory stats failed"))
        return resp.get("stats", {})

    def next_sender(self, dataset: str, query_id: str = "0") -> int:
        resp = self._rpc(
            {"op": "next_sender", "dataset": dataset, "query_id": query_id}
        )
        if not resp.get("ok"):
            raise IOError(resp.get("error", "directory next_sender failed"))
        return int(resp["sender"])


DirectoryLike = Union[WorkerDirectory, DirectoryClient]

_GLOBAL = WorkerDirectory()


def get_directory() -> DirectoryLike:
    return _GLOBAL


def set_directory(d: DirectoryLike) -> None:
    global _GLOBAL
    _GLOBAL = d
