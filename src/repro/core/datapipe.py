"""Data pipes (paper sections 4 and 5): the streams IORedirect substitutes
for file streams when an engine imports/exports a *reserved filename*.

``DataPipeOutput`` stands in for a file opened for writing.  Depending on
the negotiated :class:`PipeConfig` it operates at one of the fig. 11 rungs:

    text        raw characters forwarded in T frames (IORedirect only)
    parts       AString typed parts, delimiters retained, binary primitives
    binary_rows delimiters removed, row-major custom binary
    tagged      protobuf-analog (static/dynamic templates; fig. 13)
    arrowrow    Arrow-analog row-major typed buffers
    arrowcol    Arrow-analog columnar pivot (full PipeGen; default)

``DataPipeInput`` is the matching read side.  Decorated importers consume
typed blocks (:meth:`DataPipeInput.blocks`) or AString lines with typed
parts (:meth:`astring_lines`); undecorated importers read rendered
characters via the ordinary file protocol (``read``/``readline``/iter),
reproducing the engine's original text byte-for-byte from the schema frame
metadata.

Reserved filenames follow the paper's ``db://<dataset>?workers=N&query=Q``
syntax (section 4.2); :func:`parse_reserved` also accepts the
``/tmp/__reserved__<dataset>`` template used for engines that reject custom
URI schemes (section 6.1).
"""

from __future__ import annotations

import io
import json
import queue
import re
import socket
import threading
import time
from dataclasses import dataclass, field, replace
from typing import Any, Iterator, List, Optional, Sequence, Tuple
from urllib.parse import parse_qs, urlparse

from . import telemetry
from .astring import AString
from .compression import Codec, get_codec
from .directory import (DirectoryLike, Endpoint, LeaseRenewer,
                        get_directory)
from .telemetry import FlightRecorder, attach_flight
from .iobuf import BufferPool, DecodeArena, SegmentList, default_pool
from .shm_ring import (
    DEFAULT_RING_CAPACITY,
    ShmRing,
    ShmRingTransport,
    acquire_ring,
    attach_ring,
)
from .formopt import (
    DelimitedAssembler,
    FormOptError,
    JsonAssembler,
    render_delimited,
    render_json,
)
from .stream import (
    DEFAULT_STREAM_WINDOW,
    FaninTransport,
    StripedReceiver,
    StripedSender,
)
from .transport import (
    FRAME_BLOCK,
    FRAME_EOF,
    FRAME_PARTS,
    FRAME_RESUME,
    FRAME_SCHEMA,
    FRAME_TEXT,
    FRAME_VERIFY,
    Channel,
    ChannelTransport,
    LinkSim,
    SocketTransport,
    Transport,
    listen_socket,
)
from .types import ColumnBlock, RowBlock, Schema
from .wire import decode_schema, encode_schema, get_wire_format
from .wire.parts_rows import PartsRowsFormat

__all__ = [
    "PipeConfig",
    "ReservedName",
    "parse_reserved",
    "is_reserved",
    "DataPipeOutput",
    "DataPipeInput",
    "open_pipe_writer",
    "open_pipe_reader",
    "PipeStats",
    "collect_stats",
    "collect_stats_by_attempt",
    "clear_resume",
]

#: data-carrying frame kinds — the only kinds counted by the resume
#: watermark (schema/verify/resume/EOF are per-attempt control frames)
_DATA_FRAME_KINDS = (FRAME_TEXT, FRAME_PARTS, FRAME_BLOCK)

RESERVED_SCHEME = "db"
RESERVED_TEMPLATE = "/tmp/__reserved__"


@dataclass(frozen=True)
class ReservedName:
    dataset: str
    workers: Optional[int] = None
    query_id: str = "0"

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        return f"db://{self.dataset}?workers={self.workers}&query={self.query_id}"


def parse_reserved(filename: str) -> Optional[ReservedName]:
    """Return the ReservedName if ``filename`` activates a data pipe."""
    filename = str(filename)
    if filename.startswith(f"{RESERVED_SCHEME}://"):
        u = urlparse(filename)
        qs = parse_qs(u.query)
        workers = int(qs["workers"][0]) if "workers" in qs else None
        query_id = qs.get("query", ["0"])[0]
        return ReservedName(u.netloc or u.path.lstrip("/"), workers, query_id)
    if filename.startswith(RESERVED_TEMPLATE):
        tail = filename[len(RESERVED_TEMPLATE):]
        m = re.match(r"([^?]+)(?:\?(.*))?$", tail)
        if not m:
            return None
        qs = parse_qs(m.group(2) or "")
        workers = int(qs["workers"][0]) if "workers" in qs else None
        query_id = qs.get("query", ["0"])[0]
        return ReservedName(m.group(1), workers, query_id)
    return None


def is_reserved(filename: str) -> bool:
    return parse_reserved(filename) is not None


@dataclass
class PipeConfig:
    """Negotiated pipe behaviour; travels in the schema frame meta.

    ``pipelined``/``scatter_gather``/``pool`` are exporter-local transport
    knobs (they do not travel in the meta): ``pipelined`` runs compression
    and the vectored send on a bounded sender thread so encoding block N+1
    overlaps the send of block N (the paper's producer/consumer overlap);
    ``scatter_gather`` disables the zero-copy path when False, falling back
    to the concatenate-then-send profile (kept for the fig. 11 seed-path
    comparison); ``pool`` supplies a dedicated buffer pool (default: the
    process-wide pool).

    ``transport``/``shm_capacity``/``decode_arena`` are importer-local: the
    importer picks the rendezvous flavor (it registers the endpoint, the way
    it owns the listening socket), the exporter connects to whatever kind
    the directory hands back.  ``transport`` is one of ``socket`` (TCP,
    default), ``channel`` (in-process queue) or ``shm`` (cross-process
    shared-memory ring, zero intermediate copies); ``decode_arena`` supplies
    a dedicated :class:`~repro.core.iobuf.DecodeArena` so decode pool stats
    attribute to one pipe (default: a per-pipe arena over the process-wide
    decode pool).  ``shm_doorbell`` (importer-local, on by default) gives
    the ring a fifo/eventfd doorbell so a blocked side wakes in
    microseconds; off (or on doorbell-less platforms) it falls back to the
    exponential-backoff poll.  ``broadcast`` (importer-local, shm only)
    joins this pipe as one of N readers of a *broadcast ring*: the
    exporter encodes and publishes every frame once and all N colocated
    importers consume it from the same segment (the planner sets this on
    fan-out edges it compiles onto one export).

    Stream-fabric knobs (``repro.core.stream`` / ``repro.core.fabric``):
    ``streams`` (importer-local) stripes each pipe across N member
    connections of the chosen transport flavor — the importer registers a
    multi-endpoint group, the exporter's frames spread round-robin over the
    members and reassemble in sequence order behind a ``stream_window``-
    frame reorder window with per-stream credits.  ``partition`` (exporter-
    local) turns the transfer into an N→M shuffle: every exporter worker
    routes rows to *all* import workers by key (``hash[:col]``,
    ``range[:col]``, ``rr``); ``partition_bounds`` presets the range
    split points (the planner's global compile-time quantiles, stamped
    into every exporter so they agree); ``fanin`` (importer-local, set by
    :func:`repro.core.session.transfer` / the planner) is the number of
    exporter streams each importer merges.  ``streams`` and ``partition``
    compose: with both set, each importer registers one private *slot*
    (a striped group of ``streams`` connections) per exporter, so every
    shuffle member pipe is itself striped."""

    mode: str = "arrowcol"  # text | parts | binary_rows | tagged | arrowrow | arrowcol
    codec: str = "none"  # none | rle | zip | zstd
    block_rows: int = 65536
    text_format: str = "csv"  # csv | json  (what the engine's serializer speaks)
    delimiter: Optional[str] = None  # inferred when None (section 5.3.1)
    verify_first_n: int = 0  # probabilistic runtime check (section 4.1)
    link: Optional[LinkSim] = None
    connect_timeout: float = 30.0
    pipelined: bool = True  # double-buffered sender thread
    scatter_gather: bool = True  # zero-copy vectored send
    sender_depth: int = 2  # bounded in-flight frames (double buffering)
    block_export: bool = True  # allow exporters to hand over whole blocks
    pool: Optional[BufferPool] = None
    transport: str = "socket"  # socket | channel | shm (importer-side)
    shm_capacity: int = DEFAULT_RING_CAPACITY  # ring data-region bytes
    shm_doorbell: bool = True  # fifo/eventfd wakeups (False = backoff poll)
    broadcast: int = 0  # shm fan-out: join as one of N broadcast readers
    decode_arena: Optional[DecodeArena] = None  # importer-side decode pool
    streams: int = 1  # stripe each pipe across N member connections
    stream_window: int = DEFAULT_STREAM_WINDOW  # reorder window (frames)
    partition: Optional[str] = None  # N→M shuffle: hash[:col]|range[:col]|rr
    partition_bounds: Optional[Tuple] = None  # preset global range bounds
    fanin: int = 1  # importer-side: exporter streams to merge (shuffle)
    # robustness knobs (set by the plan executor's retry policy).  ``resume``
    # names the process-global resume ledger for this edge: stable across
    # attempts, so a retried importer replays the data frames the previous
    # attempt already received and registers its acked watermark for the
    # exporter to skip to.  ``attempt`` is the retry epoch (0 = first try),
    # echoed in the RESUME hello.  ``lease_s`` > 0 makes the importer's
    # directory registration a leased one: a renewer thread re-stamps it
    # while the importer is alive, and an expired lease is GC'd like a dead
    # pid (crashed peers stop haunting the rendezvous).
    resume: Optional[str] = None  # resume-ledger token (edge-stable)
    attempt: int = 0  # retry epoch (0 = first try)
    lease_s: float = 0.0  # directory lease TTL (0 = unleased)
    # telemetry knobs (repro.core.telemetry).  ``trace`` opts this pipe
    # into span recording (enabling the process tracer if needed);
    # ``trace_ctx`` is the propagated "trace_id:span_id" parent context,
    # stamped by the plan executor so both ends of an edge join one
    # trace; ``flight_depth`` bounds the per-pipe flight-recorder ring;
    # ``recorder`` shares the executor's per-edge FlightRecorder so pipe
    # events land in the same timeline as admission/retry events.
    trace: bool = False  # record lifecycle spans for this pipe
    trace_ctx: str = ""  # propagated parent trace context
    flight_depth: int = 64  # flight-recorder ring depth (events)
    recorder: Optional["FlightRecorder"] = None  # shared edge recorder

    def meta(self) -> dict:
        return {
            "mode": self.mode,
            "codec": self.codec,
            "text_format": self.text_format,
            "delimiter": self.delimiter,
            "verify_first_n": self.verify_first_n,
        }


@dataclass
class PipeStats:
    bytes_sent: int = 0
    frames_sent: int = 0
    rows: int = 0
    blocks: int = 0
    copies_avoided: int = 0   # segments shipped as views of live memory
    pool_hits: int = 0        # buffer acquires served without allocating
    pool_misses: int = 0
    send_overlap_s: float = 0.0  # sender-thread work hidden behind encoding
    decode_pool_hits: int = 0    # importer: arena stores served from retention
    decode_pool_misses: int = 0
    shm_spans: int = 0           # frames carried as in-place shm ring spans
    # shm ring wait attribution: how blocked sides woke up.  A doorbell
    # regression (back to polling) shows up as poll_sleeps > 0 here.
    doorbell_waits: int = 0      # waits resolved by a doorbell wakeup
    spin_wakeups: int = 0        # waits resolved during the brief spin
    poll_sleeps: int = 0         # backoff-poll sleeps (fallback path only)
    # resumable edges: how much of a retried transfer was NOT re-moved.
    # The exporter skips re-encoded frames the importer already acked
    # (resume_skipped); the importer replays its staged prefix locally
    # (resume_replayed).  Both zero on first attempts and non-resumed runs.
    resume_skipped: int = 0      # exporter: data frames dropped at the cut
    resume_replayed: int = 0     # importer: staged frames served locally
    # striped pipes: one dict per member stream ({stream, bytes, frames, ...});
    # merged views concatenate, so a shuffle's M members each contribute theirs
    per_stream: List[dict] = field(default_factory=list)

    _SUMMED = ("bytes_sent", "frames_sent", "rows", "blocks",
               "copies_avoided", "pool_hits", "pool_misses",
               "send_overlap_s", "decode_pool_hits", "decode_pool_misses",
               "shm_spans", "doorbell_waits", "spin_wakeups", "poll_sleeps",
               "resume_skipped", "resume_replayed")

    def merge(self, other: "PipeStats") -> "PipeStats":
        """Fold ``other`` into this view (counters sum, per-stream
        breakdowns concatenate).  Returns self, so
        ``PipeStats().merge(a).merge(b)`` builds an aggregate."""
        for name in self._SUMMED:
            setattr(self, name, getattr(self, name) + getattr(other, name))
        self.per_stream = self.per_stream + list(other.per_stream)
        return self


# -- per-transfer stats sink ---------------------------------------------------
# Pipes are opened deep inside engine code, so the session layer cannot reach
# them directly; closing pipes fold their PipeStats in here under the
# (dataset, query_id) of their reserved name — keyed *per attempt* inside
# the entry, so a failed attempt's counters and its successful retry's
# counters stay distinguishable — and
# :func:`repro.core.session.transfer` collects the merged views into the
# TransferResult.  Bounded so an uncollected benchmark loop cannot grow it.

_SINK_MAX = 256
#: per-pipe cap on buffered phase spans (a traced pipe must stay O(1)
#: in memory however long the stream runs; the whole-pipe span and the
#: lifecycle spans always fit)
_TSPAN_MAX = 4096
_sink_lock = threading.Lock()
# (dataset, query_id) -> {role: {attempt: PipeStats}}
_stats_sink: "dict[Tuple[str, str], dict]" = {}


def _record_stats(rn: ReservedName, role: str, stats: "PipeStats",
                  attempt: int = 0) -> None:
    with _sink_lock:
        key = (rn.dataset, rn.query_id)
        if key not in _stats_sink and len(_stats_sink) >= _SINK_MAX:
            _stats_sink.pop(next(iter(_stats_sink)))
        roles = _stats_sink.setdefault(key, {})
        attempts = roles.setdefault(role, {})
        agg = attempts.setdefault(attempt, PipeStats())
        agg.merge(stats)
    reg = telemetry.registry()
    reg.counter("pipe.closes", role=role).inc()
    reg.counter("pipe.bytes", role=role).inc(stats.bytes_sent)
    reg.counter("pipe.frames", role=role).inc(stats.frames_sent)
    reg.counter("pipe.rows", role=role).inc(stats.rows)
    if stats.resume_skipped:
        reg.counter("pipe.resume_skipped").inc(stats.resume_skipped)
    if stats.resume_replayed:
        reg.counter("pipe.resume_replayed").inc(stats.resume_replayed)
    if stats.poll_sleeps:
        reg.counter("shm.poll_sleeps").inc(stats.poll_sleeps)
    if stats.doorbell_waits:
        reg.counter("shm.doorbell_waits").inc(stats.doorbell_waits)


def collect_stats(dataset: str, query_id: str = "0") -> "dict[str, PipeStats]":
    """Pop the merged per-role (``export``/``import``) stats for one
    transfer — aggregated across workers, shuffle members, streams, *and*
    attempts (the folded view; :func:`collect_stats_by_attempt` peeks the
    per-attempt breakdown before this folds it)."""
    with _sink_lock:
        roles = _stats_sink.pop((dataset, query_id), {})
    out: "dict[str, PipeStats]" = {}
    for role, attempts in roles.items():
        agg = PipeStats()
        for k in sorted(attempts):
            agg.merge(attempts[k])
        out[role] = agg
    return out


def collect_stats_by_attempt(
        dataset: str, query_id: str = "0") -> "dict[str, dict]":
    """Non-destructive per-attempt view: ``{role: {attempt: PipeStats}}``.
    Unlike :func:`collect_stats` this does not pop the entry, so both
    views of one transfer are available."""
    with _sink_lock:
        roles = _stats_sink.get((dataset, query_id), {})
        return {role: dict(attempts) for role, attempts in roles.items()}


# -- resume ledgers ------------------------------------------------------------
# A resumable edge stages every *fully received* data frame (decompressed
# payload bytes) under its ledger token.  A retry attempt opens a fresh
# importer against the same token: the staged prefix replays locally, the
# new registration carries ``resume_seq = len(staged)`` as the acked
# watermark, and the exporter's RESUME hello says where it restarts so any
# overlap (exporter behind the watermark) is deduped by count.  The plan
# executor owns the token lifecycle and clears it once the edge settles.

class _ResumeLedger:
    __slots__ = ("staged", "lock")

    def __init__(self) -> None:
        self.staged: List[Tuple[bytes, bytes]] = []  # (kind, payload)
        self.lock = threading.Lock()


_resume_lock = threading.Lock()
_RESUME_LEDGERS: "dict[str, _ResumeLedger]" = {}


def _resume_ledger(token: str) -> _ResumeLedger:
    with _resume_lock:
        led = _RESUME_LEDGERS.get(token)
        if led is None:
            led = _RESUME_LEDGERS[token] = _ResumeLedger()
        return led


def clear_resume(token: str) -> None:
    """Drop the staged frames of one edge (call when the edge settles —
    success or final failure — so the ledger cannot leak across plans)."""
    with _resume_lock:
        _RESUME_LEDGERS.pop(token, None)


class _PoolHandle:
    """Per-pipe view of a (possibly shared) BufferPool: delegates acquires
    and counts this pipe's own hits/misses exactly, so PipeStats are not
    polluted by concurrent pipes sharing the process-wide pool."""

    __slots__ = ("pool", "hits", "misses")

    def __init__(self, pool: BufferPool):
        self.pool = pool
        self.hits = 0
        self.misses = 0

    def acquire(self, nbytes: int):
        buf = self.pool.acquire(nbytes)
        if buf.was_hit:
            self.hits += 1
        else:
            self.misses += 1
        return buf


class _PipelinedSender:
    """Bounded sender thread: compress + vectored send of frame N overlap
    the encoding of frame N+1 (double buffering via ``depth``).

    Error contract: a failure in compress/send is latched; subsequent
    submissions drain (releasing pooled buffers) so the producer never
    blocks on a dead pipe, and the error is re-raised on :meth:`submit`
    or, at the latest, :meth:`close` -- the reader is unblocked by the
    owner closing the transport."""

    _DONE = object()

    def __init__(self, transport: Transport, codec: Codec, depth: int = 2):
        self._transport = transport
        self._codec = codec
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
        self.busy_s = 0.0   # sender-thread time spent compressing/sending
        self.wait_s = 0.0   # producer time blocked on the bounded queue
        # (start, end) spans, each list appended in time order by one
        # thread: busy by the sender, blocked by the producer.  overlap_s
        # intersects them, so sender work done while the producer ran free
        # (including the post-final-submit drain) counts exactly once.
        self._busy_iv: List[Tuple[float, float]] = []
        self._blocked_iv: List[Tuple[float, float]] = []
        self.error: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._run, name="pipegen-sender", daemon=True
        )
        self._thread.start()

    def submit(self, kind: bytes, segs: SegmentList, compress: bool = True) -> None:
        if self.error is not None:
            raise self.error
        try:
            self._q.put_nowait((kind, segs, compress))
        except queue.Full:
            # only genuine backpressure counts as wait (an uncontended put
            # costs microseconds and would drown the overlap signal)
            t0 = time.perf_counter()
            self._q.put((kind, segs, compress))
            t1 = time.perf_counter()
            self.wait_s += t1 - t0
            self._blocked_iv.append((t0, t1))

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is self._DONE:
                return
            kind, segs, compress = item
            if self.error is not None:
                segs.release()  # drain so the producer never blocks
                continue
            t0 = time.perf_counter()
            try:
                if compress:
                    segs = self._codec.compress_segments(segs)
                self._transport.send_frames(kind, segs)
            except BaseException as e:  # noqa: BLE001 - latched, re-raised
                self.error = e
            finally:
                segs.release()  # recycle pooled stores on success AND error
                t1 = time.perf_counter()
                self.busy_s += t1 - t0
                self._busy_iv.append((t0, t1))

    def close(self) -> None:
        """Drain, join, and surface any latched send error."""
        self._q.put(self._DONE)
        self._thread.join()
        if self.error is not None:
            raise self.error

    @property
    def overlap_s(self) -> float:
        """Sender work hidden behind the producer: total busy time minus
        the part spent while the producer sat blocked on the bounded
        queue.  Interval intersection (not ``busy - wait``): a blocked
        put also covers sender scheduling latency, which is not sender
        work, and would otherwise cancel genuine overlap down to 0."""
        busy = 0.0
        inter = 0.0
        j = 0
        blocked = self._blocked_iv
        for a, b in self._busy_iv:
            busy += b - a
            while j < len(blocked) and blocked[j][1] <= a:
                j += 1
            k = j
            while k < len(blocked) and blocked[k][0] < b:
                inter += min(b, blocked[k][1]) - max(a, blocked[k][0])
                k += 1
        return max(0.0, busy - inter)


class DataPipeOutput:
    """File-like write end of a data pipe (subtype-substitutable for the
    engines' text writers, per fig. 5)."""

    def __init__(
        self,
        filename: str,
        config: Optional[PipeConfig] = None,
        directory: Optional[DirectoryLike] = None,
        endpoint: Optional[Endpoint] = None,
    ):
        rn = parse_reserved(filename)
        if rn is None:
            raise ValueError(f"{filename!r} is not a reserved pipe name")
        self.reserved = rn
        self.config = config or PipeConfig()
        self.stats = PipeStats()
        self.closed = False
        self._verify_rows: List[tuple] = []
        # telemetry: spans are timed locally and recorded at close under
        # the finally-resolved trace context (explicit config ctx beats
        # the importer's registration ctx beats a fresh root), so both
        # ends of the edge land in one trace no matter which side
        # originated it.  The flight recorder notes lifecycle events for
        # postmortem attachment (shared with the executor's edge recorder
        # when the plan passes one in).
        if self.config.trace and not telemetry.tracing_enabled():
            telemetry.enable_tracing()
        self._trace_on = self.config.trace or telemetry.tracing_enabled()
        self._trace_ctx = self.config.trace_ctx or telemetry.current_ctx()
        self._tspans: List[tuple] = []
        self._t_open = time.monotonic()
        self._recorder = self.config.recorder or FlightRecorder(
            self.config.flight_depth, name=f"export {rn.dataset}")
        self._recorder.note("export.open", dataset=rn.dataset,
                            query=rn.query_id, attempt=self.config.attempt)
        # validate codec/format before any rendezvous so a bad config fails
        # fast instead of leaving a half-registered peer behind
        self._codec: Codec = get_codec(self.config.codec)
        self._wire = (
            get_wire_format(self.config.mode)
            if self.config.mode not in ("text", "parts", "bytes")
            else None
        )
        directory = directory or get_directory()
        _t_rdv = time.monotonic()
        if endpoint is None:
            endpoint = directory.query(
                rn.dataset,
                rn.query_id,
                export_workers=rn.workers,
                timeout=self.config.connect_timeout,
            )
        if not self._trace_ctx:
            # adopt the importer's registration context, if it traced
            self._trace_ctx = getattr(endpoint, "trace", "") or ""
        if endpoint.is_group:
            # the importer striped its pipe: connect every member (in
            # registration order -- the importer accepts in the same order)
            # and spread frames across them (repro.core.stream)
            members = [_connect(m, self.config.link) for m in endpoint.members]
            self._transport: Transport = StripedSender(members)
        else:
            self._transport = _connect(endpoint, self.config.link)
        if self._trace_on:
            self._tspans.append(("export.rendezvous", _t_rdv,
                                 time.monotonic(), None))
            if not self._trace_ctx:
                self._trace_ctx = telemetry.new_trace_ctx()
            # the span id the whole-pipe span will be recorded under at
            # close; carried in the schema hello so importer spans parent
            # to this exporter when the trace originates here
            self._pipe_sid = telemetry.new_span_id()
        else:
            self._pipe_sid = ""
        self._recorder.note("export.connected")
        # resumable edge: the importer's registration carries the acked
        # watermark from the previous attempt; this export skips its first
        # ``resume_seq`` data frames at the _send funnel (mode-agnostic —
        # the engine re-produces the stream, the cut point is exact) and
        # announces the restart position in a RESUME hello after the schema
        self._resume_token: Optional[str] = None
        self._resume_from = 0
        self._resume_skip_left = 0
        if (self.config.resume is not None and not endpoint.is_group
                and getattr(endpoint, "broadcast", 0) <= 1):
            self._resume_token = self.config.resume
            self._resume_from = int(getattr(endpoint, "resume_seq", 0) or 0)
            self._resume_skip_left = self._resume_from
        self._pool = _PoolHandle(self.config.pool or default_pool())
        self._sender: Optional[_PipelinedSender] = None
        if self.config.pipelined:
            self._sender = _PipelinedSender(
                self._transport, self._codec, self.config.sender_depth
            )
        self._parts_wire = PartsRowsFormat()
        self._text_buf: List[str] = []
        self._text_len = 0
        self._part_rows: List[List[Any]] = []
        self._cur_parts: List[Any] = []
        if self.config.text_format == "json":
            self._asm: Any = JsonAssembler()
        else:
            self._asm = DelimitedAssembler()
            if self.config.delimiter is not None:
                self._asm.delimiter = self.config.delimiter
                self._asm._sampling = False
        self._schema_sent = False
        self._schema: Optional[Schema] = None
        self._byte_buf: List[bytes] = []
        self._byte_len = 0
        if self.config.mode in ("text", "bytes"):
            # schema frame still opens the stream so the reader can negotiate
            self._send_schema(Schema([]))

    # -- file protocol ---------------------------------------------------------
    def write(self, s: Any) -> int:
        if self.closed:
            raise ValueError("write to closed data pipe")
        if self.config.mode == "bytes":
            b = s if isinstance(s, (bytes, bytearray, memoryview)) else str(s).encode("latin-1")
            self._byte_buf.append(bytes(b))
            self._byte_len += len(b)
            if self._byte_len >= 1 << 20:
                self._flush_bytes()
            return len(b)
        if self.config.mode == "text":
            text = str(s)
            self._text_buf.append(text)
            self._text_len += len(text)
            if self._text_len >= 1 << 20:
                self._flush_text()
            return len(text)
        if self.config.mode == "parts":
            self._write_parts(s)
            return _cheap_len(s)
        self._asm.write(s if isinstance(s, (AString, str)) else str(s))
        if isinstance(self._asm, JsonAssembler) and len(self._asm._parts) >= 1 << 16:
            self._asm.flush()  # retains any incomplete trailing document
        self._maybe_flush_rows()
        return _cheap_len(s)

    def writelines(self, lines: Sequence[Any]) -> None:
        for l in lines:
            self.write(l)

    def flush(self) -> None:
        if self.config.mode == "text":
            self._flush_text()
        elif self.config.mode == "bytes":
            self._flush_bytes()

    def close(self) -> None:
        if self.closed:
            return
        sender_err: Optional[BaseException] = None
        try:
            if self.config.mode == "text":
                self._flush_text()
            elif self.config.mode == "bytes":
                self._flush_bytes()
            elif self.config.mode == "parts":
                self._flush_parts(final=True)
            else:
                self._flush_rows(final=True)
            self._send(FRAME_EOF, SegmentList([b""]), compress=False)
        finally:
            self.closed = True
            if self._sender is not None:
                try:
                    self._sender.close()
                except BaseException as e:  # noqa: BLE001 - re-raised below
                    sender_err = e
                self.stats.send_overlap_s = self._sender.overlap_s
            # always close the transport -- a sender failure must not leave
            # the reader blocked on a half-open stream.  Close *before*
            # reading the counters: a striped sender only finishes sending
            # (drains its member queues) inside close().
            try:
                self._transport.close()
            except BaseException as e:  # noqa: BLE001 - re-raised below
                sender_err = sender_err or e
            self.stats.bytes_sent = self._transport.bytes_sent
            self.stats.frames_sent = self._transport.frames_sent
            self.stats.pool_hits = self._pool.hits
            self.stats.pool_misses = self._pool.misses
            self.stats.shm_spans = getattr(self._transport, "shm_spans", 0)
            self.stats.doorbell_waits = getattr(
                self._transport, "doorbell_waits", 0)
            self.stats.spin_wakeups = getattr(
                self._transport, "spin_wakeups", 0)
            self.stats.poll_sleeps = getattr(
                self._transport, "poll_sleeps", 0)
            per_stream = getattr(self._transport, "per_stream", None)
            if per_stream is not None:
                self.stats.per_stream = per_stream()
            _record_stats(self.reserved, "export", self.stats,
                          attempt=self.config.attempt)
            self._recorder.note(
                "export.close", bytes=self.stats.bytes_sent,
                frames=self.stats.frames_sent,
                error=type(sender_err).__name__ if sender_err else None)
            self._emit_spans()
        if sender_err is not None:
            raise attach_flight(sender_err, self._recorder)

    def _emit_spans(self) -> None:
        """Record the pipe's lifecycle spans under the resolved trace
        context (buffered locally so late-arriving context — the
        importer's registration — still wins over a fresh root)."""
        tr = telemetry.tracer()
        if not self._trace_on or tr is None:
            return
        trace_id, parent = telemetry.split_ctx(
            self._trace_ctx or telemetry.new_trace_ctx())
        rn = self.reserved
        pipe_sid = tr.record(
            "export.pipe", self._t_open, time.monotonic(),
            trace_id=trace_id, parent_id=parent,
            span_id=self._pipe_sid or None,
            attrs={"dataset": rn.dataset, "query": rn.query_id,
                   "attempt": self.config.attempt, "mode": self.config.mode,
                   "bytes": self.stats.bytes_sent,
                   "frames": self.stats.frames_sent,
                   "rows": self.stats.rows})
        for name, t0, t1, attrs in self._tspans:
            tr.record(name, t0, t1, trace_id=trace_id,
                      parent_id=pipe_sid, attrs=attrs)

    def __enter__(self) -> "DataPipeOutput":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- frame egress (all rungs funnel through here) ------------------------------
    def _send(self, kind: bytes, segs: SegmentList, compress: bool = True) -> None:
        if self._trace_on:
            t0 = time.monotonic()
            try:
                return self._send_impl(kind, segs, compress)
            finally:
                if len(self._tspans) < _TSPAN_MAX:
                    self._tspans.append((
                        "export.send", t0, time.monotonic(),
                        {"kind": kind.decode("ascii", "replace")}))
        return self._send_impl(kind, segs, compress)

    def _send_impl(self, kind: bytes, segs: SegmentList,
                   compress: bool = True) -> None:
        """Route one frame out: codec at the segment level (data frames
        only -- schema/verify/EOF travel uncompressed), then either the
        double-buffered sender thread (pipelined) or an inline vectored
        send.  ``scatter_gather=False`` re-materializes the payload first,
        reproducing the seed path's concatenate-then-send copy profile."""
        if self._resume_skip_left and kind in _DATA_FRAME_KINDS:
            # the importer acked this frame on a previous attempt
            self._resume_skip_left -= 1
            self.stats.resume_skipped += 1
            segs.release()
            return
        if not self.config.scatter_gather:
            payload = segs.join()
            segs.release()
            segs = SegmentList([payload])
        self.stats.copies_avoided += segs.copies_avoided
        if self._sender is not None:
            self._sender.submit(kind, segs, compress)
            return
        if compress:
            segs = self._codec.compress_segments(segs)
        self._transport.send_frames(kind, segs)
        segs.release()

    # -- text rung ---------------------------------------------------------------
    def _flush_text(self) -> None:
        if not self._text_buf:
            return
        payload = "".join(self._text_buf).encode("utf-8", "surrogatepass")
        self._text_buf, self._text_len = [], 0
        self._send(FRAME_TEXT, SegmentList([payload]))

    # -- bytes rung (shared-binary-format passthrough, e.g. seqfiles) --------------
    def _flush_bytes(self) -> None:
        if not self._byte_buf:
            return
        payload = b"".join(self._byte_buf)
        self._byte_buf, self._byte_len = [], 0
        self._send(FRAME_TEXT, SegmentList([payload]))

    # -- parts rung (binary primitives, delimiters retained) ----------------------
    def _write_parts(self, s: Any) -> None:
        parts = s.parts if isinstance(s, AString) else (str(s),)
        for p in parts:
            if isinstance(p, str) and p.endswith("\n"):
                if p[:-1]:
                    self._cur_parts.append(p[:-1])
                self._part_rows.append(self._cur_parts)
                self._cur_parts = []
            else:
                self._cur_parts.append(p)
        if len(self._part_rows) >= self.config.block_rows:
            self._flush_parts()

    def _flush_parts(self, final: bool = False) -> None:
        if final and self._cur_parts:
            self._part_rows.append(self._cur_parts)
            self._cur_parts = []
        if not self._part_rows:
            return
        if not self._schema_sent:
            self._send_schema(Schema([]))
        segs = self._parts_wire.encode_parts(self._part_rows, pool=self._pool)
        self.stats.rows += len(self._part_rows)
        self._part_rows = []
        self._send(FRAME_PARTS, segs)
        self.stats.blocks += 1

    # -- typed-rows rungs ----------------------------------------------------------
    def _maybe_flush_rows(self) -> None:
        if len(self._asm.rows) >= self.config.block_rows:
            self._flush_rows()

    def _flush_rows(self, final: bool = False) -> None:
        if final:
            try:
                self._asm.flush()
            except FormOptError:
                pass  # trailing partial row: nothing further to emit
        if not self._asm.rows:
            return
        rb: RowBlock = self._asm.take_rows()
        if self._schema is None:
            self._schema = rb.schema
            self._send_schema(rb.schema)
        elif rb.schema.types != self._schema.types:
            # a write_block already fixed the stream schema; text rows of a
            # different shape would decode against the wrong layout
            raise ValueError(
                f"serialized rows schema {rb.schema!r} does not match the "
                f"stream schema {self._schema!r} already negotiated"
            )
        block = rb.to_columns()  # section 5.4 pivot
        if self.config.verify_first_n and len(self._verify_rows) < self.config.verify_first_n:
            take = self.config.verify_first_n - len(self._verify_rows)
            self._verify_rows.extend(rb.rows[:take])
            self._send_verify(RowBlock(rb.schema, rb.rows[:take]))
        segs = self._wire.encode_block(block, pool=self._pool)
        self._send(FRAME_BLOCK, segs)
        self.stats.rows += len(block)
        self.stats.blocks += 1

    # -- typed block fast path (decorated exporters, fig. 11 'full PipeGen') ------
    def accepts_blocks(self) -> bool:
        """True when whole ColumnBlocks can bypass the text serializer."""
        return (
            self.config.block_export
            and self.config.mode not in ("text", "parts", "bytes")
            and not self.closed
        )

    def write_block(
        self,
        block: ColumnBlock,
        header: Optional[Sequence[str]] = None,
        delimiter: Optional[str] = None,
    ) -> int:
        """Export one typed ColumnBlock directly -- the exporter-side twin
        of the importer's block fast path: no text rendering, no AString
        assembly, no row pivot.  ``header``/``delimiter`` feed the schema
        frame meta so undecorated importers can still regenerate the text
        dialect byte-for-byte.

        Zero-copy ownership contract: fixed-width columns go on the wire
        as views of ``block``'s live numpy buffers, and with
        ``pipelined=True`` the send completes asynchronously -- the caller
        must not mutate the block's columns until :meth:`close` returns
        (engines hand over stored, immutable blocks, so this holds by
        construction on every generated-adapter path)."""
        if self.closed:
            raise ValueError("write to closed data pipe")
        if not self.accepts_blocks():
            raise ValueError(
                f"mode {self.config.mode!r} cannot carry typed blocks"
            )
        self._flush_rows()  # keep ordering with any interleaved text writes
        if self._schema is not None and block.schema.types != self._schema.types:
            # the stream schema traveled once, up front; a block with
            # different column types would be decoded against the wrong
            # layout on the reader (silent corruption at same width)
            raise ValueError(
                f"write_block schema {block.schema!r} does not match the "
                f"stream schema {self._schema!r} already negotiated"
            )
        if self._schema is None:
            self._schema = block.schema
            if delimiter is not None and isinstance(self._asm, DelimitedAssembler):
                self._asm.delimiter = delimiter
                self._asm._sampling = False
            self._send_schema(block.schema, header_names=header)
        n = len(block)
        rows_per_sub = self.config.block_rows
        nstreams = getattr(self._transport, "nstreams", 1)
        if nstreams > 1 and n:
            # striped pipe: a one-shot bulk export must still produce at
            # least one frame per member stream, or the stripes sit idle
            rows_per_sub = min(rows_per_sub, max(1, -(-n // nstreams)))
        for lo in range(0, n, rows_per_sub):
            sub = (
                block
                if n <= rows_per_sub
                else ColumnBlock(
                    block.schema,
                    [c[lo : lo + rows_per_sub] for c in block.columns],
                )
            )
            if (
                self.config.verify_first_n
                and len(self._verify_rows) < self.config.verify_first_n
            ):
                rb = sub.to_rows()
                take = self.config.verify_first_n - len(self._verify_rows)
                self._verify_rows.extend(rb.rows[:take])
                self._send_verify(RowBlock(rb.schema, rb.rows[:take]))
            segs = self._wire.encode_block(sub, pool=self._pool)
            self._send(FRAME_BLOCK, segs)
            self.stats.rows += len(sub)
            self.stats.blocks += 1
        return n

    def _send_schema(
        self, schema: Schema, header_names: Optional[Sequence[str]] = None
    ) -> None:
        meta = self.config.meta()
        if self._trace_on and self._trace_ctx:
            # cross-process propagation: the importer adopts this trace
            # and parents its spans under the exporter's pipe span
            tid, _ = telemetry.split_ctx(self._trace_ctx)
            meta["trace"] = f"{tid}:{self._pipe_sid}"
        if isinstance(self._asm, DelimitedAssembler) and self._asm.delimiter:
            meta["delimiter"] = self._asm.delimiter
        if header_names:
            meta["header"] = list(header_names)
        elif getattr(self._asm, "header_names", None):
            meta["header"] = list(self._asm.header_names)
        self._send(FRAME_SCHEMA, SegmentList([encode_schema(schema, meta)]),
                   compress=False)
        if self._resume_token is not None:
            hello = json.dumps({"epoch": self.config.attempt,
                                "from": self._resume_from}).encode("utf-8")
            self._send(FRAME_RESUME, SegmentList([hello]), compress=False)
            self._recorder.note("export.resume_hello",
                                epoch=self.config.attempt,
                                skip=self._resume_from)
        self._schema_sent = True
        self._recorder.note("export.schema")

    def _send_verify(self, rb: RowBlock) -> None:
        """Probabilistic runtime check: ship the original text rendering of
        the first n rows so the importer can compare (section 4.1)."""
        if self._resume_from:
            # resumed attempt: the verify region was checked (and staged)
            # before the crash; re-sent expectations would misalign against
            # the post-watermark blocks actually on the wire
            return
        if self.config.text_format == "json":
            text = render_json(rb)
        else:
            text = render_delimited(rb, self._asm.delimiter or ",")
        self._send(FRAME_VERIFY, SegmentList([text.encode("utf-8")]),
                   compress=False)


class DataPipeInput:
    """File-like read end of a data pipe.

    Decorated importers use :meth:`blocks` (typed ColumnBlocks, zero text) or
    :meth:`astring_lines` (AStrings with typed parts).  Undecorated importers
    read characters; we regenerate them from blocks + schema-frame metadata.

    Both protocols consume from a *single* decoded-block queue, so a
    header-probing client may ``read`` a few characters, :meth:`unread` them
    (bounded rewind, one block deep — the HDFS sequence-file sniff of
    section 6.1), and then switch to the typed protocol without losing data.
    """

    def __init__(
        self,
        filename: str,
        directory: Optional[DirectoryLike] = None,
        link: Optional[LinkSim] = None,
        host: str = "127.0.0.1",
        channel: Optional[Channel] = None,
        import_workers: Optional[int] = None,
        transport: Optional[str] = None,
        shm_capacity: int = DEFAULT_RING_CAPACITY,
        shm_doorbell: bool = True,
        broadcast: int = 0,
        arena: Optional[DecodeArena] = None,
        streams: int = 1,
        fanin: int = 1,
        stream_window: int = DEFAULT_STREAM_WINDOW,
        resume: Optional[str] = None,
        attempt: int = 0,
        lease_s: float = 0.0,
        connect_timeout: float = 30.0,
        trace: bool = False,
        trace_ctx: str = "",
        flight_depth: int = 64,
        recorder: Optional[FlightRecorder] = None,
    ):
        rn = parse_reserved(filename)
        if rn is None:
            raise ValueError(f"{filename!r} is not a reserved pipe name")
        self.reserved = rn
        self._attempt = attempt
        if trace and not telemetry.tracing_enabled():
            telemetry.enable_tracing()
        self._trace_on = trace or telemetry.tracing_enabled()
        self._trace_ctx = trace_ctx or telemetry.current_ctx()
        self._tspans: List[tuple] = []
        self._t_open = time.monotonic()
        self._recorder = recorder or FlightRecorder(
            flight_depth, name=f"import {rn.dataset}")
        self._recorder.note("import.open", dataset=rn.dataset,
                            query=rn.query_id, transport=transport,
                            attempt=attempt)
        # registration context: what we publish in the directory so an
        # exporter with no context of its own joins *our* trace
        self._reg_ctx = ""
        if self._trace_on:
            self._reg_ctx = self._trace_ctx or telemetry.new_trace_ctx()
        directory = directory or get_directory()
        self._connect_timeout = float(connect_timeout) or 30.0
        if transport is None:
            transport = "channel" if channel is not None else "socket"
        if transport not in ("socket", "channel", "shm"):
            raise ValueError(
                f"unknown transport {transport!r}; have socket/channel/shm")
        workers = import_workers or rn.workers
        if broadcast > 1 and (transport != "shm" or fanin > 1 or streams > 1):
            raise ValueError(
                "broadcast pipes require transport='shm' with streams=1 "
                "and fanin=1 (one ring, one writer, N reader cursors)")
        # resumable edge (plain single-stream pipes only: stripes, shuffles
        # and broadcast rings have per-member frame orders a single frame
        # watermark cannot describe): stage received data frames under the
        # ledger token and register the acked watermark for the exporter
        self._ledger: Optional[_ResumeLedger] = None
        self._replay_idx = 0
        self._resume_base = 0
        self._resume_skip = 0
        if (resume is not None and fanin == 1 and streams == 1
                and broadcast <= 1):
            self._ledger = _resume_ledger(resume)
            self._resume_base = len(self._ledger.staged)
        _reg_kw: dict = {"lease_s": lease_s} if lease_s else {}
        _res_kw: dict = (
            {"resume_seq": self._resume_base, "resume_epoch": attempt}
            if self._ledger is not None else {})
        if self._reg_ctx:
            _res_kw["trace"] = self._reg_ctx
        _t_rdv = time.monotonic()
        if fanin > 1:
            self._transport: Transport = self._rendezvous_fanin(
                rn, directory, transport, fanin, host, link, workers,
                streams=streams, window=stream_window,
                shm_capacity=shm_capacity, shm_doorbell=shm_doorbell)
        elif streams > 1:
            self._transport = self._rendezvous_striped(
                rn, directory, transport, streams, stream_window,
                host, link, shm_capacity, workers, shm_doorbell)
        elif transport == "channel":
            ch = channel if channel is not None else Channel()
            directory.register(
                rn.dataset, Endpoint(channel=ch, **_res_kw), rn.query_id,
                import_workers=workers, **_reg_kw,
            )
            self._transport = ChannelTransport(ch, link)
        elif transport == "shm" and broadcast > 1:
            self._transport = self._rendezvous_broadcast(
                rn, directory, broadcast, shm_capacity, shm_doorbell,
                link, workers)
        elif transport == "shm":
            ring = acquire_ring(shm_capacity, doorbell=shm_doorbell)
            directory.register(
                rn.dataset,
                Endpoint(shm_name=ring.name, shm_capacity=ring.capacity,
                         **_res_kw),
                rn.query_id,
                import_workers=workers, **_reg_kw,
            )
            self._transport = ShmRingTransport(ring, link)
        else:
            lsock = listen_socket(host)
            h, p = lsock.getsockname()
            directory.register(
                rn.dataset, Endpoint(h, p, **_res_kw), rn.query_id,
                import_workers=workers, **_reg_kw,
            )
            lsock.settimeout(60.0)
            conn, _ = lsock.accept()
            lsock.close()
            self._transport = SocketTransport(conn, link)
        if self._trace_on:
            self._tspans.append(("import.rendezvous", _t_rdv,
                                 time.monotonic(), None))
        self._recorder.note("import.connected")
        if getattr(directory, "degraded", False):
            # the rendezvous went through the directory client's local
            # fallback: the broker is down and both ends of this pipe
            # must live in this process for the exporter to find us
            self._recorder.note("import.degraded_rendezvous",
                                dataset=rn.dataset, query=rn.query_id)
            telemetry.counter("pipe.degraded_rendezvous").inc()
        # leased registration: keep re-stamping the directory entry while
        # this importer is alive; if it dies (thread or process), renewals
        # stop and the lease expires into the directory's dead-peer GC.
        # The heartbeat is an owned LeaseRenewer joined in close() — its
        # lifetime is the *handle's*, not any single transfer's, so the
        # same machinery serves long-lived subscription rings.
        self._renewer: Optional[LeaseRenewer] = None
        self._lease_lost = threading.Event()
        self._lease_msg = (
            f"directory lease lost for {rn.dataset!r} (query "
            f"{rn.query_id!r}): the registration expired and was GC'd "
            f"before the exporter arrived — re-register (retried attempts "
            f"do this automatically)")
        renew = getattr(directory, "renew", None)
        if lease_s and renew is not None:

            def _on_lost(rn=rn):
                # renew's documented 0: the lease expired and the
                # registration was GC'd.  Heartbeating a nonexistent
                # entry forever (while the exporter can never find us)
                # helps nobody — mark the pipe lease-lost, kick any wait
                # parked in the ring, and let the executor's retry path
                # re-register under a fresh attempt.
                self._recorder.note("import.lease_lost",
                                    dataset=rn.dataset, query=rn.query_id)
                self._lease_lost.set()
                ring = getattr(self._transport, "ring", None)
                if ring is not None:
                    ring.abort(self._lease_msg)

            self._renewer = LeaseRenewer(
                lambda ls, fn=renew, rn=rn: fn(rn.dataset, rn.query_id,
                                               lease_s=ls),
                lease_s, on_lost=_on_lost).start()
        self._arena = arena or DecodeArena()
        self.stats = PipeStats()
        self.schema: Optional[Schema] = None
        self.meta: dict = {}
        self._codec: Codec = get_codec("none")
        self._eof = False
        self._started = False
        self._verify_expected: List[str] = []
        self.verify_failures: List[str] = []
        # unified consumption state
        self._raw_tail = ""          # text rung: undelivered raw characters
        self._raw_chunks: List[bytes] = []  # bytes rung (binary passthrough)
        self._head_block: Optional[ColumnBlock] = None
        self._head_astrs: Optional[List[AString]] = None  # parts-mode head frame
        self._head_text: Optional[str] = None  # head block rendered (memoized)
        self._head_off = 0           # chars of head text consumed by read()
        self._header_pending = False  # header line not yet delivered as text

    # -- fabric rendezvous -------------------------------------------------------
    @staticmethod
    def _rendezvous_broadcast(rn, directory, readers, shm_capacity,
                              shm_doorbell, link, workers) -> Transport:
        """Join the transfer's broadcast ring as one of ``readers``
        cursors.  The directory hands out slot indexes: slot 0 creates
        the ring (it owns the segment, like every shm importer) and
        publishes its endpoint — which also registers it for the single
        exporter's ``query`` — and slots 1..R-1 attach to it."""
        slot, ep = directory.join_broadcast(
            rn.dataset, rn.query_id, readers=readers)
        if ep is None:  # first joiner: create (or re-lease warm) + publish
            from .shm_ring import acquire_broadcast_ring

            ring = acquire_broadcast_ring(shm_capacity, readers,
                                          doorbell=shm_doorbell)
            directory.publish_broadcast(
                rn.dataset,
                Endpoint(shm_name=ring.name, shm_capacity=ring.capacity,
                         broadcast=readers, shared=True),
                rn.query_id,
                import_workers=workers,
            )
        else:
            ring = ShmRing.attach(ep.shm_name, role="reader", slot=slot)
        return ShmRingTransport(ring, link)

    @staticmethod
    def _rendezvous_striped(rn, directory, transport, streams, window,
                            host, link, shm_capacity, workers,
                            shm_doorbell: bool = True) -> Transport:
        """Register one multi-endpoint group and reassemble N member
        connections into one ordered stream (repro.core.stream)."""
        if transport == "channel":
            chans = [Channel() for _ in range(streams)]
            members = tuple(Endpoint(channel=c) for c in chans)
            directory.register(rn.dataset, Endpoint(members=members),
                               rn.query_id, import_workers=workers)
            parts: List[Transport] = [ChannelTransport(c, link) for c in chans]
        elif transport == "shm":
            rings = [acquire_ring(shm_capacity, doorbell=shm_doorbell)
                     for _ in range(streams)]
            members = tuple(
                Endpoint(shm_name=r.name, shm_capacity=r.capacity)
                for r in rings)
            directory.register(rn.dataset, Endpoint(members=members),
                               rn.query_id, import_workers=workers)
            parts = [ShmRingTransport(r, link) for r in rings]
        else:
            lsocks = [listen_socket(host) for _ in range(streams)]
            members = tuple(
                Endpoint(*ls.getsockname()) for ls in lsocks)
            directory.register(rn.dataset, Endpoint(members=members),
                               rn.query_id, import_workers=workers)
            parts = []
            # the exporter (or the stub path) connects to the members in
            # registration order, so sequential accepts pair up correctly;
            # the listen backlog absorbs any out-of-order connects
            for ls in lsocks:
                ls.settimeout(60.0)
                conn, _ = ls.accept()
                ls.close()
                parts.append(SocketTransport(conn, link))
        return StripedReceiver(parts, window=window)

    @staticmethod
    def _rendezvous_fanin(rn, directory, transport, fanin, host, link,
                          workers, streams: int = 1,
                          window: int = DEFAULT_STREAM_WINDOW,
                          shm_capacity: int = DEFAULT_RING_CAPACITY,
                          shm_doorbell: bool = True,
                          ) -> Transport:
        """Register the shuffle's import-side rendezvous and merge
        ``fanin`` exporter streams.

        Two wirings:

        * **shared** (``streams == 1`` over socket/channel): one listening
          socket every exporter connects to (or one multi-producer
          channel), merged by :class:`FaninTransport` — the paper-shaped
          minimal rendezvous;
        * **slotted** (``streams > 1``, or the single-producer shm ring):
          one *private* rendezvous slot per exporter — a striped group of
          ``streams`` member connections (or a single connection) —
          registered as a ``shared`` group endpoint whose members the
          exporters claim by index via
          :meth:`WorkerDirectory.next_sender`.  Each slot reassembles
          through its own :class:`StripedReceiver`, then the slots merge
          through :class:`FaninTransport` — this is how ``streams`` and
          ``partition`` compose on one pipe.
        """
        if streams <= 1 and transport != "shm":
            if transport == "channel":
                ch = Channel(maxsize=64 * max(1, fanin))
                directory.register(
                    rn.dataset, Endpoint(channel=ch, shared=True),
                    rn.query_id, import_workers=workers,
                )
                # one shared multi-producer queue: exporters must not close
                # it under each other (Endpoint.shared), termination is
                # counted from the explicit EOF frames
                return FaninTransport([ChannelTransport(ch, link)],
                                      expected_sources=fanin)
            lsock = listen_socket(host)
            h, p = lsock.getsockname()
            directory.register(
                rn.dataset, Endpoint(h, p, shared=True), rn.query_id,
                import_workers=workers,
            )
            lsock.settimeout(60.0)
            conns: List[Transport] = []
            try:
                for _ in range(fanin):
                    conn, _ = lsock.accept()
                    conns.append(SocketTransport(conn, link))
            finally:
                lsock.close()
            return FaninTransport(conns)
        # slotted wiring: everything is registered before anything blocks,
        # so the exporters' query_all returns only once every importer
        # published its full slot table
        slot_eps: List[Endpoint] = []
        slot_parts: List[List[Transport]] = []
        slot_socks: List[List[socket.socket]] = []
        for _ in range(fanin):
            if transport == "channel":
                chans = [Channel() for _ in range(streams)]
                mems = tuple(Endpoint(channel=c) for c in chans)
                slot_parts.append([ChannelTransport(c, link) for c in chans])
                slot_socks.append([])
            elif transport == "shm":
                rings = [acquire_ring(shm_capacity, doorbell=shm_doorbell)
                         for _ in range(streams)]
                mems = tuple(
                    Endpoint(shm_name=r.name, shm_capacity=r.capacity)
                    for r in rings)
                slot_parts.append([ShmRingTransport(r, link) for r in rings])
                slot_socks.append([])
            else:
                lsocks = [listen_socket(host) for _ in range(streams)]
                mems = tuple(Endpoint(*ls.getsockname()) for ls in lsocks)
                slot_parts.append([])
                slot_socks.append(lsocks)
            slot_eps.append(mems[0] if streams == 1
                            else Endpoint(members=mems))
        directory.register(
            rn.dataset, Endpoint(members=tuple(slot_eps), shared=True),
            rn.query_id, import_workers=workers,
        )
        for parts, lsocks in zip(slot_parts, slot_socks):
            for ls in lsocks:
                ls.settimeout(60.0)
                conn, _ = ls.accept()
                ls.close()
                parts.append(SocketTransport(conn, link))
        slot_tr: List[Transport] = [
            StripedReceiver(parts, window=window) if streams > 1
            else parts[0]
            for parts in slot_parts
        ]
        return FaninTransport(slot_tr, expected_sources=fanin)

    # -- negotiation -------------------------------------------------------------
    def _check_lease(self) -> None:
        if self._lease_lost.is_set():
            raise attach_flight(BrokenPipeError(self._lease_msg),
                                self._recorder)

    def _start(self) -> None:
        if self._started:
            return
        self._check_lease()
        t0 = time.monotonic()
        if isinstance(self._transport, ShmRingTransport):
            # the handshake is not done until the schema frame lands: an
            # exporter that died at (or never reached) rendezvous would
            # otherwise park this importer on the ring forever — a shm
            # ring with no writer yet attached cannot distinguish "slow"
            # from "never coming" (socket importers get the same bound
            # from their accept/read timeouts)
            try:
                kind, payload = self._transport.recv_frame(
                    timeout=self._connect_timeout)
            except TimeoutError:
                raise attach_flight(TimeoutError(
                    f"no exporter wrote to {self.reserved.dataset!r} "
                    f"(query {self.reserved.query_id!r}) within "
                    f"{self._connect_timeout:g}s of rendezvous — it died "
                    f"or abandoned the attempt"), self._recorder) from None
        else:
            kind, payload = self._transport.recv_frame()
        if self._trace_on:
            self._tspans.append(("import.wait_schema", t0,
                                 time.monotonic(), None))
        if kind == FRAME_EOF:
            self._eof = True  # stub socket: orphaned importer (section 4.2)
            self._started = True
            self._recorder.note("import.orphaned_eof")
            return
        if kind != FRAME_SCHEMA:
            raise IOError(f"pipe stream must begin with schema frame, got {kind!r}")
        self.schema, self.meta = decode_schema(payload)
        self._recorder.note("import.schema", mode=self.meta.get("mode"))
        if not self._trace_ctx and self.meta.get("trace"):
            # adopt the exporter's trace from the hello: our spans parent
            # under its pipe span, landing both ends in one trace
            self._trace_ctx = str(self.meta["trace"])
        self._codec = get_codec(self.meta.get("codec", "none"))
        mode = self.meta.get("mode", "arrowcol")
        self._wire = (
            get_wire_format(mode) if mode not in ("text", "parts", "bytes") else None
        )
        self._parts_wire = PartsRowsFormat()
        self._header_pending = bool(self.meta.get("header"))
        self._started = True

    @property
    def mode(self) -> str:
        self._start()
        return self.meta.get("mode", "arrowcol")

    # -- frame pump (all protocols drain through here) -----------------------------
    def _recv_data_frame(self) -> Optional[Tuple[bytes, bytes]]:
        """Next (kind, decompressed payload) data frame, or None at EOF.
        VERIFY frames are absorbed into the expected-text buffer.  On a
        resumable edge the staged prefix (frames a previous attempt fully
        received) replays first — no wire reads — then wire frames are
        deduped against the watermark and staged as they arrive."""
        led = self._ledger
        if led is not None and self._replay_idx < len(led.staged):
            kind, data = led.staged[self._replay_idx]
            self._replay_idx += 1
            self.stats.resume_replayed += 1
            return kind, data
        while not self._eof:
            self._check_lease()
            if self._trace_on:
                t0 = time.monotonic()
                kind, payload = self._transport.recv_frame()
                if len(self._tspans) < _TSPAN_MAX:
                    self._tspans.append((
                        "import.wait", t0, time.monotonic(),
                        {"kind": bytes(kind).decode("ascii", "replace")}))
            else:
                kind, payload = self._transport.recv_frame()
            if kind == FRAME_EOF:
                self._eof = True
                return None
            if kind == FRAME_RESUME:
                # exporter hello: it restarts at `from`; frames between
                # that and our staged watermark arrive twice — drop them
                doc = json.loads(bytes(payload).decode("utf-8"))
                self._resume_skip = max(
                    0, self._resume_base - int(doc.get("from", 0)))
                self._recorder.note("import.resume_hello",
                                    epoch=doc.get("epoch"),
                                    dup_skip=self._resume_skip)
                continue
            if kind == FRAME_VERIFY:
                if self._resume_base:
                    continue  # verified (and staged) before the crash
                self._verify_expected.extend(payload.decode("utf-8").splitlines())
                continue
            data = self._codec.decompress(payload)
            if led is not None:
                if self._resume_skip:
                    self._resume_skip -= 1
                    continue  # duplicate of a staged frame
                # copy: shm payloads are live ring spans consumed by the
                # next recv, and a staged frame must outlive this attempt
                with led.lock:
                    led.staged.append((kind, bytes(data)))
                self._replay_idx = len(led.staged)
            return kind, data
        return None

    def _next_block(self) -> Optional[ColumnBlock]:
        """Decode the next typed block (non-text modes)."""
        frame = self._recv_data_frame()
        if frame is None:
            return None
        kind, data = frame
        t0 = time.monotonic() if self._trace_on else 0.0
        try:
            if kind == FRAME_BLOCK:
                block = self._wire.decode_block(data, self.schema,
                                                arena=self._arena)
                self._check_verify(block)
                return block
            if kind == FRAME_PARTS:
                return self._parts_to_block(data)
            if kind == FRAME_TEXT:
                return self._text_to_block(
                    data.decode("utf-8", "surrogatepass"))
            raise IOError(f"unexpected frame kind {kind!r}")  # pragma: no cover
        finally:
            if self._trace_on and len(self._tspans) < _TSPAN_MAX:
                self._tspans.append(("import.decode", t0,
                                     time.monotonic(), None))

    # -- typed fast path -----------------------------------------------------------
    def blocks(self) -> Iterator[ColumnBlock]:
        """Yield typed ColumnBlocks (the PipeGen fast path)."""
        self._start()
        if self.mode == "text":
            # text rung: raw characters; parse per line-batch (drain any
            # characters a header probe already pulled into the raw tail)
            tail, self._raw_tail = self._raw_tail, ""
            while True:
                cut = tail.rfind("\n")
                if cut >= 0:
                    blk = self._text_to_block(tail[: cut + 1])
                    tail = tail[cut + 1:]
                    if len(blk):
                        yield blk
                frame = self._recv_data_frame()
                if frame is None:
                    if tail:
                        blk = self._text_to_block(tail)
                        if len(blk):
                            yield blk
                    return
                tail += frame[1].decode("utf-8", "surrogatepass")
        # serve the (possibly partially peeked) head frame first
        head = self._take_head_typed()
        if head is not None:
            yield head
        while True:
            blk = self._next_block()
            if blk is None:
                return
            yield blk

    def astring_lines(self) -> Iterator[AString]:
        """Yield one AString per row with typed parts + delimiters restored,
        for decorated importers (AString.parse_* skips character parsing)."""
        self._start()
        mode = self.mode
        if mode == "text":
            # raw characters: one single-part AString per line (the importer
            # parses characters exactly as it would from a file); drain any
            # characters a header probe already pulled into the raw tail
            tail, self._raw_tail = self._raw_tail, ""
            while True:
                lines = tail.split("\n")
                tail = lines.pop()
                for line in lines:
                    yield AString((line,))
                frame = self._recv_data_frame()
                if frame is None:
                    if tail:
                        yield AString((tail,))
                    return
                tail += frame[1].decode("utf-8", "surrogatepass")
        if mode == "parts":
            head = self._take_head_astrs()
            if head is not None:
                for astr in head:
                    yield astr
            while True:
                frame = self._recv_data_frame()
                if frame is None:
                    return
                for astr in self._parts_wire.decode_parts(frame[1]):
                    yield astr
            return
        d = self.meta.get("delimiter") or ","
        hdr = self.meta.get("header")
        if hdr and self._header_pending:
            self._header_pending = False
            parts: List[Any] = []
            for j, nm in enumerate(hdr):
                if j:
                    parts.append(d)
                parts.append(nm)
            yield AString(parts)
        for block in self.blocks():
            rb = block.to_rows()
            for row in rb.rows:
                parts = []
                for j, v in enumerate(row):
                    if j:
                        parts.append(d)
                    parts.append(v)
                yield AString(parts)

    # -- character protocol ----------------------------------------------------------
    def _render(self, rb: RowBlock) -> str:
        if self.meta.get("text_format") == "json":
            return render_json(rb)
        return render_delimited(rb, self.meta.get("delimiter") or ",")

    def _take_head_typed(self) -> Optional[ColumnBlock]:
        """Pop the peeked head frame as a typed block (None if no head)."""
        if self._head_block is None and self._head_astrs is None:
            return None
        if self._head_off:
            raise IOError(
                "typed read after unbalanced character peek "
                f"({self._head_off} chars consumed)"
            )
        if self._head_block is not None:
            blk, self._head_block, self._head_text = self._head_block, None, None
            return blk
        astrs, self._head_astrs, self._head_text = self._head_astrs, None, None
        return self._astrs_to_block(astrs)

    def _take_head_astrs(self) -> Optional[List[AString]]:
        """Pop the peeked head frame as AStrings (parts mode)."""
        if self._head_astrs is None:
            return None
        if self._head_off:
            raise IOError(
                "typed read after unbalanced character peek "
                f"({self._head_off} chars consumed)"
            )
        astrs, self._head_astrs, self._head_text = self._head_astrs, None, None
        return astrs

    def _pop_head(self) -> None:
        self._head_block = None
        self._head_astrs = None
        self._head_text = None
        self._head_off = 0

    def _ensure_head_text(self) -> Optional[str]:
        """Rendered text of the current head frame (fetch one if needed)."""
        if self.mode == "text":
            raise AssertionError("_ensure_head_text is for typed modes")
        if self.mode == "parts":
            if self._head_astrs is None:
                frame = self._recv_data_frame()
                if frame is None:
                    return None
                self._head_astrs = list(self._parts_wire.decode_parts(frame[1]))
                self._head_text = None
            if self._head_text is None:
                self._head_text = "".join(
                    str(a) + "\n" for a in self._head_astrs
                )
            return self._head_text
        if self._head_block is None:
            self._head_block = self._next_block()
            self._head_text = None
            if self._head_block is None:
                return None
        if self._head_text is None:
            text = self._render(self._head_block.to_rows())
            if self._header_pending:
                hdr = self.meta.get("header")
                d = self.meta.get("delimiter") or ","
                text = d.join(hdr) + "\n" + text
                self._header_pending = False
            self._head_text = text
        return self._head_text

    def _pump_raw(self) -> bool:
        """Text/bytes rung: pull one frame of raw characters into the tail."""
        frame = self._recv_data_frame()
        if frame is None:
            return False
        enc = "latin-1" if self.mode == "bytes" else "utf-8"
        self._raw_tail += frame[1].decode(enc, "surrogatepass")
        return True

    def read(self, size: int = -1) -> str:
        self._start()
        if self.mode in ("text", "bytes"):
            while (size < 0 or len(self._raw_tail) < size) and self._pump_raw():
                pass
            if size < 0:
                s, self._raw_tail = self._raw_tail, ""
                return s
            s, self._raw_tail = self._raw_tail[:size], self._raw_tail[size:]
            return s
        out: List[str] = []
        got = 0
        while size < 0 or got < size:
            text = self._ensure_head_text()
            if text is None:
                break
            avail = text[self._head_off:]
            if size >= 0 and got + len(avail) > size:
                take = size - got
                out.append(avail[:take])
                self._head_off += take
                got += take
                break
            out.append(avail)
            got += len(avail)
            self._pop_head()
        return "".join(out)

    def unread(self, text: str) -> None:
        """Bounded pushback for header-probing clients (section 6.1: the
        HDFS client's read/rewind to sniff sequence-file magic).  Rewind is
        limited to characters consumed from the current head block."""
        if self.mode in ("text", "bytes"):
            self._raw_tail = text + self._raw_tail
            return
        if len(text) > self._head_off:
            raise IOError(
                f"unread({len(text)} chars) exceeds bounded rewind "
                f"({self._head_off} available)"
            )
        self._head_off -= len(text)

    def readline(self) -> str:
        self._start()
        if self.mode in ("text", "bytes"):
            while "\n" not in self._raw_tail:
                if not self._pump_raw():
                    s, self._raw_tail = self._raw_tail, ""
                    return s
            i = self._raw_tail.index("\n") + 1
            s, self._raw_tail = self._raw_tail[:i], self._raw_tail[i:]
            return s
        out: List[str] = []
        while True:
            text = self._ensure_head_text()
            if text is None:
                return "".join(out)
            nl = text.find("\n", self._head_off)
            if nl >= 0:
                out.append(text[self._head_off: nl + 1])
                self._head_off = nl + 1
                if self._head_off >= len(text):
                    self._pop_head()
                return "".join(out)
            out.append(text[self._head_off:])
            self._pop_head()

    def read_bytes(self, size: int = -1) -> bytes:
        """Binary passthrough (shared-binary-format pipes, e.g. seqfiles)."""
        self._start()
        buf = self._raw_tail.encode("latin-1", "surrogatepass") + b"".join(self._raw_chunks)
        self._raw_tail = ""
        self._raw_chunks = []
        while size < 0 or len(buf) < size:
            frame = self._recv_data_frame()
            if frame is None:
                break
            buf += frame[1]
        if size >= 0 and len(buf) > size:
            self._raw_chunks = [buf[size:]]
            buf = buf[:size]
        return buf

    def __iter__(self) -> Iterator[str]:
        while True:
            line = self.readline()
            if not line:
                return
            yield line

    def close(self) -> None:
        if self._renewer is not None:
            # join, don't fire-and-forget: a renewer outliving its pipe
            # would keep heartbeating a dead registration (the leak the
            # live_renewers() assertion in the tests guards against)
            self._renewer.stop(join=True)
        self.stats.decode_pool_hits = self._arena.hits
        self.stats.decode_pool_misses = self._arena.misses
        self.stats.shm_spans = getattr(self._transport, "shm_spans", 0)
        self.stats.doorbell_waits = getattr(
            self._transport, "doorbell_waits", 0)
        self.stats.spin_wakeups = getattr(self._transport, "spin_wakeups", 0)
        self.stats.poll_sleeps = getattr(self._transport, "poll_sleeps", 0)
        per_stream = getattr(self._transport, "per_stream", None)
        if per_stream is not None:
            self.stats.per_stream = per_stream()
        _record_stats(self.reserved, "import", self.stats,
                      attempt=self._attempt)
        self._recorder.note("import.close",
                            replayed=self.stats.resume_replayed,
                            rows=self.stats.rows)
        self._emit_spans()
        self._transport.close()

    def _emit_spans(self) -> None:
        """Record the import-side lifecycle spans under the resolved
        trace context (hello > registration > fresh root)."""
        tr = telemetry.tracer()
        if not self._trace_on or tr is None:
            return
        ctx = self._trace_ctx or self._reg_ctx or telemetry.new_trace_ctx()
        trace_id, parent = telemetry.split_ctx(ctx)
        rn = self.reserved
        pipe_sid = tr.record(
            "import.pipe", self._t_open, time.monotonic(),
            trace_id=trace_id, parent_id=parent,
            attrs={"dataset": rn.dataset, "query": rn.query_id,
                   "attempt": self._attempt,
                   "mode": self.meta.get("mode"),
                   "rows": self.stats.rows,
                   "replayed": self.stats.resume_replayed})
        for name, t0, t1, attrs in self._tspans:
            tr.record(name, t0, t1, trace_id=trace_id,
                      parent_id=pipe_sid, attrs=attrs)

    def __enter__(self) -> "DataPipeInput":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- helpers ---------------------------------------------------------------------
    def _parts_to_block(self, data: bytes) -> ColumnBlock:
        return self._astrs_to_block(self._parts_wire.decode_parts(data))

    def _astrs_to_block(self, astrs) -> ColumnBlock:
        asm = DelimitedAssembler(sample_rows=8)
        if self.meta.get("delimiter"):
            asm.delimiter = self.meta["delimiter"]
            asm._sampling = False
        for astr in astrs:
            asm.write(astr)
            asm.write(AString(("\n",)))
        asm.flush()
        return asm.take_rows().to_columns(arena=self._arena)

    _TEXT_DELIMS = (",", "\t", ";", "|")

    def _text_to_block(self, text: str) -> ColumnBlock:
        """Text rung (IORedirect only): the payload is raw characters, so
        parse it the way the receiving engine would — split lines, sniff the
        delimiter, keep cells as strings (the importer re-parses types)."""
        lines = [l for l in text.split("\n") if l != ""]
        if not lines:
            return ColumnBlock(Schema([]), [])
        d = self.meta.get("delimiter")
        if not d:
            for cand in self._TEXT_DELIMS:
                widths = {l.count(cand) for l in lines}
                if len(widths) == 1 and widths.pop() > 0:
                    d = cand
                    break
            d = d or ","
        rows = [tuple(l.split(d)) for l in lines]
        width = max(len(r) for r in rows)
        from .types import Field, ColType
        schema = Schema([Field(f"column{i+1}", ColType.STRING) for i in range(width)])
        rows = [r + ("",) * (width - len(r)) for r in rows]
        return RowBlock(schema, rows).to_columns()

    def _check_verify(self, block: ColumnBlock) -> None:
        if not self._verify_expected:
            return
        rb = block.to_rows()
        n = min(len(self._verify_expected), len(rb.rows))
        got = self._render(RowBlock(rb.schema, rb.rows[:n])).splitlines()
        for want, have in zip(self._verify_expected[:n], got):
            if want != have:
                self.verify_failures.append(f"want {want!r} got {have!r}")
        del self._verify_expected[:n]
        if self.verify_failures:
            raise IOError(
                "data pipe verification failed: " + "; ".join(self.verify_failures)
            )


def _cheap_len(s: Any) -> int:
    """File-protocol return value without materializing the AString (the
    write() return is the number of characters a file would have taken;
    engines ignore it, so a cheap proxy suffices)."""
    if isinstance(s, AString):
        return len(s.parts)
    return len(s) if isinstance(s, str) else 1


def _connect(ep: Endpoint, link: Optional[LinkSim]) -> Transport:
    if ep.is_channel:
        # a shared channel (shuffle fan-in) is torn down by EOF counting,
        # not by any single finishing exporter
        return ChannelTransport(ep.channel, link, owns_channel=not ep.shared)
    if ep.is_shm:
        if ep.broadcast > 1:
            # broadcast ring: the single writer of an R-reader fan-out
            # (never cached — the slot table is single-use)
            return ShmRingTransport(
                ShmRing.attach(ep.shm_name, role="writer"), link)
        return ShmRingTransport(attach_ring(ep.shm_name), link)
    s = socket.create_connection((ep.host, ep.port), timeout=30.0)
    return SocketTransport(s, link)


# -- convenience API (used by engines' generated adapters) ------------------------

def open_pipe_writer(filename: str, config: Optional[PipeConfig] = None, **kw) -> DataPipeOutput:
    return DataPipeOutput(filename, config=config, **kw)


def open_pipe_reader(filename: str, **kw) -> DataPipeInput:
    return DataPipeInput(filename, **kw)
