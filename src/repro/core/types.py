"""Core value/schema/block types shared by the PipeGen data plane.

The paper's pipes move relational tuples whose attributes are fixed-width
primitives or strings.  We model that with an explicit column-typed schema
and two block representations:

* ``RowBlock``   -- a list of row tuples (what text serializers naturally
  produce/consume, row-major).
* ``ColumnBlock`` -- column-major numpy buffers + a string heap (what the
  Arrow-analog wire format and the JAX input pipeline consume).

Blocks are the unit of transfer on a data pipe: exporters accumulate rows
into blocks, the FormOpt layer pivots them (paper section 5.4), and the wire
format serializes whole blocks.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Any, Iterable, Sequence

import numpy as np

__all__ = [
    "ColType",
    "Field",
    "Schema",
    "RowBlock",
    "ColumnBlock",
    "infer_schema",
    "schema_of_value",
]


class ColType(enum.Enum):
    """Column types supported on the wire (paper: ints, doubles, strings)."""

    INT32 = "int32"
    INT64 = "int64"
    FLOAT32 = "float32"
    FLOAT64 = "float64"
    BOOL = "bool"
    STRING = "string"

    @property
    def is_fixed_width(self) -> bool:
        return self is not ColType.STRING

    @property
    def np_dtype(self) -> np.dtype:
        if self is ColType.STRING:
            # string columns are materialized as object arrays host-side
            return np.dtype(object)
        return np.dtype(self.value)

    @property
    def width(self) -> int:
        """Fixed byte width (0 for variable-length strings)."""
        return {
            ColType.INT32: 4,
            ColType.INT64: 8,
            ColType.FLOAT32: 4,
            ColType.FLOAT64: 8,
            ColType.BOOL: 1,
            ColType.STRING: 0,
        }[self]


@dataclass(frozen=True)
class Field:
    name: str
    type: ColType

    def to_dict(self) -> dict:
        return {"name": self.name, "type": self.type.value}

    @staticmethod
    def from_dict(d: dict) -> "Field":
        return Field(d["name"], ColType(d["type"]))


class Schema:
    """An ordered collection of named, typed columns."""

    __slots__ = ("fields", "_name_index")

    def __init__(self, fields: Sequence[Field]):
        self.fields = tuple(fields)
        self._name_index = {f.name: i for i, f in enumerate(self.fields)}

    # -- construction helpers -------------------------------------------------
    @staticmethod
    def of(*pairs: tuple) -> "Schema":
        return Schema([Field(name, ct) for name, ct in pairs])

    @staticmethod
    def from_dict(d: dict) -> "Schema":
        return Schema([Field.from_dict(f) for f in d["fields"]])

    def to_dict(self) -> dict:
        return {"fields": [f.to_dict() for f in self.fields]}

    # -- protocol -------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.fields)

    def __iter__(self):
        return iter(self.fields)

    def __getitem__(self, i):
        if isinstance(i, str):
            return self.fields[self._name_index[i]]
        return self.fields[i]

    def __eq__(self, other) -> bool:
        return isinstance(other, Schema) and self.fields == other.fields

    def __hash__(self) -> int:
        return hash(self.fields)

    def __repr__(self) -> str:
        inner = ", ".join(f"{f.name}:{f.type.value}" for f in self.fields)
        return f"Schema({inner})"

    def index_of(self, name: str) -> int:
        return self._name_index[name]

    @property
    def names(self) -> tuple:
        return tuple(f.name for f in self.fields)

    @property
    def types(self) -> tuple:
        return tuple(f.type for f in self.fields)

    @property
    def fixed_row_width(self) -> int:
        """Bytes per row counting only fixed-width columns."""
        return sum(f.type.width for f in self.fields)


_PY_TO_COLTYPE = {
    bool: ColType.BOOL,
    int: ColType.INT64,
    float: ColType.FLOAT64,
    str: ColType.STRING,
}


def schema_of_value(v: Any) -> ColType:
    for py, ct in _PY_TO_COLTYPE.items():
        if isinstance(v, py):
            return ct
    if isinstance(v, (np.integer,)):
        return ColType.INT64
    if isinstance(v, (np.floating,)):
        return ColType.FLOAT64
    raise TypeError(f"unsupported value type: {type(v)!r}")


def infer_schema(row: Sequence[Any], names: Sequence[str] | None = None) -> Schema:
    names = names or [f"column{i + 1}" for i in range(len(row))]
    return Schema([Field(n, schema_of_value(v)) for n, v in zip(names, row)])


class RowBlock:
    """Row-major block: what text serializers produce one line at a time."""

    __slots__ = ("schema", "rows")

    def __init__(self, schema: Schema, rows: list):
        self.schema = schema
        self.rows = rows

    def __len__(self) -> int:
        return len(self.rows)

    def to_columns(self, arena=None) -> "ColumnBlock":
        """Pivot row-major -> column-major (paper section 5.4, host side).
        ``arena`` (a :class:`~repro.core.iobuf.DecodeArena`) supplies pooled
        backing stores for the fixed-width output columns."""
        n = len(self.rows)
        cols: list = []
        if n == 0:
            for f in self.schema:
                cols.append(
                    [] if f.type is ColType.STRING else np.empty(0, f.type.np_dtype)
                )
            return ColumnBlock(self.schema, cols)
        for j, f in enumerate(self.schema):
            vals = [r[j] for r in self.rows]
            if f.type is ColType.STRING:
                cols.append(vals)
            elif arena is not None:
                cols.append(arena.take(f.type.np_dtype, n, vals))
            else:
                cols.append(np.asarray(vals, dtype=f.type.np_dtype))
        return ColumnBlock(self.schema, cols)


class ColumnBlock:
    """Column-major block: numpy buffers per fixed-width column, python list
    for string columns.  The unit the Arrow-analog wire format serializes."""

    __slots__ = ("schema", "columns")

    def __init__(self, schema: Schema, columns: Sequence[Any]):
        assert len(columns) == len(schema)
        self.schema = schema
        self.columns = list(columns)

    def __len__(self) -> int:
        if not self.columns:
            return 0
        c0 = self.columns[0]
        return len(c0)

    @property
    def nbytes(self) -> int:
        total = 0
        for f, c in zip(self.schema, self.columns):
            if f.type is ColType.STRING:
                total += sum(len(s.encode("utf-8", "surrogatepass")) + 4 for s in c)
            else:
                total += c.nbytes
        return total

    def to_rows(self) -> RowBlock:
        n = len(self)
        pycols = []
        for f, c in zip(self.schema, self.columns):
            if f.type is ColType.STRING:
                pycols.append(c)
            else:
                pycols.append(c.tolist())
        rows = list(zip(*pycols)) if pycols else [()] * n
        return RowBlock(self.schema, rows)

    def column(self, name: str):
        return self.columns[self.schema.index_of(name)]

    @staticmethod
    def concat(blocks: Sequence["ColumnBlock"]) -> "ColumnBlock":
        blocks = [b for b in blocks if len(b)]
        if not blocks:
            raise ValueError("no non-empty blocks to concat")
        schema = blocks[0].schema
        cols = []
        for j, f in enumerate(schema):
            if f.type is ColType.STRING:
                out: list = []
                for b in blocks:
                    out.extend(b.columns[j])
                cols.append(out)
            else:
                cols.append(np.concatenate([b.columns[j] for b in blocks]))
        return ColumnBlock(schema, cols)

    @staticmethod
    def from_arrays(names: Sequence[str], arrays: Sequence[Any]) -> "ColumnBlock":
        fields = []
        cols = []
        for n, a in zip(names, arrays):
            if isinstance(a, np.ndarray):
                fields.append(Field(n, ColType(str(a.dtype))))
                cols.append(a)
            else:
                fields.append(Field(n, ColType.STRING))
                cols.append(list(a))
        return ColumnBlock(Schema(fields), cols)
