"""AString -- the augmented string of paper section 5.1.

An ``AString`` behaves like a string to the surrounding serializer code but
internally stores the *sequence of typed values* that flowed into it, so the
data pipe can recover pre-stringification primitives: given

    s = str(1) + "," + "a"

the decorated form

    s = AString.of(1) + AString.of(",") + AString.of("a")

keeps the internal state ``[1, ",", "a"]`` and only materializes the
character representation on demand (memoized).  Fixed-width primitives in the
internal state are what FormOpt ships in binary; delimiter parts are inferred
and dropped (section 5.3.1).

Python notes versus the paper's Java implementation (section 6.2):

* Java needed a non-final ``java.lang.String`` loaded via dynamic code
  loading; Python duck-types, so ``AString`` simply implements the string
  protocol surface our engines use and compares equal to ``str``.
* Java AStrings flatten into preallocated byte arrays; we keep a python list
  of parts (numpy handles the bulk fixed-width traffic at block level, which
  is where the time goes in this runtime).
"""

from __future__ import annotations

from typing import Any, Iterable, List, Sequence

__all__ = ["AString", "materialize_part", "PRIMITIVE_TYPES"]

PRIMITIVE_TYPES = (bool, int, float)


def materialize_part(p: Any) -> str:
    """Render one internal part exactly like the engines' text writers do."""
    if isinstance(p, str):
        return p
    if isinstance(p, bool):
        return "true" if p else "false"
    if isinstance(p, float):
        return repr(p)  # shortest round-trip representation
    return str(p)


class AString:
    """Deferred-value string.  Immutable; concatenation produces new views.

    Concatenation is O(1): views share a lazily-flattened part tree (the
    paper's Java implementation appends into preallocated arrays, section
    6.2 — same amortized complexity, expressed immutably)."""

    __slots__ = ("_parts", "_tree", "_mat")

    def __init__(self, parts: Sequence[Any]):
        self._parts: tuple | None = tuple(parts)
        self._tree: tuple | None = None
        self._mat: str | None = None

    @property
    def parts(self) -> tuple:
        if self._parts is None:
            # flatten the concat tree iteratively (amortized once per view)
            out: List[Any] = []
            stack = [self._tree]
            while stack:
                node = stack.pop()
                if isinstance(node, AString):
                    if node._parts is not None:
                        out.extend(node._parts)
                    else:
                        stack.append(node._tree[1])
                        stack.append(node._tree[0])
                elif isinstance(node, tuple) and len(node) == 2 and (
                        isinstance(node[0], (AString, tuple))
                        or isinstance(node[1], (AString, tuple))):
                    stack.append(node[1])
                    stack.append(node[0])
                else:
                    out.append(node)
            self._parts = tuple(out)
            self._tree = None
        return self._parts

    @classmethod
    def _concat(cls, left, right) -> "AString":
        obj = cls.__new__(cls)
        obj._parts = None
        obj._tree = (left, right)
        obj._mat = None
        return obj

    # -- constructors ---------------------------------------------------------
    @staticmethod
    def of(value: Any) -> "AString":
        """Wrap a single value.  Complex objects are stringified immediately
        (paper: 'more complex types are immediately converted')."""
        if isinstance(value, AString):
            return value
        if isinstance(value, (str,) + PRIMITIVE_TYPES):
            return AString((value,))
        return AString((str(value),))

    @staticmethod
    def literal(s: str) -> "AString":
        return AString((s,))

    # -- string protocol surface ----------------------------------------------
    def materialize(self) -> str:
        if self._mat is None:
            self._mat = "".join(materialize_part(p) for p in self.parts)
        return self._mat

    def __str__(self) -> str:
        return self.materialize()

    def __repr__(self) -> str:
        return f"AString({list(self.parts)!r})"

    def __len__(self) -> int:
        return len(self.materialize())

    def __eq__(self, other) -> bool:
        if isinstance(other, AString):
            return self.materialize() == other.materialize()
        if isinstance(other, str):
            return self.materialize() == other
        return NotImplemented

    def __hash__(self) -> int:
        return hash(self.materialize())

    def __add__(self, other) -> "AString":
        if isinstance(other, AString):
            return AString._concat(self, other)
        if isinstance(other, (str,) + PRIMITIVE_TYPES):
            return AString._concat(self, AString((other,)))
        return NotImplemented

    def __radd__(self, other) -> "AString":
        if isinstance(other, (str,) + PRIMITIVE_TYPES):
            return AString._concat(AString((other,)), self)
        return NotImplemented

    def concat(self, other) -> "AString":
        return self.__add__(AString.of(other))

    def join(self, items: Iterable[Any]) -> "AString":
        """Separator-join preserving typed parts (CSV writers use this)."""
        out: List[Any] = []
        first = True
        for it in items:
            if not first:
                out.extend(self.parts)
            first = False
            if isinstance(it, AString):
                out.extend(it.parts)
            else:
                out.append(it if isinstance(it, (str,) + PRIMITIVE_TYPES) else str(it))
        return AString(out)

    def encode(self, encoding: str = "utf-8", errors: str = "strict") -> bytes:
        return self.materialize().encode(encoding, errors)

    # -- import-side operations (section 5.1: split & parse without
    # materializing character strings when typed parts are available) ---------
    def split(self, sep: str) -> list:
        vals: List[AString] = []
        cur: List[Any] = []
        for p in self.parts:
            if isinstance(p, str) and p == sep:
                vals.append(AString(cur))
                cur = []
            elif isinstance(p, str) and sep in p and len(p) > 1:
                # mixed structural text: fall back to materialized split
                return [AString((s,)) for s in self.materialize().split(sep)]
            else:
                cur.append(p)
        vals.append(AString(cur))
        return vals

    def strip(self, chars: str | None = None) -> "AString":
        parts = list(self.parts)
        while parts and isinstance(parts[0], str) and not parts[0].strip(chars):
            parts.pop(0)
        while parts and isinstance(parts[-1], str) and not parts[-1].strip(chars):
            parts.pop()
        if parts and isinstance(parts[0], str):
            parts[0] = parts[0].lstrip(chars)
        if parts and isinstance(parts[-1], str):
            parts[-1] = parts[-1].rstrip(chars)
        return AString(parts)

    # -- typed access ----------------------------------------------------------
    @property
    def sole_value(self) -> Any:
        """The single typed value if this AString wraps exactly one part."""
        if len(self.parts) == 1:
            return self.parts[0]
        return self.materialize()

    @staticmethod
    def parse_int(v: Any) -> int:
        if isinstance(v, AString):
            sv = v.sole_value
            if isinstance(sv, bool):
                return int(sv)
            if isinstance(sv, int):
                return sv  # no character parsing needed -- the paper's win
            return int(str(sv))
        return int(v)

    @staticmethod
    def parse_float(v: Any) -> float:
        if isinstance(v, AString):
            sv = v.sole_value
            if isinstance(sv, (int, float)) and not isinstance(sv, bool):
                return float(sv)
            return float(str(sv))
        return float(v)

    @staticmethod
    def parse_bool(v: Any) -> bool:
        if isinstance(v, AString):
            sv = v.sole_value
            if isinstance(sv, bool):
                return sv
            return str(sv).strip().lower() in ("true", "1")
        return str(v).strip().lower() in ("true", "1")
