"""Pipe broker: the long-lived control plane (ROADMAP's resident daemon).

Every transfer used to stand up its own directory, renewal threads, and
fds, and tear them down again — fine for one session, hopeless for the
paper's "colocated or cross-cluster" deployments where *thousands* of
concurrent plans from many tenants share one machine.  A
:class:`PipeBroker` is one resident object (optionally served over TCP)
that owns the four things a shared control plane must own:

* **Doorbell hub** (:class:`DoorbellHub`): ONE selector thread
  multiplexing every ring doorbell fifo/eventfd in the process.  Each
  blocked wait parks on a ``threading.Event`` instead of running its own
  poll syscall loop, so wait cost scales with wakeups, not with the
  number of idle rings — and because ``selectors``/``poll`` carry fds by
  value there is no FD_SETSIZE ceiling (``select.select`` crashed at
  fd >= 1024).
* **Admission control + QoS** (:meth:`PipeBroker.admit`): plans declare
  a tenant and a class (``latency`` | ``bulk``) and a resource vector
  (rings, segments, bytes).  Over-quota requests *queue* (latency ahead
  of bulk, FIFO within a class) instead of failing or oversubscribing;
  quota is enforced globally and per tenant — the CDC generator's
  db-per-tenant / db-shared split: isolated budgets over one shared
  fabric.  This is also what keeps process fd count flat under fan-out:
  admission bounds the number of *live* rings regardless of how many
  plans are in flight.
* **Warm-pool ownership**: the shm ring pool, broadcast warm-park, and
  writer mapping cache (``repro.core.shm_ring``) survive individual plan
  lifetimes already; the broker raises their depth to serving-fleet
  scale, drains them on shutdown, and — because parked segments release
  their doorbell fds — idle pool residency costs mappings, not fds.
* **Lease GC + crash sweep**: the broker's reaper runs
  :meth:`WorkerDirectory.sweep` on a period (expired/dead registrations
  dropped, orphaned shm segments and doorbell fifos unlinked), the duty
  the per-transfer ``DirectoryServer`` reaper used to carry.

``PipeBroker.install()`` makes the broker the process-global control
plane: the plan executor then routes rendezvous through the broker's
directory and wraps every work unit in an admission ticket (edge
options ``tenant=...`` / ``qos=...``).
"""

from __future__ import annotations

import heapq
import itertools
import os
import threading
import time
import selectors
from contextlib import contextmanager
from dataclasses import asdict, dataclass
from typing import Any, Dict, List, Optional, Tuple

from .directory import (DirectoryClient, DirectoryServer, WorkerDirectory,
                        get_directory, set_directory)
from . import journal as journal_mod
from . import shm_ring
from . import telemetry
from .iobuf import default_pool

__all__ = ["PipeBroker", "BrokerClient", "DoorbellHub", "TenantQuota",
           "BrokerBusy", "Admission", "NullAdmission", "RemoteAdmission",
           "QOS_CLASSES", "get_broker", "set_broker", "process_fd_count"]

#: admission classes, in scheduling priority order: a queued ``latency``
#: ticket is always admitted before a queued ``bulk`` ticket that fits
QOS_CLASSES = ("latency", "bulk")


class BrokerBusy(RuntimeError):
    """Admission was refused: the request can never fit its quota, or it
    queued past its timeout."""


def process_fd_count() -> int:
    """Open fds of this process (the broker's flatness metric)."""
    try:
        return len(os.listdir("/proc/self/fd"))
    except OSError:  # pragma: no cover - no procfs
        return -1


# -- doorbell hub -------------------------------------------------------------------


class DoorbellHub:
    """One selector thread multiplexing every doorbell fd in the process.

    Waiters (``_Doorbell.wait`` routes here while a hub is installed)
    park on a per-doorbell ``threading.Event``; the hub's loop drains the
    readable fd and sets the event.  The event is only cleared by the
    *waiter after a successful wait*, never at wait entry, so a ring that
    lands between the waiter's readiness check and its park is a spurious
    early wakeup (the caller re-checks readiness and parks again), never
    a lost one.  Registration is lazy (first hub-mediated wait) and
    undone by ``_Doorbell.close`` via :meth:`discard`."""

    def __init__(self):
        self._sel = selectors.DefaultSelector()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._wake_r, self._wake_w = os.pipe()
        os.set_blocking(self._wake_r, False)
        self._sel.register(self._wake_r, selectors.EVENT_READ, None)
        self._thread: Optional[threading.Thread] = None
        self.waits = 0
        self.wakeups = 0
        self.registered = 0  # doorbells currently multiplexed

    def start(self) -> "DoorbellHub":
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="pipegen-doorbell-hub")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self._wake()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        with self._lock:
            try:
                self._sel.close()
            except OSError:  # pragma: no cover
                pass
            for fd in (self._wake_r, self._wake_w):
                try:
                    os.close(fd)
                except OSError:  # pragma: no cover
                    pass

    def _wake(self) -> None:
        try:
            os.write(self._wake_w, b"\x01")
        except OSError:  # pragma: no cover - mid-shutdown
            pass

    def wait(self, db, timeout: float) -> bool:
        """Park until ``db`` rings (or ``timeout``).  Called from
        ``_Doorbell.wait`` whenever this hub is installed process-wide."""
        if self._stop.is_set():
            raise RuntimeError("doorbell hub stopped")
        ev = db.hub_event
        if ev is None:
            ev = self._register(db)
        self.waits += 1
        if ev.wait(max(0.0, timeout)):
            ev.clear()
            return True
        return False

    def _register(self, db) -> threading.Event:
        ev = threading.Event()
        with self._lock:
            if db.hub_event is not None:  # raced another wait
                return db.hub_event
            for fd, is_evfd in self._db_fds(db):
                try:  # a dead entry may still hold this recycled fd number
                    self._sel.unregister(fd)
                except (KeyError, ValueError):
                    pass
                self._sel.register(fd, selectors.EVENT_READ, (ev, is_evfd))
            db.hub_event = ev
            self.registered += 1
        # poll-backend selectors snapshot their fd set per select() call:
        # force a re-poll so the new doorbell is live now, not after the
        # current select slice expires
        self._wake()
        return ev

    def discard(self, db) -> None:
        """Drop a doorbell's fds from the selector (its close path)."""
        with self._lock:
            if db.hub_event is None:
                return
            for fd, _ in self._db_fds(db):
                try:
                    self._sel.unregister(fd)
                except (KeyError, ValueError, OSError):
                    pass
            db.hub_event = None
            self.registered -= 1

    @staticmethod
    def _db_fds(db) -> List[Tuple[int, bool]]:
        fds = [(db.fd, False)]
        if db.evfd is not None:
            fds.append((db.evfd, True))
        return fds

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                events = self._sel.select(timeout=0.5)
            except (OSError, RuntimeError):  # pragma: no cover - shutdown race
                if self._stop.is_set():
                    return
                continue
            for key, _ in events:
                if key.data is None:  # the wake pipe
                    try:
                        os.read(self._wake_r, 4096)
                    except OSError:  # pragma: no cover
                        pass
                    continue
                ev, is_evfd = key.data
                try:
                    if is_evfd:
                        os.eventfd_read(key.fd)
                    else:
                        os.read(key.fd, 64)
                except OSError:
                    pass  # fd raced a close; the unregister is in flight
                ev.set()
                self.wakeups += 1


# -- admission control --------------------------------------------------------------


@dataclass(frozen=True)
class TenantQuota:
    """Per-tenant ceilings (``None`` = unlimited): concurrent rings,
    concurrent shm segments, and summed ring bytes."""

    max_rings: Optional[int] = None
    max_segments: Optional[int] = None
    max_bytes: Optional[int] = None


class _Ticket:
    __slots__ = ("prio", "seq", "tenant", "qos", "rings", "segments",
                 "nbytes", "epoch", "rid", "holder", "deadline", "state")

    def __init__(self, prio, seq, tenant, qos, rings, segments, nbytes,
                 epoch=0, holder=0, deadline=0.0):
        self.prio = prio
        self.seq = seq
        self.tenant = tenant
        self.qos = qos
        self.rings = rings
        self.segments = segments
        self.nbytes = nbytes
        self.epoch = epoch          # broker incarnation that minted it
        self.rid = f"{epoch}.{seq}"  # journal/RPC ticket id
        self.holder = holder        # remote holder pid (reaper sweep)
        self.deadline = deadline    # remote reservation expiry
        self.state = "queued"       # remote: queued | granted | expired

    def __lt__(self, other):  # heap order: class priority, then FIFO
        return (self.prio, self.seq) < (other.prio, other.seq)


class Admission:
    """A granted admission ticket; a context manager whose exit releases
    the resources back to the broker.

    Release is **idempotent and thread-safe**: the flag flips under a
    lock, so a double ``__exit__`` (or an explicit release racing the
    context exit from another thread) can never credit the budget back
    twice — the check-then-act race the naive boolean had."""

    degraded = False

    def __init__(self, broker: "PipeBroker", ticket: _Ticket):
        self._broker = broker
        self._ticket = ticket
        self._lock = threading.Lock()
        self._released = False

    def release(self) -> None:
        with self._lock:
            if self._released:
                return
            self._released = True
        self._broker._release(self._ticket)

    def __enter__(self) -> "Admission":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class NullAdmission:
    """The no-op ticket handed out while the control plane is
    unreachable (degraded mode): admission is suspended rather than
    wedging the plans the degraded ladder exists to keep draining."""

    degraded = True
    ticket = None

    def release(self) -> None:
        pass

    def __enter__(self) -> "NullAdmission":
        return self

    def __exit__(self, *exc) -> None:
        pass


class RemoteAdmission:
    """An admission granted by an out-of-process broker over RPC.

    Same idempotence contract as :class:`Admission`, enforced twice: the
    client-side flag stops double RPCs, and the broker drops the ticket
    id on first release — a replayed or stale-epoch release is rejected
    there, never double-credited."""

    degraded = False

    def __init__(self, client: DirectoryClient, ticket: str):
        self._client = client
        self.ticket = ticket
        self._lock = threading.Lock()
        self._released = False

    def release(self) -> None:
        with self._lock:
            if self._released:
                return
            self._released = True
        try:
            self._client._rpc({"op": "release", "ticket": self.ticket})
        except (OSError, ValueError):
            pass  # broker gone: its recovery expires the grant

    def __enter__(self) -> "RemoteAdmission":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


# -- the broker ---------------------------------------------------------------------


class PipeBroker:
    """The resident control plane: directory + doorbell hub + admission
    + warm pools + lease/crash sweeping, in one start/stoppable object.

    In-process by default; ``serve=True`` additionally exposes the same
    ``WorkerDirectory`` over TCP (a :class:`DirectoryServer` with the
    bounded handler pool) for multi-process deployments."""

    def __init__(self,
                 lease_ttl: Optional[float] = 30.0,
                 sweep_every: Optional[float] = None,
                 orphan_min_age_s: float = 30.0,
                 serve: bool = False,
                 host: str = "127.0.0.1",
                 port: int = 0,
                 handlers: int = 8,
                 max_rings: Optional[int] = 64,
                 max_segments: Optional[int] = None,
                 max_bytes: Optional[int] = None,
                 default_quota: Optional[TenantQuota] = None,
                 tenants: Optional[Dict[str, TenantQuota]] = None,
                 qos_concurrency: Optional[Dict[str, Optional[int]]] = None,
                 admit_timeout: float = 30.0,
                 pool_park_max: Optional[int] = 16,
                 hub: bool = True,
                 journal_path: Optional[str] = None,
                 journal_fsync_batch: int = 8,
                 checkpoint_bytes: int = 1 << 20):
        self.directory = WorkerDirectory(lease_ttl=lease_ttl)
        self._hub_enabled = hub
        self.hub: Optional[DoorbellHub] = DoorbellHub() if hub else None
        self.server: Optional[DirectoryServer] = None
        self._serve = serve
        self._host, self._port, self._handlers = host, port, handlers
        self.max_rings = max_rings
        self.max_segments = max_segments
        self.max_bytes = max_bytes
        self.default_quota = default_quota or TenantQuota()
        self.tenants = dict(tenants or {})
        self.qos_concurrency = dict(qos_concurrency or {})
        for q in self.qos_concurrency:
            if q not in QOS_CLASSES:
                raise ValueError(f"unknown QoS class {q!r}; have "
                                 f"{QOS_CLASSES}")
        self.admit_timeout = admit_timeout
        self.pool_park_max = pool_park_max
        self._sweep_every = sweep_every or (lease_ttl / 2 if lease_ttl
                                            else 15.0)
        self.orphan_min_age_s = orphan_min_age_s
        # admission state
        self._cv = threading.Condition()
        self._seq = itertools.count()
        self._waiting: List[_Ticket] = []  # heap: (class prio, FIFO seq)
        self._use = [0, 0, 0]  # rings, segments, bytes
        self._use_by_tenant: Dict[str, List[int]] = {}
        self._use_by_qos: Dict[str, int] = {q: 0 for q in QOS_CLASSES}
        self.admitted = 0
        self.queued = 0
        self.rejected = 0
        # per-tenant/per-class attribution (telemetry mirrors of the
        # counters above; served verbatim by the stats RPC)
        self._grants_by: Dict[str, int] = {}     # "tenant/qos" -> grants
        self._rejects_by: Dict[str, int] = {}    # "tenant/qos" -> rejects
        self._grant_wait = telemetry.histogram("broker.grant_wait_s")
        # crash tolerance: fencing epoch + durable journal
        self.epoch = 0                  # bumped at every start (incarnation)
        self.journal_path = journal_path
        self.journal_fsync_batch = journal_fsync_batch
        self.checkpoint_bytes = checkpoint_bytes
        self.journal: Optional[journal_mod.Journal] = None
        self.recovered: Dict[str, int] = {}
        self.stale_releases = 0         # zombie tickets rejected, not credited
        self.expired_tickets = 0        # grants expired at recovery/restart
        self._remote: Dict[str, _Ticket] = {}  # rid -> remote reservation
        # lifecycle
        self._stop = threading.Event()
        self._reaper: Optional[threading.Thread] = None
        self._installed = False
        self._prev_pool_max: Optional[int] = None
        self._started = False

    # -- lifecycle -------------------------------------------------------------
    def start(self, recover: Optional[object] = None) -> "PipeBroker":
        """Start (or restart) the broker as a **new incarnation**: the
        fencing epoch is bumped, stamped into the directory, and carried
        by every grant and registration from here on.

        ``recover`` replays a journal first — ``True`` uses this
        broker's ``journal_path``, a string names another file (the
        crashed incarnation's).  Replay rebuilds leases (re-pinned with
        fresh TTLs), re-publishes names at their committed heads, and
        **expires** admission grants that were outstanding at the crash:
        their budgets are not carried over, and their eventual releases
        are rejected as stale-epoch rather than double-credited."""
        if self._started:
            return self
        state: Optional[Dict[str, Any]] = None
        if recover:
            path = self.journal_path if recover is True else str(recover)
            if not path:
                raise ValueError("start(recover=...) needs a journal path "
                                 "(set journal_path= or pass one)")
            records, truncated = journal_mod.replay(path)
            state = _fold_records(records)
            if truncated:
                telemetry.counter("broker.journal_truncated").inc()
        self._stop = threading.Event()  # a restart needs a fresh latch
        self._started = True
        self.directory.resume()  # undo a previous stop()'s interrupt
        # admission state never survives an incarnation boundary: grants
        # of the old epoch are expired, their releases fenced off
        with self._cv:
            leftover = len(self._remote)
            self._remote.clear()
            self._waiting.clear()
            self._use = [0, 0, 0]
            self._use_by_tenant.clear()
            self._use_by_qos = {q: 0 for q in QOS_CLASSES}
        self.epoch = max(self.epoch, state["epoch"] if state else 0) + 1
        self.directory.epoch = self.epoch
        if state is not None:
            self._apply_recovered(state)
            leftover += len(state.get("tickets") or {})
        if leftover:
            self.expired_tickets += leftover
            telemetry.counter("broker.tickets_expired").inc(leftover)
        if self._hub_enabled and (self.hub is None
                                  or self.hub._stop.is_set()):
            self.hub = DoorbellHub()  # hubs are one-shot: rebuild on restart
        if self.hub is not None:
            self.hub.start()
        if self.journal_path:
            self.journal = journal_mod.Journal(
                self.journal_path, fsync_batch=self.journal_fsync_batch,
                checkpoint_bytes=self.checkpoint_bytes)
            self._checkpoint_now()  # compact: this incarnation's baseline
            self.directory.observer = self._journal_event
        if self._serve:
            self.server = DirectoryServer(
                self._host, self._port, handlers=self._handlers,
                directory=self.directory)
            self.server.stats_provider = self.stats  # "stats" RPC / pipetop
            self.server.admission_provider = self._admission_rpc
            self.server.start()
            self.host, self.port = self.server.host, self.server.port
            self._port = self.port  # restarts rebind the same port
        self._reaper = threading.Thread(target=self._reap, daemon=True,
                                        name="pipegen-broker-reaper")
        self._reaper.start()
        return self

    def _reap(self) -> None:
        while not self._stop.wait(self._sweep_every):
            try:
                self.directory.sweep(orphan_min_age_s=self.orphan_min_age_s)
            except Exception:  # pragma: no cover - sweeping must never die
                pass
            try:
                self._sweep_remote()
            except Exception:  # pragma: no cover
                pass
            j = self.journal
            if j is not None and j.size > self.checkpoint_bytes:
                try:
                    self._checkpoint_now()
                except Exception:  # pragma: no cover - disk full etc.
                    pass

    def install(self) -> "PipeBroker":
        """Become the process-global control plane: rendezvous go through
        this broker's directory, doorbell waits through its hub, plan
        units through its admission gate, and the warm pools get the
        broker's (deeper) budget."""
        prev = get_broker()
        if prev is not None and prev is not self:
            # a stale broker may still be registered process-globally (a
            # crashed scope, a leaked fixture): displace it so its
            # eventual stop() cannot clobber OUR globals back off
            prev._installed = False
        self.start()
        self._installed = True
        set_directory(self.directory)
        if self.hub is not None:
            shm_ring.set_doorbell_hub(self.hub)
        if self.pool_park_max is not None:
            self._prev_pool_max = shm_ring.set_pool_limits()
            shm_ring.set_pool_limits(self.pool_park_max)
        set_broker(self)
        return self

    def stop(self, drain_pools: bool = True) -> None:
        if self._installed:
            self._installed = False
            if get_broker() is self:
                set_broker(None)
            if shm_ring.get_doorbell_hub() is self.hub:
                shm_ring.set_doorbell_hub(None)
            if (self._prev_pool_max is not None
                    and shm_ring.set_pool_limits() == self.pool_park_max):
                shm_ring.set_pool_limits(self._prev_pool_max)
        self._stop.set()
        self.directory.interrupt()
        with self._cv:
            self._cv.notify_all()  # queued admissions fail fast
        if self.server is not None:
            self.server.stop()
            self.server = None
        if self._reaper is not None and self._reaper.ident is not None:
            self._reaper.join(timeout=5.0)
        self._reaper = None
        if self.journal is not None:
            self.directory.observer = None
            try:
                self.journal.close()
            except OSError:  # pragma: no cover
                pass
            self.journal = None
        if drain_pools:
            shm_ring.drain_pools()
        if self.hub is not None:
            self.hub.stop()
        self._started = False  # stop() -> start() restarts as a new epoch

    def __enter__(self) -> "PipeBroker":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- admission -------------------------------------------------------------
    def _quota_for(self, tenant: str) -> TenantQuota:
        return self.tenants.get(tenant, self.default_quota)

    def _tenant_use(self, tenant: str) -> List[int]:
        return self._use_by_tenant.setdefault(tenant, [0, 0, 0])

    def _fits_locked(self, t: _Ticket) -> bool:
        for cap, used, want in (
                (self.max_rings, self._use[0], t.rings),
                (self.max_segments, self._use[1], t.segments),
                (self.max_bytes, self._use[2], t.nbytes)):
            if cap is not None and used + want > cap:
                return False
        q = self._quota_for(t.tenant)
        by = self._tenant_use(t.tenant)
        for cap, used, want in (
                (q.max_rings, by[0], t.rings),
                (q.max_segments, by[1], t.segments),
                (q.max_bytes, by[2], t.nbytes)):
            if cap is not None and used + want > cap:
                return False
        qcap = self.qos_concurrency.get(t.qos)
        if qcap is not None and self._use_by_qos[t.qos] + 1 > qcap:
            return False
        return True

    def _can_ever_fit(self, t: _Ticket) -> bool:
        q = self._quota_for(t.tenant)
        for cap, want in ((self.max_rings, t.rings),
                          (self.max_segments, t.segments),
                          (self.max_bytes, t.nbytes),
                          (q.max_rings, t.rings),
                          (q.max_segments, t.segments),
                          (q.max_bytes, t.nbytes)):
            if cap is not None and want > cap:
                return False
        qcap = self.qos_concurrency.get(t.qos)
        return qcap is None or qcap >= 1

    def _head_eligible_locked(self, t: _Ticket) -> bool:
        """May ``t`` go now?  Only the highest-priority *fitting* waiter
        admits — a queued latency ticket that fits always beats a queued
        bulk one, but a big ticket that does NOT fit never blocks a
        smaller one behind it (no head-of-line starvation of the fleet
        by one oversized plan)."""
        for other in sorted(self._waiting):
            if other is t:
                return self._fits_locked(t)
            if self._fits_locked(other):
                return False  # someone ahead of us fits: their turn
        return False  # pragma: no cover - t always in the heap

    def admit(self, tenant: str = "default", qos: str = "bulk",
              rings: int = 1, segments: Optional[int] = None,
              nbytes: int = 0,
              timeout: Optional[float] = None) -> Admission:
        """Block until the (rings, segments, bytes) vector fits the
        global, per-tenant, and per-class budgets, then return the
        :class:`Admission` holding it.  Raises :class:`BrokerBusy` when
        it can never fit or the queue wait exceeds ``timeout``."""
        if qos not in QOS_CLASSES:
            raise ValueError(f"unknown QoS class {qos!r}; have "
                             f"{QOS_CLASSES}")
        t = _Ticket(QOS_CLASSES.index(qos), next(self._seq), tenant, qos,
                    max(0, int(rings)),
                    max(0, int(rings if segments is None else segments)),
                    max(0, int(nbytes)), epoch=self.epoch)
        timeout = self.admit_timeout if timeout is None else timeout
        t_enter = time.monotonic()
        with self._cv:
            if not self._can_ever_fit(t):
                self.rejected += 1
                self._count_by(self._rejects_by, tenant, qos)
                telemetry.counter("broker.rejects",
                                  tenant=tenant, qos=qos).inc()
                raise BrokerBusy(
                    f"admission for tenant={tenant!r} qos={qos!r} "
                    f"(rings={t.rings}, segments={t.segments}, "
                    f"bytes={t.nbytes}) exceeds its quota outright")
            heapq.heappush(self._waiting, t)
            deadline = (None if timeout is None
                        else time.monotonic() + timeout)
            first = True
            try:
                while not self._head_eligible_locked(t):
                    if first:
                        first = False
                        self.queued += 1
                    if self._stop.is_set():
                        raise BrokerBusy("broker is shutting down")
                    remaining = (1.0 if deadline is None
                                 else deadline - time.monotonic())
                    if remaining <= 0:
                        self.rejected += 1
                        self._count_by(self._rejects_by, tenant, qos)
                        telemetry.counter("broker.rejects",
                                          tenant=tenant, qos=qos).inc()
                        raise BrokerBusy(
                            f"admission for tenant={tenant!r} qos={qos!r} "
                            f"queued past {timeout}s (over quota)")
                    self._cv.wait(min(remaining, 1.0))
            finally:
                self._waiting.remove(t)
                heapq.heapify(self._waiting)
                telemetry.gauge("broker.queue_depth").set(
                    len(self._waiting))
            self._grant_locked(t)
            t.state = "granted"
            pumped = self._pump_locked()
        self._journal_grant(t)
        for r in pumped:
            self._journal_grant(r)
        self._grant_wait.observe(time.monotonic() - t_enter)
        telemetry.counter("broker.grants", tenant=tenant, qos=qos).inc()
        return Admission(self, t)

    @staticmethod
    def _count_by(table: Dict[str, int], tenant: str, qos: str) -> None:
        key = f"{tenant}/{qos}"
        table[key] = table.get(key, 0) + 1

    def _grant_locked(self, t: _Ticket) -> None:
        self._use[0] += t.rings
        self._use[1] += t.segments
        self._use[2] += t.nbytes
        by = self._tenant_use(t.tenant)
        by[0] += t.rings
        by[1] += t.segments
        by[2] += t.nbytes
        self._use_by_qos[t.qos] += 1
        self.admitted += 1
        self._count_by(self._grants_by, t.tenant, t.qos)

    def _pump_locked(self) -> List[_Ticket]:
        """Grant every *remote* reservation that reaches head
        eligibility, expire overdue ones, and wake local waiters.
        Called (with the cv held) wherever capacity or the queue
        changes; returns the newly granted remote tickets so callers
        can journal them outside the lock."""
        now = time.monotonic()
        overdue = [t for t in self._waiting
                   if t.holder and t.state == "queued"
                   and t.deadline and now > t.deadline]
        for t in overdue:
            self._waiting.remove(t)
            t.state = "expired"
            self.rejected += 1
            self._count_by(self._rejects_by, t.tenant, t.qos)
            telemetry.counter("broker.rejects",
                              tenant=t.tenant, qos=t.qos).inc()
        if overdue:
            heapq.heapify(self._waiting)
        granted: List[_Ticket] = []
        progress = True
        while progress:
            progress = False
            for other in sorted(self._waiting):
                if not self._fits_locked(other):
                    continue
                # `other` is the head-eligible waiter.  Remote: grant it
                # here (nobody else will).  Local: its own thread grants
                # on wakeup — stop pumping past it, it has priority.
                if other.holder and other.state == "queued":
                    self._waiting.remove(other)
                    heapq.heapify(self._waiting)
                    self._grant_locked(other)
                    other.state = "granted"
                    granted.append(other)
                    progress = True
                break
        self._cv.notify_all()
        telemetry.gauge("broker.queue_depth").set(len(self._waiting))
        return granted

    def _release(self, t: _Ticket) -> None:
        if t.epoch and t.epoch != self.epoch:
            # a zombie: granted by a dead incarnation.  Its budget was
            # never carried across recovery — crediting it back now
            # would let one crash double-spend rings forever.
            self.stale_releases += 1
            telemetry.counter("broker.rejects", reason="stale_epoch").inc()
            return
        with self._cv:
            self._use[0] -= t.rings
            self._use[1] -= t.segments
            self._use[2] -= t.nbytes
            by = self._tenant_use(t.tenant)
            by[0] -= t.rings
            by[1] -= t.segments
            by[2] -= t.nbytes
            self._use_by_qos[t.qos] -= 1
            pumped = self._pump_locked()
        self._journal_event("release", {"ticket": t.rid})
        for r in pumped:
            self._journal_grant(r)

    # -- remote admission (served over the directory's RPC socket) --------------
    def _admission_rpc(self, req: Dict[str, Any]) -> Dict[str, Any]:
        """The admit/admit_poll/release provider behind the
        DirectoryServer.  Non-blocking by design: a queued admission is
        held as a *reservation* in the same priority heap as local
        waiters and granted by :meth:`_pump_locked`; the client polls.
        Parking RPC handler threads here instead would deadlock the
        bounded pool under a plan burst (queued admits starving the
        query ops whose completion would release the rings)."""
        op = req.get("op")
        if op == "release":
            rid = str(req.get("ticket") or "")
            with self._cv:
                t = self._remote.pop(rid, None)
                if t is not None and t.state == "queued":
                    # abandoned before grant: just unqueue it
                    if t in self._waiting:
                        self._waiting.remove(t)
                        heapq.heapify(self._waiting)
                    t = None
            if t is None:
                ep = rid.split(".", 1)[0]
                if ep and ep != str(self.epoch):
                    # a final verdict about the TICKET, not the caller's
                    # epoch pin — "stale_ticket", not "stale_epoch", so
                    # the client does not adopt-and-replay a release
                    # that can never be credited
                    self.stale_releases += 1
                    telemetry.counter("broker.rejects",
                                      reason="stale_epoch").inc()
                    return {"ok": True, "stale_ticket": True,
                            "bepoch": self.epoch,
                            "error": f"ticket {rid} was granted by a dead "
                                     f"broker incarnation"}
                return {"ok": True, "unknown": True}
            self._release(t)
            return {"ok": True}
        if op == "admit":
            qos = req.get("qos", "bulk")
            if qos not in QOS_CLASSES:
                return {"ok": False, "busy": True,
                        "error": f"unknown QoS class {qos!r}"}
            tenant = str(req.get("tenant", "default"))
            rings = max(0, int(req.get("rings", 1)))
            segments = req.get("segments")
            timeout = req.get("timeout")
            timeout = self.admit_timeout if timeout is None else float(timeout)
            t = _Ticket(QOS_CLASSES.index(qos), next(self._seq), tenant, qos,
                        rings,
                        max(0, int(rings if segments is None else segments)),
                        max(0, int(req.get("nbytes", 0))),
                        epoch=self.epoch,
                        holder=int(req.get("holder") or 0) or -1,
                        deadline=time.monotonic() + timeout)
            with self._cv:
                if self._stop.is_set():
                    return {"ok": False, "busy": True,
                            "error": "broker is shutting down"}
                if not self._can_ever_fit(t):
                    self.rejected += 1
                    self._count_by(self._rejects_by, tenant, qos)
                    telemetry.counter("broker.rejects",
                                      tenant=tenant, qos=qos).inc()
                    return {"ok": False, "busy": True,
                            "error": f"admission for tenant={tenant!r} "
                                     f"qos={qos!r} exceeds its quota "
                                     f"outright"}
                heapq.heappush(self._waiting, t)
                self._remote[t.rid] = t
                pumped = self._pump_locked()
                queued = t.state == "queued"
                if queued:
                    self.queued += 1
            for r in pumped:
                self._journal_grant(r)
            if not queued:
                telemetry.counter("broker.grants",
                                  tenant=tenant, qos=qos).inc()
            return {"ok": True, "granted": not queued, "ticket": t.rid}
        if op == "admit_poll":
            rid = str(req.get("ticket") or "")
            with self._cv:
                t = self._remote.get(rid)
                if t is None:
                    ep = rid.split(".", 1)[0]
                    stale = bool(ep and ep != str(self.epoch))
                    return {"ok": False, "gone": True, "stale_ticket": stale,
                            "bepoch": self.epoch,
                            "error": f"no reservation {rid!r} (broker "
                                     f"restarted or it expired)"}
                pumped = self._pump_locked()
                state = t.state
                if state == "expired":
                    self._remote.pop(rid, None)
            for r in pumped:
                self._journal_grant(r)
            if state == "expired":
                return {"ok": False, "busy": True,
                        "error": "admission queued past its timeout "
                                 "(over quota)"}
            if state == "granted":
                telemetry.counter("broker.grants",
                                  tenant=t.tenant, qos=t.qos).inc()
                return {"ok": True, "granted": True, "ticket": rid}
            return {"ok": True, "granted": False, "ticket": rid}
        return {"ok": False, "error": f"bad admission op {op!r}"}

    def _sweep_remote(self) -> None:
        """Reaper duty: a remote holder that died without releasing must
        not pin budget forever — release its grants, drop its queue."""
        dead: List[_Ticket] = []
        with self._cv:
            for rid, t in list(self._remote.items()):
                if t.holder and t.holder > 0 \
                        and not shm_ring._pid_alive(t.holder):
                    self._remote.pop(rid, None)
                    if t.state == "queued" and t in self._waiting:
                        self._waiting.remove(t)
                        heapq.heapify(self._waiting)
                    elif t.state == "granted":
                        dead.append(t)
        for t in dead:
            telemetry.counter("broker.tickets_reaped").inc()
            self._release(t)

    # -- durable journal --------------------------------------------------------
    def _journal_event(self, kind: str, doc: Dict[str, Any]) -> None:
        """The directory's observer hook + the broker's own append path.
        Best-effort: a full disk must degrade durability, not wedge the
        RPC that triggered the append."""
        j = self.journal
        if j is not None:
            try:
                j.append(kind, doc)
            except OSError:  # pragma: no cover - disk trouble
                pass

    def _journal_grant(self, t: _Ticket) -> None:
        self._journal_event("admit", {
            "ticket": t.rid, "tenant": t.tenant, "qos": t.qos,
            "rings": t.rings, "segments": t.segments, "nbytes": t.nbytes,
            "holder": t.holder})

    def _config_doc(self) -> Dict[str, Any]:
        return {
            "max_rings": self.max_rings,
            "max_segments": self.max_segments,
            "max_bytes": self.max_bytes,
            "admit_timeout": self.admit_timeout,
            "default_quota": asdict(self.default_quota),
            "tenants": {k: asdict(v) for k, v in self.tenants.items()},
            "qos_concurrency": dict(self.qos_concurrency),
        }

    def set_quota(self, tenant: str, quota: TenantQuota) -> None:
        """Install/replace a tenant's quota at runtime (journaled, so a
        recovered broker enforces the same budgets)."""
        with self._cv:
            self.tenants[tenant] = quota
            self._cv.notify_all()
        self._journal_event("quota", {"tenant": tenant, **asdict(quota)})

    def _checkpoint_now(self) -> None:
        """Fold live state into one checkpoint record and truncate the
        journal to it (atomic rewrite) — replay cost stays proportional
        to live state, not to lease-heartbeat history."""
        with self._cv:
            tickets = {rid: {"ticket": rid, "tenant": t.tenant,
                             "qos": t.qos, "rings": t.rings,
                             "segments": t.segments, "nbytes": t.nbytes,
                             "holder": t.holder}
                       for rid, t in self._remote.items()
                       if t.state == "granted"}
        state = {"epoch": self.epoch,
                 "config": self._config_doc(),
                 "tickets": tickets,
                 **self.directory.export_state()}
        self.journal.checkpoint([("checkpoint", {"state": state})])

    def _apply_recovered(self, state: Dict[str, Any]) -> None:
        cfg = state.get("config") or None
        if cfg:
            self.max_rings = cfg.get("max_rings", self.max_rings)
            self.max_segments = cfg.get("max_segments", self.max_segments)
            self.max_bytes = cfg.get("max_bytes", self.max_bytes)
            self.admit_timeout = cfg.get("admit_timeout", self.admit_timeout)
            if cfg.get("default_quota") is not None:
                self.default_quota = TenantQuota(**cfg["default_quota"])
            self.tenants = {k: TenantQuota(**v)
                            for k, v in (cfg.get("tenants") or {}).items()}
            qc = cfg.get("qos_concurrency")
            if qc is not None:
                self.qos_concurrency = {k: v for k, v in qc.items()
                                        if k in QOS_CLASSES}
        self.directory.restore_state(state)
        self.recovered = {
            "entries": len(state.get("entries") or ()),
            "popped": len(state.get("popped") or ()),
            "names": len(state.get("names") or {}),
            "expired_tickets": len(state.get("tickets") or {}),
        }

    # -- observability ----------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        """JSON-serializable broker snapshot: admission counters, live
        resource use (global / per-tenant / per-class), grant-wait
        latency, pool occupancy, and the process metrics registry.
        Served verbatim by the directory's ``stats`` RPC and rendered by
        ``python -m repro.tools.pipetop``."""
        with self._cv:
            use = list(self._use)
            waiting = len(self._waiting)
            by_qos = dict(self._use_by_qos)
            by_tenant = {k: list(v) for k, v in self._use_by_tenant.items()}
            grants_by = dict(self._grants_by)
            rejects_by = dict(self._rejects_by)
            remote = len(self._remote)
        gw = self._grant_wait
        out: Dict[str, object] = {
            "epoch": self.epoch,
            "stale_releases": self.stale_releases,
            "expired_tickets": self.expired_tickets,
            "remote_tickets": remote,
            "recovered": dict(self.recovered),
            "journal": (self.journal.info()
                        if self.journal is not None else None),
            "admitted": self.admitted,
            "queued": self.queued,
            "rejected": self.rejected,
            "waiting": waiting,
            "active_rings": use[0],
            "active_segments": use[1],
            "active_bytes": use[2],
            "active_by_qos": by_qos,
            "active_by_tenant": by_tenant,  # tenant -> [rings, segs, bytes]
            "grants_by": grants_by,         # "tenant/qos" -> grants
            "rejects_by": rejects_by,       # "tenant/qos" -> BrokerBusy count
            "grant_wait": {"total": gw.total, "sum_s": gw.sum,
                           "p50_s": gw.quantile(0.5),
                           "p95_s": gw.quantile(0.95),
                           "p99_s": gw.quantile(0.99)},
            "pool": shm_ring.pool_info(),
            "buffer_pool": default_pool().stats.snapshot(),
            "fds": process_fd_count(),
            "metrics": telemetry.registry().snapshot(),
        }
        if self.hub is not None:
            out["hub_waits"] = self.hub.waits
            out["hub_wakeups"] = self.hub.wakeups
            out["hub_registered"] = self.hub.registered
            telemetry.gauge("hub.registered").set(self.hub.registered)
            telemetry.gauge("hub.wakeups").set(self.hub.wakeups)
            telemetry.gauge("hub.waits").set(self.hub.waits)
        # live publications in this process (continuous pipes): lazy
        # import — subscribe pulls in the full pipe stack and most broker
        # users never publish
        try:
            from .subscribe import publications_snapshot
            out["subscriptions"] = publications_snapshot()
        except Exception:
            out["subscriptions"] = []
        return out


def _fold_records(records: List[Tuple[str, Dict[str, Any]]]
                  ) -> Dict[str, Any]:
    """Fold a replayed journal into the recoverable broker state: the
    last checkpoint (if any) plus every delta after it.  Pops net out
    registrations; released tickets net out grants; renews carry no
    fold-time information (recovery re-stamps every lease fresh)."""
    state: Dict[str, Any] = {"epoch": 0, "config": None, "entries": [],
                             "popped": [], "names": {}, "tickets": {}}
    for kind, doc in records:
        if kind == "checkpoint":
            st = doc.get("state") or {}
            state = {"epoch": int(st.get("epoch") or 0),
                     "config": st.get("config"),
                     "entries": list(st.get("entries") or ()),
                     "popped": list(st.get("popped") or ()),
                     "names": dict(st.get("names") or {}),
                     "tickets": dict(st.get("tickets") or {})}
        elif kind == "incarnation":
            state["epoch"] = max(state["epoch"], int(doc.get("epoch") or 0))
            if doc.get("config"):
                state["config"] = doc["config"]
        elif kind == "register":
            state["entries"].append(doc)
        elif kind == "pop":
            for i, rec in enumerate(state["entries"]):
                if (rec.get("dataset") == doc.get("dataset")
                        and rec.get("query_id") == doc.get("query_id")
                        and rec.get("ep") == doc.get("ep")):
                    state["popped"].append(state["entries"].pop(i))
                    break
        elif kind == "renew":
            pass  # leases are re-stamped wholesale at recovery
        elif kind == "publish_name":
            state["names"][doc["name"]] = {"doc": doc.get("doc") or {},
                                           "pid": doc.get("pid", 0)}
        elif kind == "unpublish_name":
            state["names"].pop(doc.get("name"), None)
        elif kind == "quota":
            cfg = state.setdefault("config", None) or {}
            tenants = cfg.setdefault("tenants", {})
            tenants[doc["tenant"]] = {k: doc.get(k) for k in
                                      ("max_rings", "max_segments",
                                       "max_bytes")}
            state["config"] = cfg
        elif kind == "admit":
            state["tickets"][doc["ticket"]] = doc
        elif kind == "release":
            state["tickets"].pop(doc.get("ticket"), None)
    return state


# -- out-of-process broker handle ----------------------------------------------------


class BrokerClient:
    """Executor-facing handle to a :class:`PipeBroker` served in another
    process: rendezvous rides a degraded-capable
    :class:`~repro.core.directory.DirectoryClient`, admission rides the
    broker's reservation RPC (admit → poll → release), and
    :meth:`install` makes this the process-global control plane exactly
    like an in-process broker would.

    Failure ladder (see ``DirectoryClient``): while the broker is
    unreachable, :meth:`admit` returns :class:`NullAdmission` (a no-op
    under the ``broker.degraded`` gauge) and rendezvous falls back to a
    process-local directory; when the broker returns — same or new
    incarnation — the client re-attaches and new work flows through it
    again."""

    def __init__(self, host: str, port: int, degraded_ok: bool = True,
                 admit_timeout: float = 30.0, poll_interval: float = 0.05):
        self.directory = DirectoryClient(host, port, degraded_ok=degraded_ok)
        self.admit_timeout = admit_timeout
        self.poll_interval = poll_interval
        self._prev_dir = None
        self._installed = False

    @property
    def epoch(self) -> int:
        return self.directory.epoch

    @property
    def degraded(self) -> bool:
        return self.directory.degraded

    def admit(self, tenant: str = "default", qos: str = "bulk",
              rings: int = 1, segments: Optional[int] = None,
              nbytes: int = 0, timeout: Optional[float] = None):
        """Same contract as :meth:`PipeBroker.admit`, minus the parked
        thread: a queued admission is a broker-side reservation this
        client polls (bounded backoff), so 200 queued plans cost the
        broker zero handler threads."""
        timeout = self.admit_timeout if timeout is None else timeout
        deadline = time.monotonic() + (timeout if timeout else 30.0)
        req = {"op": "admit", "tenant": tenant, "qos": qos,
               "rings": int(rings),
               "segments": int(rings if segments is None else segments),
               "nbytes": int(nbytes), "timeout": timeout,
               "holder": os.getpid()}
        resp = self.directory._rpc(req)
        pause = self.poll_interval
        while True:
            if resp.get("degraded"):
                telemetry.counter("broker.admit_degraded").inc()
                return NullAdmission()
            if resp.get("busy"):
                raise BrokerBusy(resp.get("error", "admission refused"))
            if resp.get("granted"):
                return RemoteAdmission(self.directory, str(resp["ticket"]))
            if resp.get("gone") or not resp.get("ok"):
                # the broker restarted under our queued reservation: it
                # died with the old incarnation — re-submit to the new one
                if time.monotonic() >= deadline:
                    raise BrokerBusy(resp.get(
                        "error", "admission lost to a broker restart and "
                                 "the re-queue timed out"))
                resp = self.directory._rpc(req)
                continue
            if time.monotonic() >= deadline + 5.0:
                # backstop: the broker expires reservations itself, but a
                # wedged one must not spin this loop forever
                raise BrokerBusy(f"admission for tenant={tenant!r} "
                                 f"qos={qos!r} queued past {timeout}s")
            time.sleep(pause)
            pause = min(pause * 2.0, 0.25)
            resp = self.directory._rpc({"op": "admit_poll",
                                        "ticket": resp.get("ticket")})

    def stats(self) -> Dict[str, Any]:
        return self.directory.stats()

    def install(self) -> "BrokerClient":
        prev = get_broker()
        if prev is not None and prev is not self \
                and isinstance(prev, PipeBroker):
            prev._installed = False  # displace a stale in-process broker
        self._prev_dir = get_directory()
        set_directory(self.directory)
        set_broker(self)
        self._installed = True
        return self

    def stop(self) -> None:
        """Uninstall (the broker itself lives in another process)."""
        if not self._installed:
            return
        self._installed = False
        if get_broker() is self:
            set_broker(None)
        if self._prev_dir is not None \
                and get_directory() is self.directory:
            set_directory(self._prev_dir)

    close = stop

    def __enter__(self) -> "BrokerClient":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


# -- process-global broker ----------------------------------------------------------

_GLOBAL: Optional[Any] = None  # PipeBroker or BrokerClient


def get_broker() -> Optional[Any]:
    """The installed process-global broker — an in-process
    :class:`PipeBroker` or a :class:`BrokerClient` handle to one served
    elsewhere (the plan executor's admission + rendezvous hook)."""
    return _GLOBAL


def set_broker(broker: Optional[Any]) -> None:
    global _GLOBAL
    _GLOBAL = broker


@contextmanager
def broker_installed(broker: PipeBroker):
    """Scoped install (tests): install, yield, stop + uninstall."""
    broker.install()
    try:
        yield broker
    finally:
        broker.stop()
