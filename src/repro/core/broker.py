"""Pipe broker: the long-lived control plane (ROADMAP's resident daemon).

Every transfer used to stand up its own directory, renewal threads, and
fds, and tear them down again — fine for one session, hopeless for the
paper's "colocated or cross-cluster" deployments where *thousands* of
concurrent plans from many tenants share one machine.  A
:class:`PipeBroker` is one resident object (optionally served over TCP)
that owns the four things a shared control plane must own:

* **Doorbell hub** (:class:`DoorbellHub`): ONE selector thread
  multiplexing every ring doorbell fifo/eventfd in the process.  Each
  blocked wait parks on a ``threading.Event`` instead of running its own
  poll syscall loop, so wait cost scales with wakeups, not with the
  number of idle rings — and because ``selectors``/``poll`` carry fds by
  value there is no FD_SETSIZE ceiling (``select.select`` crashed at
  fd >= 1024).
* **Admission control + QoS** (:meth:`PipeBroker.admit`): plans declare
  a tenant and a class (``latency`` | ``bulk``) and a resource vector
  (rings, segments, bytes).  Over-quota requests *queue* (latency ahead
  of bulk, FIFO within a class) instead of failing or oversubscribing;
  quota is enforced globally and per tenant — the CDC generator's
  db-per-tenant / db-shared split: isolated budgets over one shared
  fabric.  This is also what keeps process fd count flat under fan-out:
  admission bounds the number of *live* rings regardless of how many
  plans are in flight.
* **Warm-pool ownership**: the shm ring pool, broadcast warm-park, and
  writer mapping cache (``repro.core.shm_ring``) survive individual plan
  lifetimes already; the broker raises their depth to serving-fleet
  scale, drains them on shutdown, and — because parked segments release
  their doorbell fds — idle pool residency costs mappings, not fds.
* **Lease GC + crash sweep**: the broker's reaper runs
  :meth:`WorkerDirectory.sweep` on a period (expired/dead registrations
  dropped, orphaned shm segments and doorbell fifos unlinked), the duty
  the per-transfer ``DirectoryServer`` reaper used to carry.

``PipeBroker.install()`` makes the broker the process-global control
plane: the plan executor then routes rendezvous through the broker's
directory and wraps every work unit in an admission ticket (edge
options ``tenant=...`` / ``qos=...``).
"""

from __future__ import annotations

import heapq
import itertools
import os
import selectors
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from .directory import DirectoryServer, WorkerDirectory, set_directory
from . import shm_ring
from . import telemetry
from .iobuf import default_pool

__all__ = ["PipeBroker", "DoorbellHub", "TenantQuota", "BrokerBusy",
           "QOS_CLASSES", "get_broker", "set_broker", "process_fd_count"]

#: admission classes, in scheduling priority order: a queued ``latency``
#: ticket is always admitted before a queued ``bulk`` ticket that fits
QOS_CLASSES = ("latency", "bulk")


class BrokerBusy(RuntimeError):
    """Admission was refused: the request can never fit its quota, or it
    queued past its timeout."""


def process_fd_count() -> int:
    """Open fds of this process (the broker's flatness metric)."""
    try:
        return len(os.listdir("/proc/self/fd"))
    except OSError:  # pragma: no cover - no procfs
        return -1


# -- doorbell hub -------------------------------------------------------------------


class DoorbellHub:
    """One selector thread multiplexing every doorbell fd in the process.

    Waiters (``_Doorbell.wait`` routes here while a hub is installed)
    park on a per-doorbell ``threading.Event``; the hub's loop drains the
    readable fd and sets the event.  The event is only cleared by the
    *waiter after a successful wait*, never at wait entry, so a ring that
    lands between the waiter's readiness check and its park is a spurious
    early wakeup (the caller re-checks readiness and parks again), never
    a lost one.  Registration is lazy (first hub-mediated wait) and
    undone by ``_Doorbell.close`` via :meth:`discard`."""

    def __init__(self):
        self._sel = selectors.DefaultSelector()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._wake_r, self._wake_w = os.pipe()
        os.set_blocking(self._wake_r, False)
        self._sel.register(self._wake_r, selectors.EVENT_READ, None)
        self._thread: Optional[threading.Thread] = None
        self.waits = 0
        self.wakeups = 0
        self.registered = 0  # doorbells currently multiplexed

    def start(self) -> "DoorbellHub":
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="pipegen-doorbell-hub")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self._wake()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        with self._lock:
            try:
                self._sel.close()
            except OSError:  # pragma: no cover
                pass
            for fd in (self._wake_r, self._wake_w):
                try:
                    os.close(fd)
                except OSError:  # pragma: no cover
                    pass

    def _wake(self) -> None:
        try:
            os.write(self._wake_w, b"\x01")
        except OSError:  # pragma: no cover - mid-shutdown
            pass

    def wait(self, db, timeout: float) -> bool:
        """Park until ``db`` rings (or ``timeout``).  Called from
        ``_Doorbell.wait`` whenever this hub is installed process-wide."""
        if self._stop.is_set():
            raise RuntimeError("doorbell hub stopped")
        ev = db.hub_event
        if ev is None:
            ev = self._register(db)
        self.waits += 1
        if ev.wait(max(0.0, timeout)):
            ev.clear()
            return True
        return False

    def _register(self, db) -> threading.Event:
        ev = threading.Event()
        with self._lock:
            if db.hub_event is not None:  # raced another wait
                return db.hub_event
            for fd, is_evfd in self._db_fds(db):
                try:  # a dead entry may still hold this recycled fd number
                    self._sel.unregister(fd)
                except (KeyError, ValueError):
                    pass
                self._sel.register(fd, selectors.EVENT_READ, (ev, is_evfd))
            db.hub_event = ev
            self.registered += 1
        # poll-backend selectors snapshot their fd set per select() call:
        # force a re-poll so the new doorbell is live now, not after the
        # current select slice expires
        self._wake()
        return ev

    def discard(self, db) -> None:
        """Drop a doorbell's fds from the selector (its close path)."""
        with self._lock:
            if db.hub_event is None:
                return
            for fd, _ in self._db_fds(db):
                try:
                    self._sel.unregister(fd)
                except (KeyError, ValueError, OSError):
                    pass
            db.hub_event = None
            self.registered -= 1

    @staticmethod
    def _db_fds(db) -> List[Tuple[int, bool]]:
        fds = [(db.fd, False)]
        if db.evfd is not None:
            fds.append((db.evfd, True))
        return fds

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                events = self._sel.select(timeout=0.5)
            except (OSError, RuntimeError):  # pragma: no cover - shutdown race
                if self._stop.is_set():
                    return
                continue
            for key, _ in events:
                if key.data is None:  # the wake pipe
                    try:
                        os.read(self._wake_r, 4096)
                    except OSError:  # pragma: no cover
                        pass
                    continue
                ev, is_evfd = key.data
                try:
                    if is_evfd:
                        os.eventfd_read(key.fd)
                    else:
                        os.read(key.fd, 64)
                except OSError:
                    pass  # fd raced a close; the unregister is in flight
                ev.set()
                self.wakeups += 1


# -- admission control --------------------------------------------------------------


@dataclass(frozen=True)
class TenantQuota:
    """Per-tenant ceilings (``None`` = unlimited): concurrent rings,
    concurrent shm segments, and summed ring bytes."""

    max_rings: Optional[int] = None
    max_segments: Optional[int] = None
    max_bytes: Optional[int] = None


class _Ticket:
    __slots__ = ("prio", "seq", "tenant", "qos", "rings", "segments",
                 "nbytes")

    def __init__(self, prio, seq, tenant, qos, rings, segments, nbytes):
        self.prio = prio
        self.seq = seq
        self.tenant = tenant
        self.qos = qos
        self.rings = rings
        self.segments = segments
        self.nbytes = nbytes

    def __lt__(self, other):  # heap order: class priority, then FIFO
        return (self.prio, self.seq) < (other.prio, other.seq)


class Admission:
    """A granted admission ticket; a context manager whose exit releases
    the resources back to the broker."""

    def __init__(self, broker: "PipeBroker", ticket: _Ticket):
        self._broker = broker
        self._ticket = ticket
        self._released = False

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._broker._release(self._ticket)

    def __enter__(self) -> "Admission":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


# -- the broker ---------------------------------------------------------------------


class PipeBroker:
    """The resident control plane: directory + doorbell hub + admission
    + warm pools + lease/crash sweeping, in one start/stoppable object.

    In-process by default; ``serve=True`` additionally exposes the same
    ``WorkerDirectory`` over TCP (a :class:`DirectoryServer` with the
    bounded handler pool) for multi-process deployments."""

    def __init__(self,
                 lease_ttl: Optional[float] = 30.0,
                 sweep_every: Optional[float] = None,
                 orphan_min_age_s: float = 30.0,
                 serve: bool = False,
                 host: str = "127.0.0.1",
                 port: int = 0,
                 handlers: int = 8,
                 max_rings: Optional[int] = 64,
                 max_segments: Optional[int] = None,
                 max_bytes: Optional[int] = None,
                 default_quota: Optional[TenantQuota] = None,
                 tenants: Optional[Dict[str, TenantQuota]] = None,
                 qos_concurrency: Optional[Dict[str, Optional[int]]] = None,
                 admit_timeout: float = 30.0,
                 pool_park_max: Optional[int] = 16,
                 hub: bool = True):
        self.directory = WorkerDirectory(lease_ttl=lease_ttl)
        self.hub: Optional[DoorbellHub] = DoorbellHub() if hub else None
        self.server: Optional[DirectoryServer] = None
        self._serve = serve
        self._host, self._port, self._handlers = host, port, handlers
        self.max_rings = max_rings
        self.max_segments = max_segments
        self.max_bytes = max_bytes
        self.default_quota = default_quota or TenantQuota()
        self.tenants = dict(tenants or {})
        self.qos_concurrency = dict(qos_concurrency or {})
        for q in self.qos_concurrency:
            if q not in QOS_CLASSES:
                raise ValueError(f"unknown QoS class {q!r}; have "
                                 f"{QOS_CLASSES}")
        self.admit_timeout = admit_timeout
        self.pool_park_max = pool_park_max
        self._sweep_every = sweep_every or (lease_ttl / 2 if lease_ttl
                                            else 15.0)
        self.orphan_min_age_s = orphan_min_age_s
        # admission state
        self._cv = threading.Condition()
        self._seq = itertools.count()
        self._waiting: List[_Ticket] = []  # heap: (class prio, FIFO seq)
        self._use = [0, 0, 0]  # rings, segments, bytes
        self._use_by_tenant: Dict[str, List[int]] = {}
        self._use_by_qos: Dict[str, int] = {q: 0 for q in QOS_CLASSES}
        self.admitted = 0
        self.queued = 0
        self.rejected = 0
        # per-tenant/per-class attribution (telemetry mirrors of the
        # counters above; served verbatim by the stats RPC)
        self._grants_by: Dict[str, int] = {}     # "tenant/qos" -> grants
        self._rejects_by: Dict[str, int] = {}    # "tenant/qos" -> rejects
        self._grant_wait = telemetry.histogram("broker.grant_wait_s")
        # lifecycle
        self._stop = threading.Event()
        self._reaper: Optional[threading.Thread] = None
        self._installed = False
        self._prev_pool_max: Optional[int] = None
        self._started = False

    # -- lifecycle -------------------------------------------------------------
    def start(self) -> "PipeBroker":
        if self._started:
            return self
        self._started = True
        if self.hub is not None:
            self.hub.start()
        if self._serve:
            self.server = DirectoryServer(
                self._host, self._port, handlers=self._handlers,
                directory=self.directory)
            self.server.stats_provider = self.stats  # "stats" RPC / pipetop
            self.server.start()
            self.host, self.port = self.server.host, self.server.port
        self._reaper = threading.Thread(target=self._reap, daemon=True,
                                        name="pipegen-broker-reaper")
        self._reaper.start()
        return self

    def _reap(self) -> None:
        while not self._stop.wait(self._sweep_every):
            try:
                self.directory.sweep(orphan_min_age_s=self.orphan_min_age_s)
            except Exception:  # pragma: no cover - sweeping must never die
                pass

    def install(self) -> "PipeBroker":
        """Become the process-global control plane: rendezvous go through
        this broker's directory, doorbell waits through its hub, plan
        units through its admission gate, and the warm pools get the
        broker's (deeper) budget."""
        self.start()
        self._installed = True
        set_directory(self.directory)
        if self.hub is not None:
            shm_ring.set_doorbell_hub(self.hub)
        if self.pool_park_max is not None:
            self._prev_pool_max = shm_ring.set_pool_limits()
            shm_ring.set_pool_limits(self.pool_park_max)
        set_broker(self)
        return self

    def stop(self, drain_pools: bool = True) -> None:
        if self._installed:
            self._installed = False
            if get_broker() is self:
                set_broker(None)
            if shm_ring.get_doorbell_hub() is self.hub:
                shm_ring.set_doorbell_hub(None)
            if self._prev_pool_max is not None:
                shm_ring.set_pool_limits(self._prev_pool_max)
        self._stop.set()
        self.directory.interrupt()
        with self._cv:
            self._cv.notify_all()  # queued admissions fail fast
        if self.server is not None:
            self.server.stop()
        if self._reaper is not None and self._reaper.ident is not None:
            self._reaper.join(timeout=5.0)
        if drain_pools:
            shm_ring.drain_pools()
        if self.hub is not None:
            self.hub.stop()

    def __enter__(self) -> "PipeBroker":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- admission -------------------------------------------------------------
    def _quota_for(self, tenant: str) -> TenantQuota:
        return self.tenants.get(tenant, self.default_quota)

    def _tenant_use(self, tenant: str) -> List[int]:
        return self._use_by_tenant.setdefault(tenant, [0, 0, 0])

    def _fits_locked(self, t: _Ticket) -> bool:
        for cap, used, want in (
                (self.max_rings, self._use[0], t.rings),
                (self.max_segments, self._use[1], t.segments),
                (self.max_bytes, self._use[2], t.nbytes)):
            if cap is not None and used + want > cap:
                return False
        q = self._quota_for(t.tenant)
        by = self._tenant_use(t.tenant)
        for cap, used, want in (
                (q.max_rings, by[0], t.rings),
                (q.max_segments, by[1], t.segments),
                (q.max_bytes, by[2], t.nbytes)):
            if cap is not None and used + want > cap:
                return False
        qcap = self.qos_concurrency.get(t.qos)
        if qcap is not None and self._use_by_qos[t.qos] + 1 > qcap:
            return False
        return True

    def _can_ever_fit(self, t: _Ticket) -> bool:
        q = self._quota_for(t.tenant)
        for cap, want in ((self.max_rings, t.rings),
                          (self.max_segments, t.segments),
                          (self.max_bytes, t.nbytes),
                          (q.max_rings, t.rings),
                          (q.max_segments, t.segments),
                          (q.max_bytes, t.nbytes)):
            if cap is not None and want > cap:
                return False
        qcap = self.qos_concurrency.get(t.qos)
        return qcap is None or qcap >= 1

    def _head_eligible_locked(self, t: _Ticket) -> bool:
        """May ``t`` go now?  Only the highest-priority *fitting* waiter
        admits — a queued latency ticket that fits always beats a queued
        bulk one, but a big ticket that does NOT fit never blocks a
        smaller one behind it (no head-of-line starvation of the fleet
        by one oversized plan)."""
        for other in sorted(self._waiting):
            if other is t:
                return self._fits_locked(t)
            if self._fits_locked(other):
                return False  # someone ahead of us fits: their turn
        return False  # pragma: no cover - t always in the heap

    def admit(self, tenant: str = "default", qos: str = "bulk",
              rings: int = 1, segments: Optional[int] = None,
              nbytes: int = 0,
              timeout: Optional[float] = None) -> Admission:
        """Block until the (rings, segments, bytes) vector fits the
        global, per-tenant, and per-class budgets, then return the
        :class:`Admission` holding it.  Raises :class:`BrokerBusy` when
        it can never fit or the queue wait exceeds ``timeout``."""
        if qos not in QOS_CLASSES:
            raise ValueError(f"unknown QoS class {qos!r}; have "
                             f"{QOS_CLASSES}")
        t = _Ticket(QOS_CLASSES.index(qos), next(self._seq), tenant, qos,
                    max(0, int(rings)),
                    max(0, int(rings if segments is None else segments)),
                    max(0, int(nbytes)))
        timeout = self.admit_timeout if timeout is None else timeout
        t_enter = time.monotonic()
        with self._cv:
            if not self._can_ever_fit(t):
                self.rejected += 1
                self._count_by(self._rejects_by, tenant, qos)
                telemetry.counter("broker.rejects",
                                  tenant=tenant, qos=qos).inc()
                raise BrokerBusy(
                    f"admission for tenant={tenant!r} qos={qos!r} "
                    f"(rings={t.rings}, segments={t.segments}, "
                    f"bytes={t.nbytes}) exceeds its quota outright")
            heapq.heappush(self._waiting, t)
            deadline = (None if timeout is None
                        else time.monotonic() + timeout)
            first = True
            try:
                while not self._head_eligible_locked(t):
                    if first:
                        first = False
                        self.queued += 1
                    if self._stop.is_set():
                        raise BrokerBusy("broker is shutting down")
                    remaining = (1.0 if deadline is None
                                 else deadline - time.monotonic())
                    if remaining <= 0:
                        self.rejected += 1
                        self._count_by(self._rejects_by, tenant, qos)
                        telemetry.counter("broker.rejects",
                                          tenant=tenant, qos=qos).inc()
                        raise BrokerBusy(
                            f"admission for tenant={tenant!r} qos={qos!r} "
                            f"queued past {timeout}s (over quota)")
                    self._cv.wait(min(remaining, 1.0))
            finally:
                self._waiting.remove(t)
                heapq.heapify(self._waiting)
                telemetry.gauge("broker.queue_depth").set(
                    len(self._waiting))
            self._use[0] += t.rings
            self._use[1] += t.segments
            self._use[2] += t.nbytes
            by = self._tenant_use(t.tenant)
            by[0] += t.rings
            by[1] += t.segments
            by[2] += t.nbytes
            self._use_by_qos[t.qos] += 1
            self.admitted += 1
            self._count_by(self._grants_by, tenant, qos)
            self._cv.notify_all()  # another small ticket may also fit
        self._grant_wait.observe(time.monotonic() - t_enter)
        telemetry.counter("broker.grants", tenant=tenant, qos=qos).inc()
        return Admission(self, t)

    @staticmethod
    def _count_by(table: Dict[str, int], tenant: str, qos: str) -> None:
        key = f"{tenant}/{qos}"
        table[key] = table.get(key, 0) + 1

    def _release(self, t: _Ticket) -> None:
        with self._cv:
            self._use[0] -= t.rings
            self._use[1] -= t.segments
            self._use[2] -= t.nbytes
            by = self._tenant_use(t.tenant)
            by[0] -= t.rings
            by[1] -= t.segments
            by[2] -= t.nbytes
            self._use_by_qos[t.qos] -= 1
            self._cv.notify_all()

    # -- observability ----------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        """JSON-serializable broker snapshot: admission counters, live
        resource use (global / per-tenant / per-class), grant-wait
        latency, pool occupancy, and the process metrics registry.
        Served verbatim by the directory's ``stats`` RPC and rendered by
        ``python -m repro.tools.pipetop``."""
        with self._cv:
            use = list(self._use)
            waiting = len(self._waiting)
            by_qos = dict(self._use_by_qos)
            by_tenant = {k: list(v) for k, v in self._use_by_tenant.items()}
            grants_by = dict(self._grants_by)
            rejects_by = dict(self._rejects_by)
        gw = self._grant_wait
        out: Dict[str, object] = {
            "admitted": self.admitted,
            "queued": self.queued,
            "rejected": self.rejected,
            "waiting": waiting,
            "active_rings": use[0],
            "active_segments": use[1],
            "active_bytes": use[2],
            "active_by_qos": by_qos,
            "active_by_tenant": by_tenant,  # tenant -> [rings, segs, bytes]
            "grants_by": grants_by,         # "tenant/qos" -> grants
            "rejects_by": rejects_by,       # "tenant/qos" -> BrokerBusy count
            "grant_wait": {"total": gw.total, "sum_s": gw.sum,
                           "p50_s": gw.quantile(0.5),
                           "p95_s": gw.quantile(0.95),
                           "p99_s": gw.quantile(0.99)},
            "pool": shm_ring.pool_info(),
            "buffer_pool": default_pool().stats.snapshot(),
            "fds": process_fd_count(),
            "metrics": telemetry.registry().snapshot(),
        }
        if self.hub is not None:
            out["hub_waits"] = self.hub.waits
            out["hub_wakeups"] = self.hub.wakeups
            out["hub_registered"] = self.hub.registered
            telemetry.gauge("hub.registered").set(self.hub.registered)
            telemetry.gauge("hub.wakeups").set(self.hub.wakeups)
            telemetry.gauge("hub.waits").set(self.hub.waits)
        # live publications in this process (continuous pipes): lazy
        # import — subscribe pulls in the full pipe stack and most broker
        # users never publish
        try:
            from .subscribe import publications_snapshot
            out["subscriptions"] = publications_snapshot()
        except Exception:
            out["subscriptions"] = []
        return out


# -- process-global broker ----------------------------------------------------------

_GLOBAL: Optional[PipeBroker] = None


def get_broker() -> Optional[PipeBroker]:
    """The installed process-global broker, if any (the plan executor's
    admission + rendezvous hook)."""
    return _GLOBAL


def set_broker(broker: Optional[PipeBroker]) -> None:
    global _GLOBAL
    _GLOBAL = broker


@contextmanager
def broker_installed(broker: PipeBroker):
    """Scoped install (tests): install, yield, stop + uninstall."""
    broker.install()
    try:
        yield broker
    finally:
        broker.stop()
