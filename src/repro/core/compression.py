"""Wire compression codecs (paper section 7.4).

The paper evaluates run-length encoding, dictionary-based compression
("zip"), and uncompressed transfer, finding compression a net loss for
colocated workers and a modest win for dictionary compression at 40 ms
latency.  We implement the same three plus zstd as a modern beyond-paper
option (used also by the checkpoint substrate).

All codecs accept any bytes-like object (``bytes``/``bytearray``/
``memoryview``) so the scatter-gather path can compress straight from
buffer views without materializing a copy first.
:meth:`Codec.compress_segments` is the SegmentList-level entry point: the
identity codec passes the views through untouched (zero-copy preserved);
compressing codecs consume the views and emit a single compressed segment.
"""

from __future__ import annotations

import zlib
from typing import Callable, Dict

import numpy as np

from .iobuf import Buffer, SegmentList

try:
    import zstandard as _zstd
except Exception:  # pragma: no cover
    _zstd = None

__all__ = ["Codec", "get_codec", "CODECS"]


class Codec:
    name: str = "none"

    def compress(self, data: Buffer) -> Buffer:
        return data

    def decompress(self, data: Buffer) -> bytes:
        return data if isinstance(data, bytes) else bytes(data)

    def compress_segments(self, segs: SegmentList) -> SegmentList:
        """Compress an encoded block at the segment level: compress from
        the views (one unavoidable gather for multi-segment payloads) and
        return a single-segment list that still owns the pooled stores so
        they are recycled after send.  The identity codec overrides this to
        forward the views untouched (zero-copy preserved)."""
        data: Buffer
        if len(segs) == 1:
            data = segs[0]  # compress straight from the view, no copy
        else:
            data = segs.join()
        out = SegmentList([self.compress(data)])
        # transfer pooled-store ownership so release-after-send still recycles
        out._pooled, segs._pooled = segs._pooled, []
        return out


class NoneCodec(Codec):
    name = "none"

    def compress_segments(self, segs: SegmentList) -> SegmentList:
        return segs

    def decompress(self, data: Buffer) -> Buffer:
        # pass views straight through: the shm-ring read path hands the
        # decoder a memoryview into the mapped region, consumed in place
        return data


class RleCodec(Codec):
    """Byte-level run-length encoding, vectorized with numpy.

    Layout: sequence of (count: uint8 in [1,255], value: uint8) pairs.
    """

    name = "rle"

    def compress(self, data: bytes) -> bytes:
        if not data:
            return b""
        a = np.frombuffer(data, dtype=np.uint8)
        # boundaries where the value changes
        change = np.nonzero(np.diff(a))[0] + 1
        starts = np.concatenate(([0], change))
        ends = np.concatenate((change, [len(a)]))
        lengths = ends - starts
        values = a[starts]
        # split runs longer than 255
        reps = (lengths + 254) // 255
        out_vals = np.repeat(values, reps)
        out_lens = np.empty(out_vals.shape, dtype=np.uint8)
        idx = 0
        # vectorized fill: each run contributes (reps-1) copies of 255 + remainder
        rem = lengths - (reps - 1) * 255
        pos = np.concatenate(([0], np.cumsum(reps)))
        full = np.full(int(reps.sum()), 255, dtype=np.uint8)
        full[pos[1:] - 1] = rem.astype(np.uint8)
        out_lens = full
        del idx
        interleaved = np.empty(out_vals.size * 2, dtype=np.uint8)
        interleaved[0::2] = out_lens
        interleaved[1::2] = out_vals
        return interleaved.tobytes()

    def decompress(self, data: bytes) -> bytes:
        if not data:
            return b""
        a = np.frombuffer(data, dtype=np.uint8)
        lens = a[0::2].astype(np.int64)
        vals = a[1::2]
        return np.repeat(vals, lens).tobytes()


class ZipCodec(Codec):
    """Dictionary-based compression; the paper's 'zip'."""

    name = "zip"

    def __init__(self, level: int = 6):
        self.level = level

    def compress(self, data: bytes) -> bytes:
        return zlib.compress(data, self.level)

    def decompress(self, data: bytes) -> bytes:
        return zlib.decompress(data)


class ZstdCodec(Codec):
    """Beyond-paper: zstd, the format a 2026 deployment would actually use."""

    name = "zstd"

    def __init__(self, level: int = 3):
        if _zstd is None:  # pragma: no cover
            raise RuntimeError("zstandard not available")
        self._c = _zstd.ZstdCompressor(level=level)
        self._d = _zstd.ZstdDecompressor()

    def compress(self, data: bytes) -> bytes:
        return self._c.compress(data)

    def decompress(self, data: bytes) -> bytes:
        return self._d.decompress(data)


CODECS: Dict[str, Callable[[], Codec]] = {
    "none": NoneCodec,
    "rle": RleCodec,
    "zip": ZipCodec,
}
if _zstd is not None:
    CODECS["zstd"] = ZstdCodec


def get_codec(name: str) -> Codec:
    try:
        return CODECS[name]()
    except KeyError:
        raise ValueError(f"unknown codec {name!r}; have {sorted(CODECS)}") from None
