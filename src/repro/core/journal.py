"""Append-only broker journal (control-plane crash tolerance).

The :class:`~repro.core.broker.PipeBroker` is the sole owner of leases,
admission tickets, and the publication registry — state that, before
this module, lived only in its process memory.  A SIGKILL therefore
wiped the control plane: every in-flight edge lost its lease, every
publication its name, every granted ticket its budget accounting.  The
journal makes that state durable the same way *Mainlining Databases*
makes storage recoverable: a compact append-only log of state deltas,
periodically folded into a checkpoint so replay cost is bounded by the
*live* state, not the history.

Format: one record per line, ``{crc32:08x} {json}\n`` — the CRC covers
the JSON bytes, so a torn write (power cut / SIGKILL mid-append) is
detectable.  Records are ``(kind, doc)`` pairs; the journal itself is
agnostic to kinds (the broker defines register/pop/renew/publish_name/
admit/release/… and folds them in ``broker._fold_records``).

Durability knobs:

* ``fsync_batch`` — records are flushed on every append but fsync'd
  once per batch (default 8): the crash window is bounded without
  paying a disk flush per lease heartbeat.
* ``checkpoint_bytes`` — when the file grows past this, the owner calls
  :meth:`Journal.checkpoint` with a snapshot record set; the journal is
  rewritten atomically (tmp file + fsync + ``os.replace``) so a crash
  mid-checkpoint leaves the *old* journal intact.

Replay tolerates a truncated or corrupt **tail** record — the one a
crash can legitimately tear — by recovering to the last intact record.
Corruption *before* intact records is a different animal (bit rot, a
concurrent writer) and raises :class:`JournalError` loudly instead of
silently dropping committed state.
"""

from __future__ import annotations

import json
import os
import threading
import zlib
from typing import Any, Dict, Iterable, List, Tuple

__all__ = ["Journal", "JournalError", "replay"]

Record = Tuple[str, Dict[str, Any]]


class JournalError(RuntimeError):
    """The journal is damaged beyond the tail-truncation a crash can
    cause; recovering from it would silently drop committed records."""


def _encode(kind: str, doc: Dict[str, Any]) -> bytes:
    payload = json.dumps({"k": kind, **doc}, separators=(",", ":"),
                         sort_keys=True).encode()
    return b"%08x %s\n" % (zlib.crc32(payload), payload)


def _decode(line: bytes) -> Record:
    crc_hex, _, payload = line.rstrip(b"\n").partition(b" ")
    if len(crc_hex) != 8 or not payload:
        raise ValueError("malformed journal line")
    if zlib.crc32(payload) != int(crc_hex, 16):
        raise ValueError("journal record CRC mismatch")
    doc = json.loads(payload)
    kind = doc.pop("k")
    return str(kind), doc


def replay(path: str) -> Tuple[List[Record], bool]:
    """Read every intact record from ``path``.

    Returns ``(records, truncated)`` where ``truncated`` flags a
    torn/corrupt tail that was dropped (the normal crash signature).  A
    missing or empty file replays to ``([], False)``.  Corruption that
    is *followed by* intact records raises :class:`JournalError`: that
    cannot be explained by a crashed appender, and recovering past it
    would resurrect a state the later records contradict.
    """
    try:
        with open(path, "rb") as fh:
            lines = fh.readlines()
    except FileNotFoundError:
        return [], False
    records: List[Record] = []
    bad_at = None
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            rec = _decode(line)
        except (ValueError, json.JSONDecodeError, KeyError):
            if bad_at is None:
                bad_at = i
            continue
        if bad_at is not None:
            raise JournalError(
                f"{path}: corrupt record at line {bad_at + 1} is followed "
                f"by intact records — refusing to silently drop committed "
                f"state (a crash can only tear the tail)")
        records.append(rec)
    return records, bad_at is not None


class Journal:
    """Append-side handle.  Thread-safe; owned by one broker process."""

    def __init__(self, path: str, fsync_batch: int = 8,
                 checkpoint_bytes: int = 1 << 20):
        self.path = path
        self.fsync_batch = max(1, int(fsync_batch))
        self.checkpoint_bytes = int(checkpoint_bytes)
        self._lock = threading.Lock()
        self._fh = open(path, "ab")
        self._unsynced = 0
        self.records = 0
        self.syncs = 0
        self.checkpoints = 0

    @property
    def size(self) -> int:
        """Bytes in the journal file (the checkpoint trigger)."""
        with self._lock:
            if self._fh.closed:
                return 0
            return self._fh.tell()

    def append(self, kind: str, doc: Dict[str, Any]) -> None:
        with self._lock:
            if self._fh.closed:
                return
            self._fh.write(_encode(kind, doc))
            self._fh.flush()
            self.records += 1
            self._unsynced += 1
            if self._unsynced >= self.fsync_batch:
                self._fsync_locked()

    def _fsync_locked(self) -> None:
        try:
            os.fsync(self._fh.fileno())
        except OSError:  # pragma: no cover - e.g. journal on a pipe/tmpfs oddity
            pass
        self._unsynced = 0
        self.syncs += 1

    def sync(self) -> None:
        with self._lock:
            if not self._fh.closed and self._unsynced:
                self._fsync_locked()

    def checkpoint(self, records: Iterable[Record]) -> None:
        """Atomically replace the journal with ``records`` (the owner's
        folded snapshot).  Crash-safe: the old journal stays intact
        until the new one is fully on disk (tmp + fsync + replace)."""
        tmp = f"{self.path}.ckpt.{os.getpid()}"
        with self._lock:
            if self._fh.closed:
                return
            with open(tmp, "wb") as out:
                n = 0
                for kind, doc in records:
                    out.write(_encode(kind, doc))
                    n += 1
                out.flush()
                os.fsync(out.fileno())
            os.replace(tmp, self.path)
            self._fh.close()
            self._fh = open(self.path, "ab")
            self._unsynced = 0
            self.records = n
            self.checkpoints += 1

    def info(self) -> Dict[str, Any]:
        with self._lock:
            size = 0 if self._fh.closed else self._fh.tell()
        return {"path": self.path, "bytes": size, "records": self.records,
                "checkpoints": self.checkpoints, "syncs": self.syncs}

    def close(self) -> None:
        with self._lock:
            if self._fh.closed:
                return
            if self._unsynced:
                self._fsync_locked()
            self._fh.close()
