"""Continuous pipes: a publish/subscribe data plane over the pipe fabric.

One-shot pipes move a relation once; a *publication* keeps moving it.  An
exporter ``publish()``es a relation under a name registered in the worker
directory; every ``commit()`` of delta blocks becomes a monotonically
increasing **epoch**.  Each epoch is encoded exactly once into wire payload
bytes and appended to a bounded in-memory **replay log** (epoch- and
byte-capped; oldest epochs evicted first).  Committed epochs are pushed to
subscribers over the existing transports — the broadcast shm ring for a
colocated fan-out (one encode, one ring write, R readers), striped pipes
or sockets for remote subscribers — reusing ring doorbells so an idle
subscription parks on an fd instead of polling.

Importers ``subscribe()`` with a **watermark**, the last epoch they have
applied.  The publisher's per-subscriber sender walks forward from that
watermark: epochs still retained in the log are *replayed* from their
stored payloads (no re-encode); if the watermark has fallen off the log,
the subscriber receives a full **snapshot** of the publication's current
image stamped with its epoch, then live deltas — the same RESUME-style
idea the fault harness uses for one-shot edges.  Publisher crash +
restart therefore heals end-to-end: the restarted publisher re-publishes
under the same name (the registry entry is pid-owned and lease-swept) and
subscribers resubscribe at their watermark.

Wire protocol per subscriber connection::

    S  schema hello (schema + {"mode","codec","name"} meta)
    D  epoch header {"epoch","head","kind","blocks","rows","ts"}
    B  x header["blocks"] — wire-format payload, one committed block each
    ...repeated per epoch...
    E  publication closed

Lifecycle notes: a :class:`Subscription` owns its directory lease renewer
(:class:`repro.core.directory.LeaseRenewer`) until ``close()`` — renewal
is *not* bounded by any single transfer.  Broker admission is taken per
subscriber ring under the publication's ``tenant``/``qos`` so a bulk
fan-out queues behind latency traffic instead of starving it.
"""

from __future__ import annotations

import itertools
import json
import os
import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from . import telemetry
from .broker import BrokerBusy, get_broker
from .compression import get_codec
from .datapipe import _connect
from .directory import (DirectoryLike, Endpoint, LeaseRenewer,
                        get_directory)
from .shm_ring import (DEFAULT_RING_CAPACITY, ShmRing, ShmRingTransport,
                       acquire_broadcast_ring, acquire_ring)
from .stream import StripedReceiver
from .transport import (Channel, ChannelTransport, FRAME_BLOCK, FRAME_EOF,
                        FRAME_EPOCH, FRAME_SCHEMA, LinkSim, SocketTransport,
                        Transport, listen_socket)
from .types import ColumnBlock, Schema
from .wire import decode_schema, encode_schema, get_wire_format

__all__ = [
    "EpochDelta",
    "Publication",
    "PublicationEnded",
    "ReplayLog",
    "SubscribeError",
    "Subscription",
    "apply_to_engine",
    "decode_epoch_header",
    "encode_epoch_header",
    "publications_snapshot",
    "publish",
    "subscribe",
]

_SUB_QUERY = "sub"


def _sub_dataset(name: str) -> str:
    return f"__sub__.{name}"


class SubscribeError(RuntimeError):
    """Misuse or unrecoverable state of a publication/subscription."""


class PublicationEnded(BrokenPipeError):
    """The publisher closed (or died) and every queued epoch is drained.

    Carries ``watermark`` so a caller can resubscribe exactly where it
    stopped: ``subscribe(name, watermark=exc.watermark)``.
    """

    def __init__(self, msg: str, watermark: int = 0):
        super().__init__(msg)
        self.watermark = watermark


# -- epoch framing (rides FRAME_EPOCH over any transport) ------------------------

def encode_epoch_header(epoch: int, head: int, kind: str = "delta",
                        blocks: int = 1, rows: int = 0,
                        ts: float = 0.0) -> bytes:
    return json.dumps({
        "epoch": int(epoch), "head": int(head), "kind": kind,
        "blocks": int(blocks), "rows": int(rows), "ts": float(ts),
    }).encode()


def decode_epoch_header(payload: Any) -> Dict[str, Any]:
    return json.loads(bytes(payload).decode())


# -- replay log --------------------------------------------------------------------

@dataclass
class _EpochRecord:
    epoch: int
    kind: str                 # "delta" | "snapshot"
    payloads: List[bytes]     # encoded + compressed, one per block
    rows: int
    nbytes: int
    ts: float


class ReplayLog:
    """Bounded epoch → payload store; oldest epochs evicted first.

    Retention is the product of two caps: at most ``retain_epochs``
    entries and at most ``retain_bytes`` of stored payload (the newest
    epoch is always kept even if it alone exceeds the byte cap, so the
    live path never starves).
    """

    def __init__(self, retain_epochs: int = 64,
                 retain_bytes: int = 64 << 20):
        self.retain_epochs = int(retain_epochs)
        self.retain_bytes = int(retain_bytes)
        self._lock = threading.Lock()
        self._recs: "OrderedDict[int, _EpochRecord]" = OrderedDict()
        self.nbytes = 0
        self.evicted = 0

    def append(self, rec: _EpochRecord) -> None:
        with self._lock:
            self._recs[rec.epoch] = rec
            self.nbytes += rec.nbytes
            while len(self._recs) > 1 and (
                    len(self._recs) > self.retain_epochs
                    or self.nbytes > self.retain_bytes):
                _, old = self._recs.popitem(last=False)
                self.nbytes -= old.nbytes
                self.evicted += 1

    def get(self, epoch: int) -> Optional[_EpochRecord]:
        with self._lock:
            return self._recs.get(epoch)

    @property
    def floor(self) -> int:
        """Oldest retained epoch (0 when the log is empty)."""
        with self._lock:
            return next(iter(self._recs), 0)

    def __len__(self) -> int:
        with self._lock:
            return len(self._recs)


# -- publisher ---------------------------------------------------------------------

@dataclass
class PubStats:
    epochs: int = 0            # epochs committed
    encodes: int = 0           # block encodes in commit (one per block, ever)
    fallback_encodes: int = 0  # snapshot re-encodes for un-retained watermarks
    snapshot_fallbacks: int = 0
    replayed_epochs: int = 0   # epochs served to late joiners from the log
    bytes_logged: int = 0
    admission_rejects: int = 0


def _chunk_rows(block: ColumnBlock,
                target_bytes: Optional[int]) -> List[ColumnBlock]:
    """Row-slice ``block`` so each piece carries at most ~``target_bytes``
    of raw payload (None = no cap).  Lets a snapshot of a large image ship
    over a small shm ring as k frames instead of one oversized frame."""
    n = len(block)
    if target_bytes is None or n <= 1 or block.nbytes <= target_bytes:
        return [block]
    step = max(1, int(n * target_bytes / max(1, block.nbytes)))
    return [ColumnBlock(block.schema, [c[i:i + step] for c in block.columns])
            for i in range(0, n, step)]


class _SubscriberConn:
    """One attached subscriber: a transport plus the sender thread that
    walks it forward from its watermark.  Broadcast rings fan out to R
    readers through a single conn (one write per epoch)."""

    _ids = itertools.count()

    def __init__(self, pub: "Publication", transport: Transport,
                 watermark: int, readers: int = 1, admission: Any = None):
        self.pub = pub
        self.transport = transport
        self.sent = int(watermark)
        self.readers = readers
        self.admission = admission
        self.attached_at_head = pub.head
        self._stop = False
        self._thread = threading.Thread(
            target=self._run, daemon=True,
            name=f"pipegen-pub-send-{pub.name}-{next(self._ids)}")

    def start(self) -> None:
        self._thread.start()

    def stop(self) -> None:
        with self.pub._cv:
            self._stop = True
            self.pub._cv.notify_all()

    def join(self, timeout: float = 10.0) -> None:
        self._thread.join(timeout)

    # sender loop ---------------------------------------------------------------
    def _run(self) -> None:
        pub = self.pub
        try:
            self.transport.send_frame(
                FRAME_SCHEMA, encode_schema(pub.schema, pub.hello_meta()))
            while True:
                with pub._cv:
                    while (pub.head <= self.sent and not self._stop
                           and not pub._closing):
                        pub._cv.wait(1.0)
                    if pub.head <= self.sent and (self._stop or pub._closing):
                        break  # drained: graceful EOF below
                    head = pub.head
                rec = pub._log.get(self.sent + 1)
                if rec is not None:
                    self._send_record(rec, head)
                    if rec.epoch <= self.attached_at_head:
                        pub.stats.replayed_epochs += 1
                    self.sent = rec.epoch
                else:
                    # watermark fell off the log: full snapshot of the
                    # current image stamped with its epoch, then deltas
                    self._send_snapshot()
                pub._update_gauges()
            self.transport.send_frame(FRAME_EOF, b"")
        except (OSError, ValueError, IOError):
            pass  # subscriber went away; the publication keeps running
        finally:
            try:
                self.transport.close()
            except Exception:
                pass
            if self.admission is not None:
                try:
                    self.admission.release()
                except Exception:
                    pass
            pub._retire(self)

    def _send_record(self, rec: _EpochRecord, head: int) -> None:
        hdr = encode_epoch_header(rec.epoch, head, rec.kind,
                                  len(rec.payloads), rec.rows, rec.ts)
        self.transport.send_frame(FRAME_EPOCH, hdr)
        for payload in rec.payloads:
            self.transport.send_frame(FRAME_BLOCK, payload)

    def _max_chunk_bytes(self) -> Optional[int]:
        """Raw-bytes budget per snapshot chunk: an shm ring bounds the frame
        size at its capacity, so a big image must ship as k row-slices (the
        D header's ``blocks`` field already frames multi-payload epochs).
        Socket/channel/striped transports have no frame cap."""
        ring = getattr(self.transport, "ring", None)
        if ring is None:
            return None
        return max(4096, ring.capacity // 2)

    def _send_snapshot(self) -> None:
        pub = self.pub
        epoch, image = pub._snapshot_image()
        if image is None:       # closing before any commit
            return
        chunks = _chunk_rows(image, self._max_chunk_bytes())
        payloads, rows, _ = pub._encode_blocks(chunks, fallback=True)
        pub.stats.snapshot_fallbacks += 1
        hdr = encode_epoch_header(epoch, epoch, "snapshot",
                                  len(payloads), rows, time.time())
        self.transport.send_frame(FRAME_EPOCH, hdr)
        for payload in payloads:
            self.transport.send_frame(FRAME_BLOCK, payload)
        self.sent = epoch


class Publication:
    """A named, continuously-updated relation other processes subscribe to.

    ``commit(blocks)`` assigns the next epoch, encodes each block exactly
    once, appends the payloads to the replay log, folds the delta into the
    publication's running *image* (the late-joiner snapshot source — kept
    here, not in the engine, so snapshots are epoch-consistent without
    holding any engine lock), and wakes every sender.
    """

    def __init__(self, name: str, schema: Schema, *,
                 directory: Optional[DirectoryLike] = None,
                 mode: str = "arrowcol", codec: str = "none",
                 retain_epochs: int = 64, retain_bytes: int = 64 << 20,
                 start_epoch: int = 0, lease_s: Optional[float] = None,
                 tenant: str = "default", qos: str = "bulk",
                 link: Optional[LinkSim] = None,
                 attach_wait: Optional[float] = None):
        self.name = name
        self.schema = schema
        self.mode = mode
        self.codec_name = codec
        self.tenant = tenant
        self.qos = qos
        self._directory = directory if directory is not None else get_directory()
        self._wire = get_wire_format(mode)
        self._codec = get_codec(codec)
        self._link = link
        self._dataset = _sub_dataset(name)
        self._log = ReplayLog(retain_epochs, retain_bytes)
        self.head = int(start_epoch)
        self._image: Optional[ColumnBlock] = None
        self._cv = threading.Condition()
        self._conns: List[_SubscriberConn] = []
        self._closing = False
        self._closed = False
        self.stats = PubStats()

        # long-lived concurrency ticket: the publication itself holds a
        # zero-byte admission under its tenant/qos; each subscriber ring
        # admits its own (rings, bytes) vector at attach time
        self._admission = None
        broker = get_broker()
        if broker is not None:
            self._admission = broker.admit(
                tenant=tenant, qos=qos, rings=0, segments=0, nbytes=0)

        doc = {
            "name": name, "dataset": self._dataset, "query": _SUB_QUERY,
            "mode": mode, "codec": codec, "pid": os.getpid(),
            "schema": schema.to_dict(), "start_epoch": int(start_epoch),
        }
        self._pub_doc = doc
        self._lease_s = lease_s
        self._head_published = int(start_epoch)
        self._head_pub_at = time.monotonic()
        self._directory.publish_name(name, doc, lease_s=lease_s)
        self._renewer: Optional[LeaseRenewer] = None
        if lease_s and hasattr(self._directory, "renew_name"):
            self._renewer = LeaseRenewer(
                lambda ls: self._directory.renew_name(self.name, lease_s=ls),
                lease_s, on_lost=self._republish,
                name=f"pipegen-pub-renew-{name}").start()

        _register_publication(self)
        # in-process directories block cheaply on a condvar; a
        # DirectoryClient burns an RPC per poll, so poll it coarser
        in_proc = hasattr(self._directory, "_queries")
        self._attach_wait = attach_wait if attach_wait else (
            5.0 if in_proc else 1.0)
        self._attach_thread = threading.Thread(
            target=self._attach_loop, daemon=True,
            name=f"pipegen-pub-attach-{name}")
        self._attach_thread.start()

    # -- commit path ------------------------------------------------------------
    def commit(self, blocks: Any, kind: str = "delta") -> int:
        """Commit one epoch of ``blocks`` (a ColumnBlock or sequence).
        Returns the epoch assigned; an empty delta commits nothing and
        returns the current head."""
        if isinstance(blocks, ColumnBlock):
            blocks = [blocks]
        blocks = [b for b in blocks if len(b)]
        if not blocks:
            if kind == "snapshot":
                raise SubscribeError("snapshot commit needs at least one row")
            return self.head
        with telemetry.span("subscribe.epoch", pub=self.name, kind=kind):
            payloads, rows, nbytes = self._encode_blocks(blocks)
            with self._cv:
                if self._closing:
                    raise SubscribeError(
                        f"publication {self.name!r} is closed")
                epoch = self.head + 1
                self._log.append(_EpochRecord(
                    epoch, kind, payloads, rows, nbytes, time.time()))
                if kind == "snapshot":
                    self._image = (blocks[0] if len(blocks) == 1
                                   else ColumnBlock.concat(blocks))
                elif self._image is not None and len(self._image):
                    self._image = ColumnBlock.concat([self._image] + blocks)
                else:
                    self._image = (blocks[0] if len(blocks) == 1
                                   else ColumnBlock.concat(blocks))
                self.head = epoch
                self.stats.epochs += 1
                self.stats.bytes_logged += nbytes
                self._cv.notify_all()
        self._update_gauges()
        self._maybe_publish_head()
        return epoch

    def _maybe_publish_head(self) -> None:
        """Re-stamp the published name doc with the committed head,
        throttled to one RPC per half second.  A journaling broker logs
        every ``publish_name``, so after a control-plane crash the
        recovered registry re-pins this publication at (close to) its
        committed head instead of the head it had at publish time."""
        now = time.monotonic()
        with self._cv:
            if (self._closing or self.head == self._head_published
                    or now - self._head_pub_at < 0.5):
                return
            self._head_published = self.head
            self._head_pub_at = now
            doc = dict(self._pub_doc, head=self.head)
        try:
            self._directory.publish_name(self.name, doc,
                                         lease_s=self._lease_s)
        except (OSError, ValueError):  # pragma: no cover - broker flap
            pass

    def _republish(self) -> None:
        """Name-lease ``on_lost``: the published name expired under us
        (broker restart without a journal, or a GC race) while the
        publication itself is alive and committing.  Self-heal: publish
        again at the current head and restart the heartbeat."""
        with self._cv:
            if self._closing:
                return
            doc = dict(self._pub_doc, head=self.head)
            self._head_published = self.head
        try:
            self._directory.publish_name(self.name, doc,
                                         lease_s=self._lease_s)
        except (OSError, ValueError):  # pragma: no cover - broker gone
            return
        telemetry.counter("subscribe.name_republished").inc()
        self._renewer = LeaseRenewer(
            lambda ls: self._directory.renew_name(self.name, lease_s=ls),
            self._lease_s, on_lost=self._republish,
            name=f"pipegen-pub-renew-{self.name}").start()

    def append(self, block: ColumnBlock) -> int:
        return self.commit(block, kind="delta")

    def commit_snapshot(self, block: ColumnBlock) -> int:
        """Commit the relation's full current contents as one epoch — the
        normal first commit, and the restart path after a crash (the new
        image replaces, rather than extends, what subscribers hold)."""
        return self.commit(block, kind="snapshot")

    def _encode_blocks(self, blocks: Sequence[ColumnBlock],
                       fallback: bool = False
                       ) -> Tuple[List[bytes], int, int]:
        payloads: List[bytes] = []
        rows = 0
        nbytes = 0
        for b in blocks:
            data = self._codec.compress(self._wire.encode_block(b).join())
            payloads.append(bytes(data))
            rows += len(b)
            nbytes += len(payloads[-1])
        if fallback:
            self.stats.fallback_encodes += len(blocks)
        else:
            self.stats.encodes += len(blocks)
        return payloads, rows, nbytes

    def _snapshot_image(self, timeout: float = 30.0
                        ) -> Tuple[int, Optional[ColumnBlock]]:
        """The current (head, image) pair, bound together under the
        publication lock so the snapshot is exactly epoch ``head``."""
        deadline = time.monotonic() + timeout
        with self._cv:
            while self._image is None and not self._closing:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._cv.wait(remaining)
            return self.head, self._image

    def hello_meta(self) -> Dict[str, Any]:
        return {"mode": self.mode, "codec": self.codec_name,
                "name": self.name}

    # -- subscriber attach ------------------------------------------------------
    def _attach_loop(self) -> None:
        while True:
            with self._cv:
                if self._closing:
                    return
            try:
                ep = self._directory.query(
                    self._dataset, _SUB_QUERY, timeout=self._attach_wait)
            except TimeoutError:
                continue
            except Exception:
                with self._cv:
                    if self._closing:
                        return
                time.sleep(0.2)
                continue
            if ep.resume_seq < 0:
                # wake sentinel — ours, or one a closed predecessor of
                # this name never popped; never a real subscriber
                with self._cv:
                    if self._closing:
                        return
                continue
            with self._cv:
                closing = self._closing
            if closing:
                self._refuse(ep)
                return
            self._attach(ep)

    def _attach(self, ep: Endpoint) -> None:
        admission = None
        broker = get_broker()
        shm_rings = ((1 if ep.is_shm else 0)
                     + sum(1 for m in ep.members if m.is_shm))
        if broker is not None and shm_rings:
            nbytes = (ep.shm_capacity or 0) + sum(
                m.shm_capacity or 0 for m in ep.members)
            try:
                admission = broker.admit(
                    tenant=self.tenant, qos=self.qos, rings=shm_rings,
                    segments=shm_rings, nbytes=nbytes, timeout=30.0)
            except BrokerBusy:
                self.stats.admission_rejects += 1
                telemetry.counter("pipe.subscription.admission_rejects",
                                  pub=self.name).inc()
                self._refuse(ep)
                return
        try:
            if ep.is_group:
                # per-subscriber striped pipes (remote): wrap the member
                # transports in the striped sender used by one-shot edges
                from .stream import StripedSender
                parts = [_connect(m, self._link) for m in ep.members]
                transport: Transport = StripedSender(parts)
            else:
                transport = _connect(ep, self._link)
        except (OSError, IOError):
            if admission is not None:
                admission.release()
            return
        conn = _SubscriberConn(
            self, transport, watermark=ep.resume_seq,
            readers=max(1, ep.broadcast), admission=admission)
        with self._cv:
            self._conns.append(conn)
        conn.start()
        self._update_gauges()

    def _refuse(self, ep: Endpoint) -> None:
        """EOF a subscriber we cannot serve so it fails fast instead of
        waiting out its rendezvous timeout."""
        try:
            tr = _connect(ep, self._link)
            tr.send_frame(FRAME_EOF, b"")
            tr.close()
        except Exception:
            pass

    def _retire(self, conn: _SubscriberConn) -> None:
        with self._cv:
            if conn in self._conns:
                self._conns.remove(conn)
        self._update_gauges()

    # -- introspection ----------------------------------------------------------
    @property
    def subscribers(self) -> int:
        with self._cv:
            return sum(c.readers for c in self._conns)

    @property
    def min_watermark(self) -> int:
        with self._cv:
            if not self._conns:
                return self.head
            return min(c.sent for c in self._conns)

    def _update_gauges(self) -> None:
        reg = telemetry.registry()
        labels = {"pub": self.name}
        reg.gauge("pipe.subscription.head_epoch", **labels).set(self.head)
        reg.gauge("pipe.subscription.retained_bytes",
                  **labels).set(self._log.nbytes)
        reg.gauge("pipe.subscription.subscribers",
                  **labels).set(self.subscribers)
        reg.gauge("pipe.subscription.min_watermark",
                  **labels).set(self.min_watermark)

    def snapshot_row(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "subscribers": self.subscribers,
            "head_epoch": self.head,
            "min_watermark": self.min_watermark,
            "retained_bytes": self._log.nbytes,
            "retained_epochs": len(self._log),
            "floor": self._log.floor,
            "epochs": self.stats.epochs,
            "snapshot_fallbacks": self.stats.snapshot_fallbacks,
        }

    # -- teardown ---------------------------------------------------------------
    def close(self, timeout: float = 10.0) -> None:
        """Drain committed epochs to every subscriber, EOF them, release
        admission, drop the name, stop the lease renewer."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._closing = True
            self._cv.notify_all()
        self._wake_attach()
        self._attach_thread.join(self._attach_wait + 2.0)
        with self._cv:
            conns = list(self._conns)
        for conn in conns:
            conn.stop()
        for conn in conns:
            conn.join(timeout)
        if self._renewer is not None:
            self._renewer.stop(join=True)
        try:
            self._directory.unpublish_name(self.name)
        except Exception:
            pass
        if self._admission is not None:
            try:
                self._admission.release()
            except Exception:
                pass
        _unregister_publication(self)
        reg = telemetry.registry()
        for g in ("head_epoch", "retained_bytes", "subscribers",
                  "min_watermark"):
            reg.drop(f"pipe.subscription.{g}", kind="g", pub=self.name)

    def _wake_attach(self) -> None:
        # the attach loop may be parked inside query(); an in-process
        # directory wakes instantly off a sentinel channel endpoint, a
        # DirectoryClient polls out within _attach_wait on its own
        try:
            if hasattr(self._directory, "_queries"):
                # resume_seq=-1 marks it as a sentinel: if the attach
                # loop exits before popping it, a successor publication
                # under the same name must not mistake it for a real
                # subscriber and serve a snapshot into the void
                self._directory.register(
                    self._dataset, Endpoint(channel=Channel(),
                                            resume_seq=-1), _SUB_QUERY)
        except Exception:
            pass

    def __enter__(self) -> "Publication":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


# -- subscriber --------------------------------------------------------------------

@dataclass
class EpochDelta:
    """One received epoch: ``kind == "snapshot"`` replaces the local copy
    of the relation, ``"delta"`` extends it."""

    epoch: int
    kind: str
    blocks: List[ColumnBlock] = field(default_factory=list)
    rows: int = 0
    ts: float = 0.0
    head: int = 0

    @property
    def block(self) -> ColumnBlock:
        if len(self.blocks) == 1:
            return self.blocks[0]
        return ColumnBlock.concat(self.blocks)


@dataclass
class SubStats:
    epochs: int = 0
    snapshots: int = 0
    duplicates: int = 0
    rows: int = 0


class Subscription:
    """A live importer handle on a named publication.

    ``poll()`` returns the epochs received since the last call (advancing
    ``watermark``); once the publisher EOFs or dies *and* every queued
    epoch is drained, ``poll()`` raises :class:`PublicationEnded` carrying
    the watermark to resubscribe at.  The handle owns its directory lease
    renewer for its whole lifetime — close() stops and joins it.
    """

    _ids = itertools.count()

    def __init__(self, name: str, *, watermark: int = 0,
                 directory: Optional[DirectoryLike] = None,
                 transport: str = "shm", broadcast: int = 0,
                 group: str = "bc0", streams: int = 1,
                 shm_capacity: int = DEFAULT_RING_CAPACITY,
                 doorbell: bool = True, lease_s: Optional[float] = None,
                 timeout: float = 30.0, link: Optional[LinkSim] = None,
                 host: str = "127.0.0.1",
                 apply: Optional[Callable[[EpochDelta], None]] = None,
                 queue_max: int = 0, sub_id: Optional[str] = None):
        self.name = name
        self._directory = directory if directory is not None else get_directory()
        doc = self._directory.lookup_name(name, timeout=timeout)
        self._dataset = doc.get("dataset") or _sub_dataset(name)
        self.watermark = int(watermark)
        self.head = int(watermark)
        self.mode = doc.get("mode", "arrowcol")
        self.schema: Optional[Schema] = (
            Schema.from_dict(doc["schema"]) if doc.get("schema") else None)
        self.sub_id = sub_id or f"{os.getpid()}-{next(self._ids)}"
        self._apply = apply
        self._link = link
        self._cv = threading.Condition()
        self._queue: "deque[EpochDelta]" = deque()
        # bounded queue = real backpressure: a subscriber that stops
        # polling stops draining its ring, the publisher's sender blocks,
        # and retention eviction heals it with a snapshot on resume
        self._queue_max = int(queue_max)
        self._received = int(watermark)   # dedup floor (broadcast overlap)
        self._ended = False
        self._error: Optional[BaseException] = None
        self._closed = False
        self.stats = SubStats()
        self._ring: Optional[ShmRing] = None
        self._renewer: Optional[LeaseRenewer] = None

        self._transport = self._rendezvous(
            transport, broadcast, group, streams, shm_capacity, doorbell,
            lease_s, timeout, host)

        self._recv_thread = threading.Thread(
            target=self._recv_loop, daemon=True,
            name=f"pipegen-sub-recv-{name}-{self.sub_id}")
        self._recv_thread.start()

    # -- rendezvous -------------------------------------------------------------
    def _rendezvous(self, transport: str, broadcast: int, group: str,
                    streams: int, shm_capacity: int, doorbell: bool,
                    lease_s: Optional[float], timeout: float,
                    host: str) -> Transport:
        d = self._directory
        if broadcast > 1:
            if transport != "shm":
                raise SubscribeError(
                    "broadcast subscriptions require the shm transport")
            # R colocated subscribers share one ring: first joiner creates
            # and registers it (its watermark seeds the group's resume
            # point — co-subscribers should join at the same watermark)
            slot, ep = d.join_broadcast(
                self._dataset, _SUB_QUERY, readers=broadcast,
                timeout=timeout)
            if ep is None:
                ring = acquire_broadcast_ring(
                    shm_capacity, broadcast, doorbell=doorbell)
                d.publish_broadcast(
                    self._dataset,
                    Endpoint(shm_name=ring.name, shm_capacity=ring.capacity,
                             broadcast=broadcast, shared=True,
                             resume_seq=self.watermark),
                    _SUB_QUERY, import_workers=1)
            else:
                ring = ShmRing.attach(ep.shm_name, role="reader", slot=slot)
            self._ring = ring
            return ShmRingTransport(ring, self._link)
        if streams > 1:
            # striped remote subscription: N member sockets, one logical pipe
            members: List[Endpoint] = []
            socks = []
            for _ in range(streams):
                ls = listen_socket(host)
                socks.append(ls)
                members.append(Endpoint(host=host, port=ls.getsockname()[1]))
            ep = Endpoint(members=tuple(members), resume_seq=self.watermark)
            d.register(self._dataset, ep, _SUB_QUERY, lease_s=lease_s)
            self._start_renewer(lease_s)
            parts = []
            for ls in socks:
                ls.settimeout(timeout)
                conn, _ = ls.accept()
                ls.close()
                parts.append(SocketTransport(conn, self._link))
            return StripedReceiver(parts)
        if transport == "channel":
            ch = Channel()
            d.register(self._dataset, Endpoint(channel=ch),
                       _SUB_QUERY, lease_s=lease_s)
            self._start_renewer(lease_s)
            return ChannelTransport(ch, self._link)
        if transport == "shm":
            ring = acquire_ring(shm_capacity, doorbell=doorbell)
            d.register(self._dataset,
                       Endpoint(shm_name=ring.name,
                                shm_capacity=ring.capacity,
                                resume_seq=self.watermark),
                       _SUB_QUERY, lease_s=lease_s)
            self._start_renewer(lease_s)
            self._ring = ring
            return ShmRingTransport(ring, self._link)
        if transport == "socket":
            ls = listen_socket(host)
            d.register(self._dataset,
                       Endpoint(host=host, port=ls.getsockname()[1],
                                resume_seq=self.watermark),
                       _SUB_QUERY, lease_s=lease_s)
            self._start_renewer(lease_s)
            ls.settimeout(timeout)
            conn, _ = ls.accept()
            ls.close()
            return SocketTransport(conn, self._link)
        raise SubscribeError(f"unknown subscription transport {transport!r}")

    def _start_renewer(self, lease_s: Optional[float]) -> None:
        # satellite fix: the renewer belongs to the *subscription handle*,
        # not to any single transfer — it heartbeats until close()
        if not lease_s or not hasattr(self._directory, "renew"):
            return
        self._renewer = LeaseRenewer(
            lambda ls: self._directory.renew(
                self._dataset, _SUB_QUERY, lease_s=ls),
            lease_s, on_lost=self._on_lease_lost,
            name=f"pipegen-sub-renew-{self.name}").start()

    def _on_lease_lost(self) -> None:
        if self._ring is not None:
            try:
                self._ring.abort(
                    f"subscription lease on {self.name!r} expired")
            except Exception:
                pass

    # -- receive path -----------------------------------------------------------
    def _recv_loop(self) -> None:
        tr = self._transport
        try:
            kind, payload = tr.recv_frame()
            if kind == FRAME_SCHEMA:
                schema, meta = decode_schema(bytes(payload))
                self.schema = schema
                wire = get_wire_format(meta.get("mode", self.mode))
                codec = get_codec(meta.get("codec", "none"))
                while True:
                    kind, payload = tr.recv_frame()
                    if kind == FRAME_EOF:
                        break
                    if kind != FRAME_EPOCH:
                        continue  # tolerate stray frames (verify etc.)
                    hdr = decode_epoch_header(payload)
                    blocks: List[ColumnBlock] = []
                    for _ in range(int(hdr.get("blocks", 1))):
                        k2, data = tr.recv_frame()
                        if k2 == FRAME_EOF:
                            raise BrokenPipeError(
                                "publication ended mid-epoch")
                        if k2 != FRAME_BLOCK:
                            raise IOError(
                                f"expected block frame, got {k2!r}")
                        # decode immediately: shm payloads are in-place
                        # views consumed by the next recv
                        blocks.append(wire.decode_block(
                            codec.decompress(data), schema))
                    self._on_epoch(hdr, blocks)
            elif kind != FRAME_EOF:
                raise IOError(f"expected schema hello, got {kind!r}")
        except BaseException as e:
            with self._cv:
                if not self._closed:
                    self._error = e
                self._ended = True
                self._cv.notify_all()
        else:
            with self._cv:
                self._ended = True
                self._cv.notify_all()

    def _on_epoch(self, hdr: Dict[str, Any],
                  blocks: List[ColumnBlock]) -> None:
        epoch = int(hdr.get("epoch", 0))
        kind = hdr.get("kind", "delta")
        head = int(hdr.get("head", epoch))
        ts = float(hdr.get("ts", 0.0))
        with self._cv:
            while (self._queue_max and len(self._queue) >= self._queue_max
                   and not self._closed):
                self._cv.wait(0.2)
            self.head = max(self.head, head)
            if epoch <= self._received:
                # broadcast rings share one stream: co-subscribers with a
                # lower watermark see replays this handle already applied
                self.stats.duplicates += 1
                return
            self._received = epoch
            delta = EpochDelta(epoch, kind, blocks,
                               int(hdr.get("rows", 0)), ts, head)
            self._queue.append(delta)
            self.stats.epochs += 1
            if kind == "snapshot":
                self.stats.snapshots += 1
            self.stats.rows += delta.rows
            self._cv.notify_all()
        self._lag_gauges(ts)

    def _lag_gauges(self, ts: float = 0.0) -> None:
        reg = telemetry.registry()
        labels = {"pub": self.name, "sub": self.sub_id}
        reg.gauge("pipe.subscription.lag_epochs", **labels).set(
            max(0, self.head - self.watermark))
        if ts:
            reg.gauge("pipe.subscription.lag_seconds", **labels).set(
                max(0.0, time.time() - ts))

    # -- consumer API -----------------------------------------------------------
    def poll(self, timeout: float = 0.0,
             max_epochs: Optional[int] = None) -> List[EpochDelta]:
        """Epochs received since the last poll, oldest first.  Blocks up
        to ``timeout`` seconds for at least one (0 = non-blocking).
        Raises :class:`PublicationEnded` once the publisher is gone *and*
        the queue is drained."""
        deadline = time.monotonic() + timeout if timeout else None
        out: List[EpochDelta] = []
        with self._cv:
            while True:
                while self._queue and (max_epochs is None
                                       or len(out) < max_epochs):
                    out.append(self._queue.popleft())
                if out:
                    self._cv.notify_all()  # wake a backpressured receiver
                if out or self._closed:
                    break
                if self._ended:
                    raise PublicationEnded(
                        f"publication {self.name!r} ended "
                        f"(watermark {self.watermark})",
                        watermark=self.watermark) from self._error
                if deadline is None:
                    break
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._cv.wait(remaining)
        for delta in out:
            if self._apply is not None:
                self._apply(delta)
            self.watermark = delta.epoch
        if out:
            self._lag_gauges()
        return out

    @property
    def lag_epochs(self) -> int:
        return max(0, self.head - self.watermark)

    @property
    def ended(self) -> bool:
        with self._cv:
            return self._ended and not self._queue

    def close(self) -> None:
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()
        if self._ring is not None:
            try:
                self._ring.abort("subscription closed")
            except Exception:
                pass
        try:
            self._transport.close()
        except Exception:
            pass
        self._recv_thread.join(5.0)
        if self._renewer is not None:
            self._renewer.stop(join=True)
        reg = telemetry.registry()
        for g in ("lag_epochs", "lag_seconds"):
            reg.drop(f"pipe.subscription.{g}", kind="g",
                     pub=self.name, sub=self.sub_id)

    def __enter__(self) -> "Subscription":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


# -- module registry (pipetop / broker stats) --------------------------------------

_PUBS_LOCK = threading.Lock()
_PUBS: Dict[int, Publication] = {}


def _register_publication(pub: Publication) -> None:
    with _PUBS_LOCK:
        _PUBS[id(pub)] = pub


def _unregister_publication(pub: Publication) -> None:
    with _PUBS_LOCK:
        _PUBS.pop(id(pub), None)


def publications_snapshot() -> List[Dict[str, Any]]:
    """One row per live publication in this process — what pipetop's
    subscriptions table and ``PipeBroker.stats()`` serve."""
    with _PUBS_LOCK:
        pubs = list(_PUBS.values())
    return [p.snapshot_row() for p in pubs]


# -- factories ---------------------------------------------------------------------

def publish(name: str, schema: Optional[Schema] = None, *,
            initial: Optional[ColumnBlock] = None,
            **kw: Any) -> Publication:
    """Publish a relation under ``name``.  ``initial`` commits the current
    contents as epoch ``start_epoch + 1`` (a snapshot) so subscribers have
    a base image; pass ``schema`` alone to start empty."""
    if schema is None:
        if initial is None:
            raise SubscribeError("publish() needs a schema or an initial block")
        schema = initial.schema
    pub = Publication(name, schema, **kw)
    if initial is not None and len(initial):
        pub.commit_snapshot(initial)
    return pub


def subscribe(name: str, **kw: Any) -> Subscription:
    """Subscribe to publication ``name`` at ``watermark`` (default 0 = from
    the beginning; the publisher decides replay vs snapshot per its log)."""
    return Subscription(name, **kw)


def apply_to_engine(engine: Any, table: str) -> Callable[[EpochDelta], None]:
    """An ``apply=`` callback that maintains ``engine[table]`` from the
    epoch stream: snapshots replace the table, deltas append to it."""
    def _apply(delta: EpochDelta) -> None:
        if delta.kind == "snapshot" or table not in engine.tables:
            engine.put_block(table, delta.block)
        else:
            engine.append(table, delta.block)
    return _apply
