"""Transfer session: the user-facing surface of PipeGen (paper section 3.1).

The paper's usage model is two queries — an export on the source DBMS and an
import on the target — with PipeGen's worker directory pairing the two sides
at runtime.  :func:`transfer` packages exactly that for the one-edge case; it
is a thin back-compat shim over a one-edge :mod:`repro.core.plan`
TransferPlan, which is where multi-edge DAGs (chains, fan-outs, batches),
per-edge negotiation, and ``explain()`` live.

:func:`transfer_via_files` is the baseline the paper compares against: the
same export/import through real files on the file system (a one-edge plan
with ``via="files"``).
"""

from __future__ import annotations

import itertools
import os
import tempfile
import threading
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional

from .codegen import GeneratedPipe, PipeEnabledEngine, generate_pipe_adapter
from .datapipe import PipeConfig, PipeStats
from .directory import WorkerDirectory
from .ioredirect import PipeOpenContext

__all__ = ["TransferResult", "transfer", "transfer_via_files", "adapter_for",
           "negotiate_pipe_mode"]

_query_counter = itertools.count(1)
_adapter_cache: Dict[str, GeneratedPipe] = {}
_adapter_lock = threading.Lock()


@dataclass
class TransferResult:
    source: str
    target: str
    mode: str
    codec: str
    rows: int
    seconds: float
    export_seconds: float = 0.0
    import_seconds: float = 0.0
    bytes_moved: int = 0
    # every peer failure (export AND import side, plus timeouts), formatted;
    # empty on success.  transfer() raises the first underlying exception
    # with the others chained as __context__; PlanResult keeps them all.
    errors: List[str] = field(default_factory=list)
    # merged PipeStats across all workers / shuffle members / streams of
    # the transfer (per-stream breakdowns under .per_stream); None when the
    # path doesn't open data pipes (the file baseline)
    export_stats: Optional[PipeStats] = None
    import_stats: Optional[PipeStats] = None
    # retry policy history, one dict per attempt ({attempt, query_id,
    # transport, seconds, ok, error, export_stats, import_stats}); a
    # single clean run has one entry when the edge carries a retry
    # policy, else it stays empty
    attempts: List[dict] = field(default_factory=list)

    def stats_for_attempt(self, attempt: int, role: str = "export"
                          ) -> Optional[PipeStats]:
        """That attempt's own pipe stats (``role`` is "export" or
        "import"), or None when the attempt is unknown or carried none."""
        for rec in self.attempts:
            if rec.get("attempt") == attempt:
                return rec.get(f"{role}_stats")
        return None

    def folded_stats(self, role: str = "export") -> Optional[PipeStats]:
        """Pipe stats merged across every recorded attempt — the
        whole-edge cost including retries; falls back to the top-level
        (final-attempt) stats when no per-attempt history exists."""
        merged: Optional[PipeStats] = None
        for rec in self.attempts:
            st = rec.get(f"{role}_stats")
            if st is None:
                continue
            if merged is None:
                merged = PipeStats()
            merged.merge(st)
        if merged is not None:
            return merged
        return (self.export_stats if role == "export"
                else self.import_stats)


def adapter_for(engine: Any) -> GeneratedPipe:
    """Generate (once per engine class) the pipe adapter via the compile
    loop: run the engine's unit tests, locate IO call sites, emit adapter."""
    key = engine.name
    with _adapter_lock:
        gp = _adapter_cache.get(key)
        if gp is None:
            with tempfile.TemporaryDirectory() as td:
                gp = generate_pipe_adapter(
                    engine.name,
                    engine.unit_export_test,
                    engine.unit_import_test,
                    os.path.join(td, "unit.csv"),
                )
            _adapter_cache[key] = gp
        return gp


#: FormOpt optimization ladder, most-optimized first (paper sections 5.1/5.2:
#: if the generated code fails the unit tests, disable the optimization and
#: fall back — ultimately to the basic IORedirect text pipe).
MODE_LADDER = ("arrowcol", "arrowrow", "binary_rows", "parts", "text")


def negotiate_pipe_mode(engine: Any, spool_dir: Optional[str] = None) -> PipeConfig:
    """Run the engine's own round-trip unit tests across the verification
    proxy for each FormOpt rung, most-optimized first; return the first
    configuration that validates (the paper's disable-on-failure loop).

    The planner caches the outcome process-wide per engine name
    (:func:`repro.core.plan.negotiated_config`)."""
    import tempfile

    from .verify import validate_generated_pipe

    gp = adapter_for(engine)
    own_tmp = spool_dir is None
    td = spool_dir or tempfile.mkdtemp(prefix="pipegen-verify-")
    try:
        for mode in MODE_LADDER:
            cfg = PipeConfig(mode=mode)
            with PipeEnabledEngine(gp), PipeOpenContext(cfg):
                res = validate_generated_pipe(
                    engine.name, engine.unit_roundtrip_test, td,
                    dataset=f"neg-{engine.name}-{mode}", config=cfg)
            if res.passed:
                return cfg
        raise RuntimeError(
            f"no pipe mode validates for engine {engine.name!r}")
    finally:
        if own_tmp:
            import shutil

            shutil.rmtree(td, ignore_errors=True)


def transfer(
    src: Any,
    table: str,
    dst: Any,
    dst_table: str,
    config: Optional[PipeConfig] = None,
    workers: int = 1,
    import_workers: Optional[int] = None,
    dataset: Optional[str] = None,
    directory: Optional[WorkerDirectory] = None,
    timeout: float = 120.0,
    transport: Optional[str] = None,
    streams: Optional[int] = None,
    partition: Optional[str] = None,
    tenant: str = "default",
    qos: str = "bulk",
) -> TransferResult:
    """Move ``src:table`` into ``dst:dst_table`` over a generated data pipe.

    Back-compat shim: builds a one-edge :mod:`repro.core.plan` plan with an
    explicit config (no negotiation ladder) and executes it.  The export
    runs with the destination's dialect (header/delimiter), the way the
    paper's users configure their export queries; ``workers`` /
    ``import_workers`` reproduce the section 4.2 N:M pairing.

    ``transport`` overrides the pipe's rendezvous flavor without building a
    whole config: ``socket`` (TCP loopback), ``channel`` (in-process
    queue), or ``shm`` (shared-memory ring — the zero-copy path that also
    works when exporter and importer are separate OS processes).

    ``streams`` stripes every worker pair's pipe across N member
    connections (reassembled in order on the import side); ``partition``
    (``hash[:col]`` / ``range[:col]`` / ``rr``) runs the transfer as an
    N→M repartitioning shuffle instead of 1:1 pairing — every export
    worker routes rows by key to *all* ``import_workers`` importers, each
    of which merges the ``workers`` incoming streams.  The two knobs
    compose: with both set, each shuffle member pipe is itself striped
    across ``streams`` connections (the importer registers one private
    slot group per exporter).

    ``tenant`` / ``qos`` tag the transfer for admission when a
    :class:`repro.core.broker.PipeBroker` is installed (no-ops otherwise):
    the broker draws the transfer's rings/segments/bytes from that
    tenant's budget, and ``qos="latency"`` jumps the admission queue
    ahead of ``"bulk"`` work.

    On failure the first exception is raised with every other peer failure
    chained as ``__context__`` (nothing is swallowed).
    """
    from .plan import chain_exceptions, plan as _plan

    config = config or PipeConfig()
    if transport is not None:
        config = replace(config, transport=transport)
    if streams is not None:
        config = replace(config, streams=streams)
    if partition is not None:
        config = replace(config, partition=partition)
    p = _plan(directory=directory, negotiate=False).move(
        src, table, dst, dst_table,
        config=config, workers=workers, import_workers=import_workers,
        dataset=dataset, timeout=timeout, tenant=tenant, qos=qos,
    )
    res = p.compile().execute(raise_on_error=False)
    if res.exceptions:
        raise chain_exceptions(res.exceptions)
    return res.single()


def transfer_via_files(
    src: Any,
    table: str,
    dst: Any,
    dst_table: str,
    workers: int = 1,
    tmpdir: Optional[str] = None,
) -> TransferResult:
    """The paper's baseline: export to CSV files on disk, then import them.
    Fully sequential (the importer cannot start until the files exist).
    Back-compat shim over a one-edge ``via="files"`` plan."""
    from .plan import chain_exceptions, plan as _plan

    if tmpdir is not None:
        # caller-owned spool dir: keep the part files around (the legacy
        # contract tests/benchmarks rely on), so run the file edge inline
        from .plan import run_file_transfer

        return run_file_transfer(src, table, dst, dst_table, workers,
                                 td=tmpdir)
    p = _plan(negotiate=False).move(src, table, dst, dst_table,
                                    via="files", workers=workers)
    res = p.compile().execute(raise_on_error=False)
    if res.exceptions:
        raise chain_exceptions(res.exceptions)
    return res.single()
