"""Transfer session: the user-facing surface of PipeGen (paper section 3.1).

The paper's usage model is two queries — an export on the source DBMS and an
import on the target — with PipeGen's worker directory pairing the two sides
at runtime.  :func:`transfer` packages exactly that: it runs the export and
import concurrently (each under its engine's generated pipe splice), matches
the destination's text dialect the way a user would configure the export,
and returns timing/byte statistics for the benchmarks.

:func:`transfer_via_files` is the baseline the paper compares against: the
same export/import through real files on the file system.
"""

from __future__ import annotations

import itertools
import os
import tempfile
import threading
import time
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional

from .codegen import GeneratedPipe, PipeEnabledEngine, generate_pipe_adapter
from .datapipe import PipeConfig, PipeStats, collect_stats
from .directory import WorkerDirectory, set_directory
from .ioredirect import PipeOpenContext

__all__ = ["TransferResult", "transfer", "transfer_via_files", "adapter_for",
           "negotiate_pipe_mode"]

_query_counter = itertools.count(1)
_adapter_cache: Dict[str, GeneratedPipe] = {}
_adapter_lock = threading.Lock()


@dataclass
class TransferResult:
    source: str
    target: str
    mode: str
    codec: str
    rows: int
    seconds: float
    export_seconds: float = 0.0
    import_seconds: float = 0.0
    bytes_moved: int = 0
    errors: List[str] = field(default_factory=list)
    # merged PipeStats across all workers / shuffle members / streams of
    # the transfer (per-stream breakdowns under .per_stream); None when the
    # path doesn't open data pipes (the file baseline)
    export_stats: Optional[PipeStats] = None
    import_stats: Optional[PipeStats] = None


def adapter_for(engine: Any) -> GeneratedPipe:
    """Generate (once per engine class) the pipe adapter via the compile
    loop: run the engine's unit tests, locate IO call sites, emit adapter."""
    key = engine.name
    with _adapter_lock:
        gp = _adapter_cache.get(key)
        if gp is None:
            with tempfile.TemporaryDirectory() as td:
                gp = generate_pipe_adapter(
                    engine.name,
                    engine.unit_export_test,
                    engine.unit_import_test,
                    os.path.join(td, "unit.csv"),
                )
            _adapter_cache[key] = gp
        return gp


#: FormOpt optimization ladder, most-optimized first (paper sections 5.1/5.2:
#: if the generated code fails the unit tests, disable the optimization and
#: fall back — ultimately to the basic IORedirect text pipe).
MODE_LADDER = ("arrowcol", "arrowrow", "binary_rows", "parts", "text")


def negotiate_pipe_mode(engine: Any, spool_dir: Optional[str] = None) -> PipeConfig:
    """Run the engine's own round-trip unit tests across the verification
    proxy for each FormOpt rung, most-optimized first; return the first
    configuration that validates (the paper's disable-on-failure loop)."""
    import tempfile

    from .verify import validate_generated_pipe

    gp = adapter_for(engine)
    own_tmp = spool_dir is None
    td = spool_dir or tempfile.mkdtemp(prefix="pipegen-verify-")
    try:
        for mode in MODE_LADDER:
            cfg = PipeConfig(mode=mode)
            with PipeEnabledEngine(gp), PipeOpenContext(cfg):
                res = validate_generated_pipe(
                    engine.name, engine.unit_roundtrip_test, td,
                    dataset=f"neg-{engine.name}-{mode}", config=cfg)
            if res.passed:
                return cfg
        raise RuntimeError(
            f"no pipe mode validates for engine {engine.name!r}")
    finally:
        if own_tmp:
            import shutil

            shutil.rmtree(td, ignore_errors=True)


def transfer(
    src: Any,
    table: str,
    dst: Any,
    dst_table: str,
    config: Optional[PipeConfig] = None,
    workers: int = 1,
    import_workers: Optional[int] = None,
    dataset: Optional[str] = None,
    directory: Optional[WorkerDirectory] = None,
    timeout: float = 120.0,
    transport: Optional[str] = None,
    streams: Optional[int] = None,
    partition: Optional[str] = None,
) -> TransferResult:
    """Move ``src:table`` into ``dst:dst_table`` over a generated data pipe.

    The export runs with the destination's dialect (header/delimiter), the
    way the paper's users configure their export queries.  ``workers`` /
    ``import_workers`` reproduce the section 4.2 N:M pairing.

    ``transport`` overrides the pipe's rendezvous flavor without building a
    whole config: ``socket`` (TCP loopback), ``channel`` (in-process
    queue), or ``shm`` (shared-memory ring — the zero-copy path that also
    works when exporter and importer are separate OS processes).

    ``streams`` stripes every worker pair's pipe across N member
    connections (reassembled in order on the import side); ``partition``
    (``hash[:col]`` / ``range[:col]`` / ``rr``) runs the transfer as an
    N→M repartitioning shuffle instead of 1:1 pairing — every export
    worker routes rows by key to *all* ``import_workers`` importers, each
    of which merges the ``workers`` incoming streams.  The two knobs are
    mutually exclusive (stripe a shuffle's member pipes is future work).
    """
    config = config or PipeConfig()
    if transport is not None:
        config = replace(config, transport=transport)
    if streams is not None:
        config = replace(config, streams=streams)
    if partition is not None:
        config = replace(config, partition=partition)
    if config.partition:
        if config.streams > 1:
            raise ValueError("streams and partition do not compose yet")
        # each importer merges one stream per export worker
        config = replace(config, fanin=workers)
    if directory is not None:
        set_directory(directory)
    gp_src, gp_dst = adapter_for(src), adapter_for(dst)
    qid = f"q{next(_query_counter)}"
    ds = dataset or f"{src.name}2{dst.name}"
    imp_workers = import_workers if import_workers is not None else workers
    name_exp = f"db://{ds}?workers={workers}&query={qid}"
    name_imp = f"db://{ds}?workers={imp_workers}&query={qid}"
    errs: List[BaseException] = []
    times = {"export": 0.0, "import": 0.0}
    stats_holder: List[Any] = []

    def run_import() -> None:
        t0 = time.perf_counter()
        try:
            with PipeEnabledEngine(gp_dst), PipeOpenContext(config):
                dst.import_csv_parallel(dst_table, name_imp, workers=imp_workers)
        except BaseException as e:  # noqa: BLE001 - surfaced via result
            errs.append(e)
        times["import"] = time.perf_counter() - t0

    def run_export() -> None:
        t0 = time.perf_counter()
        try:
            with PipeEnabledEngine(gp_src), PipeOpenContext(config):
                src.export_csv_parallel(
                    table, name_exp, workers=workers,
                    header=dst.writes_header, delimiter=dst.csv_delimiter,
                )
        except BaseException as e:  # noqa: BLE001
            errs.append(e)
        times["export"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    # daemon: a failed peer must not pin the process on an orphaned
    # accept/recv (the surviving side times out on its own)
    ti = threading.Thread(target=run_import, name=f"pipegen-import-{qid}",
                          daemon=True)
    te = threading.Thread(target=run_export, name=f"pipegen-export-{qid}",
                          daemon=True)
    ti.start()
    te.start()
    ti.join(timeout)
    te.join(timeout)
    elapsed = time.perf_counter() - t0
    if errs:
        raise errs[0]
    if ti.is_alive() or te.is_alive():
        raise TimeoutError(f"transfer {ds} did not complete within {timeout}s")
    rows = len(dst.get_block(dst_table))
    stats = collect_stats(ds, qid)
    exp_stats = stats.get("export")
    return TransferResult(
        source=src.name, target=dst.name, mode=config.mode, codec=config.codec,
        rows=rows, seconds=elapsed,
        export_seconds=times["export"], import_seconds=times["import"],
        bytes_moved=exp_stats.bytes_sent if exp_stats else 0,
        export_stats=exp_stats, import_stats=stats.get("import"),
    )


def transfer_via_files(
    src: Any,
    table: str,
    dst: Any,
    dst_table: str,
    workers: int = 1,
    tmpdir: Optional[str] = None,
) -> TransferResult:
    """The paper's baseline: export to CSV files on disk, then import them.
    Fully sequential (the importer cannot start until the files exist)."""
    own_tmp = tmpdir is None
    td = tmpdir or tempfile.mkdtemp(prefix="pipegen-fs-")
    base = os.path.join(td, f"{src.name}2{dst.name}.csv")
    t0 = time.perf_counter()
    src.export_csv_parallel(
        table, base, workers=workers,
        header=dst.writes_header, delimiter=dst.csv_delimiter,
    )
    t1 = time.perf_counter()
    # single-worker export writes `base` itself; parallel writes part files
    if workers <= 1:
        if not os.path.exists(base):
            raise FileNotFoundError(base)
        dst.import_csv(dst_table, base)
    else:
        dst.import_csv_parallel(dst_table, base, workers=workers)
    t2 = time.perf_counter()
    bytes_moved = 0
    for fn in os.listdir(td):
        if fn.startswith(os.path.basename(base)):
            bytes_moved += os.path.getsize(os.path.join(td, fn))
    if own_tmp:
        for fn in os.listdir(td):
            os.unlink(os.path.join(td, fn))
        os.rmdir(td)
    rows = len(dst.get_block(dst_table))
    return TransferResult(
        source=src.name, target=dst.name, mode="file-csv", codec="none",
        rows=rows, seconds=t2 - t0,
        export_seconds=t1 - t0, import_seconds=t2 - t1,
        bytes_moved=bytes_moved,
    )
