"""Zero-copy I/O buffer subsystem for the transfer hot path.

The paper's speedup over text rests on *not* re-materializing data on the
way to the wire (fig. 14's preallocated ArrowBufs).  This module provides
the three pieces the encode->frame->send path needs to hit that standard:

* :class:`BufferPool` -- size-classed pools of reusable ``bytearray``
  backing stores.  Encoders acquire a :class:`PooledBuf`, fill it, and the
  transport releases it back after the frame is on the wire, so steady-state
  block traffic allocates nothing.
* :class:`SegmentList` -- the scatter-gather unit: an ordered sequence of
  buffer views (``bytes``/``memoryview``/numpy buffers) that is sent with
  one vectored ``sendmsg`` instead of being concatenated.  It tracks which
  segments are pool-owned so they can be recycled exactly once, and counts
  the copies the view-based path avoided.
* :class:`BufWriter` -- an append-only writer over a pooled buffer for the
  row-major formats (``binary_rows``, ``tagged``, ``parts_rows``) whose
  output is inherently built piecewise; it replaces the per-block
  ``b"".join(out)`` allocate-and-copy with reuse of one pooled store.

Pool size classes are powers of two between ``MIN_CLASS`` and
``MAX_CLASS``; requests above the largest class fall through to plain
allocation (counted as misses) so pathological blocks cannot pin huge
buffers forever.
"""

from __future__ import annotations

import threading
import weakref
from dataclasses import dataclass
from typing import Iterator, List, Optional, Sequence, Union

import numpy as np

__all__ = [
    "BufferPool",
    "BufWriter",
    "DecodeArena",
    "PoolStats",
    "PooledBuf",
    "SegmentList",
    "default_pool",
    "default_decode_pool",
]

Buffer = Union[bytes, bytearray, memoryview]

MIN_CLASS = 1 << 10   # 1 KiB: below this, allocation is cheaper than pooling
MAX_CLASS = 1 << 24   # 16 MiB: largest buffer the pool will retain
MAX_PER_CLASS = 8     # retained buffers per size class (double-buffering x4)


@dataclass
class PoolStats:
    hits: int = 0             # acquires served from a retained buffer
    misses: int = 0           # acquires that had to allocate
    releases: int = 0
    bytes_served: int = 0     # requested bytes across all acquires
    bytes_retained: int = 0   # currently parked in the pool

    def snapshot(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "releases": self.releases,
            "bytes_served": self.bytes_served,
            "bytes_retained": self.bytes_retained,
        }


class PooledBuf:
    """A leased backing store: a ``bytearray`` of one size class, of which
    the first ``nbytes`` are meaningful for the current lease."""

    __slots__ = ("store", "nbytes", "was_hit", "_pool")

    def __init__(self, store: bytearray, nbytes: int, pool: Optional["BufferPool"],
                 was_hit: bool = False):
        self.store = store
        self.nbytes = nbytes
        self.was_hit = was_hit  # served from a retained store (for attribution)
        self._pool = pool

    def view(self, n: Optional[int] = None) -> memoryview:
        """Writable view of the first ``n`` (default: leased) bytes."""
        return memoryview(self.store)[: self.nbytes if n is None else n]

    def release(self) -> None:
        if self._pool is not None:
            pool, self._pool = self._pool, None
            pool._release(self)


class BufferPool:
    """Thread-safe size-classed pool of reusable bytearrays (fig. 14's
    preallocated ArrowBufs, generalized to every wire format)."""

    def __init__(self, max_per_class: int = MAX_PER_CLASS):
        self.max_per_class = max_per_class
        self.stats = PoolStats()
        self._lock = threading.Lock()
        self._classes: dict = {}  # class size -> list[bytearray]

    @staticmethod
    def _class_for(nbytes: int) -> Optional[int]:
        if nbytes > MAX_CLASS:
            return None
        c = MIN_CLASS
        while c < nbytes:
            c <<= 1
        return c

    def acquire(self, nbytes: int) -> PooledBuf:
        """Lease a buffer with at least ``nbytes`` of room."""
        cls = self._class_for(max(nbytes, 1))
        with self._lock:
            self.stats.bytes_served += nbytes
            free = self._classes.get(cls)
            if cls is not None and free:
                store = free.pop()
                self.stats.hits += 1
                self.stats.bytes_retained -= len(store)
                return PooledBuf(store, nbytes, self, was_hit=True)
            self.stats.misses += 1
        return PooledBuf(bytearray(cls or nbytes), nbytes, self)

    def _release(self, buf: PooledBuf) -> None:
        store = buf.store
        cls = len(store)
        if cls < MIN_CLASS or cls > MAX_CLASS or cls & (cls - 1):
            return  # not one of ours (oversize or foreign) -- let GC have it
        with self._lock:
            self.stats.releases += 1
            free = self._classes.setdefault(cls, [])
            if len(free) < self.max_per_class:
                free.append(store)
                self.stats.bytes_retained += cls

    def clear(self) -> None:
        with self._lock:
            self._classes.clear()
            self.stats.bytes_retained = 0


_default_pool: Optional[BufferPool] = None
_default_lock = threading.Lock()


def default_pool() -> BufferPool:
    """Process-wide pool shared by pipes that don't bring their own."""
    global _default_pool
    if _default_pool is None:
        with _default_lock:
            if _default_pool is None:
                _default_pool = BufferPool()
    return _default_pool


class SegmentList:
    """An encoded payload as an ordered list of buffer views.

    This is what :meth:`WireFormat.encode_block` now returns: the transport
    sends the segments with one vectored syscall, then calls
    :meth:`release` to recycle any pool-owned backing stores.  ``join`` is
    the compatibility/copy path (codecs that need contiguous input, tests).
    """

    __slots__ = ("segments", "_pooled", "copies_avoided")

    def __init__(self, segments: Optional[Sequence[Buffer]] = None):
        self.segments: List[Buffer] = list(segments) if segments else []
        self._pooled: List[PooledBuf] = []
        # number of segments that went on the wire as views of live memory
        # (numpy column buffers, pooled stores) instead of fresh copies
        self.copies_avoided = 0

    # -- construction ----------------------------------------------------------
    def append(self, seg: Buffer, zero_copy: bool = False) -> None:
        self.segments.append(seg)
        if zero_copy:
            self.copies_avoided += 1

    def append_pooled(self, buf: PooledBuf) -> None:
        """Append the leased prefix of a pooled buffer; the buffer is
        recycled when this SegmentList is released."""
        self.segments.append(buf.view())
        self._pooled.append(buf)
        self.copies_avoided += 1

    def adopt(self, buf: PooledBuf) -> None:
        """Take ownership of a pooled buffer without appending a segment
        (used when a view of it was already appended piecewise)."""
        self._pooled.append(buf)

    # -- sequence protocol ------------------------------------------------------
    def __iter__(self) -> Iterator[Buffer]:
        return iter(self.segments)

    def __len__(self) -> int:
        return len(self.segments)

    def __getitem__(self, i):
        return self.segments[i]

    @property
    def nbytes(self) -> int:
        return sum(_seg_len(s) for s in self.segments)

    # -- materialization & recycling -------------------------------------------
    def join(self) -> bytes:
        """Contiguous copy of the payload (compat path; defeats zero-copy)."""
        if len(self.segments) == 1:
            return bytes(self.segments[0])
        return b"".join(bytes(s) for s in self.segments)

    def release(self) -> None:
        """Recycle pool-owned stores.  The views in ``segments`` are dead
        after this call; only invoke once the payload is on the wire."""
        pooled, self._pooled = self._pooled, []
        self.segments = []
        for buf in pooled:
            buf.release()


_default_decode_pool: Optional[BufferPool] = None


def default_decode_pool() -> BufferPool:
    """Process-wide pool backing decode arenas (kept separate from the
    encode pool so hit rates attribute cleanly to each side)."""
    global _default_decode_pool
    if _default_decode_pool is None:
        with _default_lock:
            if _default_decode_pool is None:
                _default_decode_pool = BufferPool()
    return _default_decode_pool


class DecodeArena:
    """Decode-side twin of the encode pool: recycled backing stores for the
    numpy columns ``decode_block`` materializes.

    The seed decode path allocated a fresh buffer per column per block
    (``frombuffer(...).copy()`` / ``asarray(list)``); with an arena the
    decoder copies the wire view into a pooled store instead, so a
    *streaming* consumer — one that drops each block as it goes — recycles
    stores and allocates nothing at steady state (the ROADMAP decode-pool
    open item, fig. 14's ArrowBufs mirrored).  A consumer that retains
    every block until a final merge (the engines' bulk import) keeps all
    stores leased and sees little reuse — the safety contract trades reuse
    for zero defensive copies there.

    Safety contract (what the aliasing regression test pins down): a store
    is recycled only when the array carved from it — and every live numpy
    view of it — has been garbage collected (a ``weakref.finalize`` on the
    array; CPython refcounting makes this prompt for streaming consumers).
    Consumers that retain blocks simply keep the stores leased; nothing is
    ever overwritten under a live view.
    """

    __slots__ = ("pool", "hits", "misses", "live", "__weakref__")

    def __init__(self, pool: Optional[BufferPool] = None):
        self.pool = pool or default_decode_pool()
        self.hits = 0       # column allocations served from a retained store
        self.misses = 0
        self.live = 0       # arrays handed out and not yet reclaimed

    def array(self, dtype, n: int) -> np.ndarray:
        """A writable ndarray of ``n`` elements over a pooled store; the
        store returns to the pool when the array (and its views) die."""
        dtype = np.dtype(dtype)
        buf = self.pool.acquire(max(1, n * dtype.itemsize))
        if buf.was_hit:
            self.hits += 1
        else:
            self.misses += 1
        arr: np.ndarray = np.frombuffer(buf.store, dtype, n)
        self.live += 1
        weakref.finalize(arr, self._reclaim, buf)
        return arr

    def take(self, dtype, n: int, source) -> np.ndarray:
        """Arena-backed copy of ``source`` (the in-place wire view): the
        one unavoidable transfer out of transport memory, into a store that
        will be reused instead of reallocated."""
        out = self.array(dtype, n)
        out[:] = source
        return out

    def _reclaim(self, buf: PooledBuf) -> None:
        self.live -= 1
        buf.release()


def _seg_len(s: Buffer) -> int:
    if isinstance(s, memoryview):
        return s.nbytes
    return len(s)


class BufWriter:
    """Append-only writer over a pooled backing store.

    Row-major formats build their payload out of many small pieces; writing
    them straight into one reused store replaces the seed path's
    list-of-bytes + ``b"".join`` (one alloc + full copy per block).
    Grows geometrically through the pool's size classes when the initial
    hint is too small.
    """

    __slots__ = ("_pool", "_buf", "_len")

    def __init__(self, pool: Optional[BufferPool] = None, size_hint: int = MIN_CLASS):
        self._pool = pool or default_pool()
        self._buf = self._pool.acquire(size_hint)
        self._len = 0

    def write(self, data: Buffer) -> None:
        n = _seg_len(data)
        need = self._len + n
        store = self._buf.store
        if need > len(store):
            grown = self._pool.acquire(max(need, len(store) * 2))
            grown.store[: self._len] = store[: self._len]
            self._buf.release()
            self._buf = grown
            store = grown.store
        store[self._len : need] = data
        self._len = need

    def pack_into(self, st, *vals) -> None:
        """``struct.Struct.pack_into`` directly into the store (no temp)."""
        need = self._len + st.size
        if need > len(self._buf.store):
            self.write(b"\x00" * st.size)  # grow, then overwrite in place
            self._len = need - st.size
        st.pack_into(self._buf.store, self._len, *vals)
        self._len = need

    def __len__(self) -> int:
        return self._len

    def detach(self) -> SegmentList:
        """Finish: one pooled segment holding everything written."""
        self._buf.nbytes = self._len
        out = SegmentList()
        out.append_pooled(self._buf)
        self._buf = None  # type: ignore[assignment]
        return out
