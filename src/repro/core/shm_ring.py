"""Cross-process shared-memory ring transport (ROADMAP: the zero-copy
SegmentList path across process boundaries).

``ChannelTransport`` is the in-process queue analog of a colocated pipe; it
still pays one materialization per frame because the consuming thread may
run after the producer's pooled buffers are recycled.  ``ShmRing`` removes
that copy *and* the process boundary: a fixed-capacity byte ring mapped
through ``multiprocessing.shared_memory`` with a small header region
holding the monotonic head/tail cursors and the peer liveness fields.

Zero-copy contract:

* the writer **reserves a contiguous span** inside the mapped region
  (:meth:`ShmRing.begin_frame`), the transport gathers the encoded
  ``SegmentList`` views straight into it, and :meth:`ShmRing.commit_frame`
  publishes the frame — no intermediate ``bytes`` is ever built;
* the reader hands out a **``memoryview`` slice of the mapped region**
  (:meth:`ShmRing.recv`) that ``decode_block`` consumes in place; the span
  is recycled (:meth:`ShmRing.consume`) only after the next frame is
  requested, by which point the decoder has copied the values out into
  arena-backed columns.

Layout **v2** (offsets in bytes)::

    0   u32  magic 'PGR1'
    4   u32  version (2)
    8   u64  capacity of the data region
    16  u64  head  (monotonic bytes written, wrap padding included)
    24  u64  tail  (monotonic bytes consumed; SPSC reader cursor)
    32  u32  writer pid (0 = not yet attached)
    36  u32  reader pid (SPSC)
    40  u32  writer closed flag
    44  u32  reader closed flag (SPSC)
    48  u32  doorbell kind (0 = poll fallback, 1 = fifo/eventfd pair)
    52  u32  reader waiting flag (SPSC)
    56  u32  writer waiting flag
    60  u32  reader slot count (0 = SPSC, R = broadcast)
    64  u32  lease epoch (bumped by reset(); keys the seqlock tokens)
    68..96   reserved
    96..     broadcast only: R reader-cursor slots of 32 bytes each
             (+0 u64 tail, +8 u32 pid, +12 u32 state, +16 u32 waiting,
              +20 f64 reserved-claim deadline)
    ...      data region (capacity bytes)

Frame records never wrap and carry a **per-frame seqlock word**::

    commit u32 | kind u8 | length u32 | payload

The writer stamps ``commit = 0`` when it reserves the span, fills kind/
length/payload, and only then stores the commit token — a value derived
from the frame's monotonic byte offset *and the ring's lease epoch*
(never 0).  The reader polls *the commit word at its own cursor*, not
the shared head, and validates token + length again after reading the
frame header, so a frame is only ever parsed after its publication is
complete: the head-before-payload reordering the v1 docstring had to
caveat for weakly-ordered ISAs can no longer desync the reader (a torn
publication reads as "not ready" or fails loudly, never as a bogus
frame).  The epoch key closes the pooled-reuse hole: ``reset()`` rewinds
the monotonic cursors, which would make the previous lease's stale
commit words token-valid at the same offsets again — bumping the epoch
makes every stale word a guaranteed mismatch, so even a maximally
reordered view degrades to "not ready", never to a stale payload.  When
the run to the region end is too short the writer stamps the *wrap
token* (the same keyed token space, wrap bit set) and both sides skip to
the region start.

**Doorbell.**  Blocked sides no longer rely on exponential-backoff polling
(which capped idle wakeup latency at 2 ms): each direction gets a real
doorbell — a per-ring named pipe created next to the segment (the fifo
path derives from the segment name, which travels through the
``WorkerDirectory``/``DirectoryServer`` rendezvous) plus, for same-process
peers, an ``os.eventfd`` shared via a process-local registry.  A waiter
publishes its *waiting flag* in the header, re-checks readiness, and parks
in ``select`` on the doorbell fds; the peer rings (one write syscall) only
when the flag is set, so the streaming hot path pays a single u32 load per
frame.  Wakeup is microseconds instead of up to ``_SLEEP_MAX``.  Where
``os.eventfd``/fifos are unavailable (non-Linux) the v1 backoff poll
remains as the fallback, selected per ring at creation.  Per-instance
wakeup counters (``spin``/``doorbell``/``poll``) feed
``PipeStats.doorbell_waits``/``spin_wakeups``/``poll_sleeps``.

**Broadcast variant** (``nreaders > 0``): one writer, R reader cursor
slots.  Every reader consumes every frame at its own pace; a span recycles
only when the *minimum* of the live reader tails passes it, so one export
(one encode) feeds R colocated importers from one segment.  Readers claim
pre-reserved slots by index (handed out by the directory's broadcast
rendezvous), a slot whose process dies is **evicted by pid-probe** so a
SIGKILLed reader cannot wedge the writer, and a closed slot stops gating
recycling.  Broadcast rings are never pooled.

The reader side *creates* (and ultimately unlinks) the segment — it is the
rendezvous registrant, mirroring the socket path where the importer
listens.  On Python < 3.13 the attaching process must be unregistered from
the ``resource_tracker`` or its exit would unlink the segment under the
still running reader (bpo-39959); :meth:`ShmRing.attach` handles that.
"""

from __future__ import annotations

import atexit
import errno
import glob
import os
import secrets
import select
import struct
import tempfile
import threading
import time
from multiprocessing import resource_tracker, shared_memory
from typing import Dict, Iterable, List, Optional, Tuple

from . import faults
from . import telemetry
from .iobuf import Buffer, _seg_len
from .transport import FRAME_EOF, LinkSim, Transport

__all__ = ["ShmRing", "ShmRingTransport", "DEFAULT_RING_CAPACITY",
           "acquire_ring", "acquire_broadcast_ring", "attach_ring",
           "doorbell_supported", "sweep_orphans", "set_doorbell_hub",
           "get_doorbell_hub", "set_pool_limits", "pool_info",
           "drain_pools"]

_MAGIC = 0x50475231  # 'PGR1'
_VERSION = 2
_HDR = struct.Struct("<IIQ")      # magic, version, capacity
_U64 = struct.Struct("<Q")
_U32 = struct.Struct("<I")
_FRAME = struct.Struct("<IcI")    # commit word, kind, payload length
_KL = struct.Struct("<cI")        # kind + length (at frame offset +4)

HEADER_SIZE = 96
_OFF_CAPACITY = 8
_OFF_HEAD = 16
_OFF_TAIL = 24
_OFF_WRITER_PID = 32
_OFF_READER_PID = 36
_OFF_WRITER_CLOSED = 40
_OFF_READER_CLOSED = 44
_OFF_DOORBELL = 48
_OFF_READER_WAIT = 52
_OFF_WRITER_WAIT = 56
_OFF_NREADERS = 60
_OFF_EPOCH = 64

# tail, pid, state, waiting, reserved-claim deadline (+pad) = 32 B
_SLOT = struct.Struct("<QIIId4x")
_SLOT_OFF_DEADLINE = 20
_F64 = struct.Struct("<d")
_SLOT_STATE_RESERVED = 0  # pre-created by the ring owner, not yet claimed
_SLOT_STATE_ATTACHED = 1
_SLOT_STATE_CLOSED = 2
_SLOT_STATE_EVICTED = 3   # pid-probe / claim-deadline found it dead

#: how long a pre-reserved broadcast slot may stay unclaimed before the
#: writer evicts it — an importer that died between the directory join
#: and the ring attach must not wedge the group (legitimate attaches
#: happen within milliseconds of the join)
_RESERVED_GRACE = 15.0

# seqlock publication tokens: derived from the frame's monotonic byte
# offset, the ring's lease epoch, and a wrap bit — never 0 (unpublished),
# and never valid across a pooled reset() (the epoch bump guarantees a
# stale word mismatches even at the same offset)
_TOKEN_MOD = 0xFFFFFFFD
_M64 = (1 << 64) - 1


def _token(mono: int, epoch: int = 0, wrap: bool = False) -> int:
    v = (mono << 1) | (1 if wrap else 0)
    if epoch:
        v ^= (epoch * 0x9E3779B1) & _M64
    return (v % _TOKEN_MOD) + 1


#: the *logical* frame header charged to bytes_sent/LinkSim — kept at the
#: socket/channel transports' 5 bytes so PipeStats stay comparable; the
#: 4-byte seqlock word is physical ring overhead, not wire accounting
_WIRE_HEADER = 5

DEFAULT_RING_CAPACITY = 1 << 25   # 32 MiB: several default-size blocks deep

_SPIN = 200                       # polls before any sleeping at all
_SLEEP_MIN = 1e-6
# Poll-fallback backoff (doorbell-less platforms): restarts on every wait,
# so a *streaming* peer wakes within microseconds of the cursor moving;
# only a genuinely idle wait escalates to the cap.
_SLEEP_MAX = 2e-3
_LIVENESS_EVERY = 64              # peer pid probes, once per N sleeps
_PARK_AFTER = 256e-6              # micro-backoff budget before parking on
                                  # the doorbell: streaming gaps (peer
                                  # mid-encode) resolve in here without a
                                  # single doorbell syscall; only a
                                  # demonstrably idle wait pays the park
_DB_SLICE_MIN = 2e-3              # first doorbell select slice: escalates
                                  # per wait, so a doorbell that cannot be
                                  # rung (fifo path mismatch across mount
                                  # namespaces, raced unlink) degrades to
                                  # poll-cap behaviour, not 50 ms stalls
_DB_SLICE = 0.05                  # slice cap (liveness-probe cadence, and
                                  # the self-heal bound for the rare
                                  # cross-process lost-wakeup window)

#: platform gate for the doorbell machinery; tests monkeypatch this to
#: exercise the poll fallback on doorbell-capable hosts.  The wait path
#: uses ``select.poll`` — ``select.select`` is FD_SETSIZE-bound and
#: raises ValueError for any fd >= 1024, which broker-scale fan-out
#: (hundreds of rings x 2+ fds each) reaches routinely.
_DOORBELL_OK = (hasattr(os, "eventfd") and hasattr(os, "mkfifo")
                and hasattr(select, "poll"))

_DB_NONE = 0
_DB_FDS = 1


def doorbell_supported() -> bool:
    return _DOORBELL_OK


# segment names created by THIS process: an in-process attach (exporter and
# importer threads of one transfer) must not unregister the creator's
# resource-tracker entry, or the eventual unlink double-unregisters
_created_here: set = set()


def _pid_alive(pid: int) -> bool:
    if pid <= 0:
        return True  # peer not attached yet: nothing to pronounce dead
    try:
        # /proc beats os.kill(pid, 0): a SIGKILLed child is a zombie until
        # reaped, and a zombie still answers signal probes
        with open(f"/proc/{pid}/stat", "rb") as f:
            stat = f.read()
        after_comm = stat[stat.rfind(b")") + 2:]
        return not after_comm.startswith(b"Z")
    except FileNotFoundError:
        return False
    except OSError:  # pragma: no cover - no procfs: fall back to a probe
        pass
    try:  # pragma: no cover
        os.kill(pid, 0)
    except ProcessLookupError:  # pragma: no cover
        return False
    except PermissionError:  # pragma: no cover - exists but not ours
        return True
    except OSError as e:  # pragma: no cover - exotic platforms
        return e.errno != errno.ESRCH
    return True  # pragma: no cover


# -- doorbells ----------------------------------------------------------------------
#
# One named pipe per direction (to-writer: ".w"; to-reader slot i:
# ".r<i>"), created by the segment creator; the path derives from the
# segment name so it rides the same directory rendezvous.  Same-process
# peers additionally share an os.eventfd through a refcounted registry —
# the waiter selects on both fds, the ringer rings both, so mixed
# in-process/cross-process peerings always wake.

_DB_BYTE = b"\x01"
_ev_lock = threading.Lock()
_ev_reg: Dict[str, List[int]] = {}  # fifo path -> [eventfd, refcount]

#: process-wide doorbell hub (installed by ``repro.core.broker``): when
#: set, every doorbell wait parks on a ``threading.Event`` and ONE
#: selector thread multiplexes all doorbell fds, instead of each waiter
#: running its own poll syscall loop.  Duck-typed: anything with
#: ``wait(doorbell, timeout) -> bool`` and ``discard(doorbell)`` works.
_HUB = None


def set_doorbell_hub(hub) -> None:
    """Install (or, with ``None``, remove) the process-wide doorbell hub."""
    global _HUB
    _HUB = hub


def get_doorbell_hub():
    return _HUB


def _db_path(name: str, suffix: str) -> str:
    return os.path.join(tempfile.gettempdir(), f"{name}.pgdb-{suffix}")


def _evfd_acquire(path: str, create: bool) -> Optional[int]:
    if not hasattr(os, "eventfd"):  # pragma: no cover - linux-only API
        return None
    with _ev_lock:
        ent = _ev_reg.get(path)
        if ent is None:
            if not create:
                return None  # creator is another process: fifo carries it
            try:
                fd = os.eventfd(0, os.EFD_NONBLOCK)
            except OSError:  # pragma: no cover - fd exhaustion
                return None
            ent = _ev_reg[path] = [fd, 0]
        ent[1] += 1
        return ent[0]


def _evfd_release(path: str) -> None:
    with _ev_lock:
        ent = _ev_reg.get(path)
        if ent is None:  # pragma: no cover - double release
            return
        ent[1] -= 1
        if ent[1] <= 0:
            del _ev_reg[path]
            try:
                os.close(ent[0])
            except OSError:  # pragma: no cover
                pass


class _Doorbell:
    """One wakeup channel: a named-pipe fd plus (same-process) an eventfd."""

    __slots__ = ("path", "fd", "evfd", "hub_event")

    def __init__(self, path: str, create_event: bool):
        self.path = path
        self.fd = os.open(path, os.O_RDWR | os.O_NONBLOCK)
        self.evfd = _evfd_acquire(path, create=create_event)
        self.hub_event = None  # set by the hub on first hub-mediated wait

    def ring(self) -> None:
        if faults._ACTIVE is not None:
            if faults.fire("shm.doorbell.ring") == "drop":
                return  # injected lost wakeup: waiter relies on slice cap
        try:
            os.write(self.fd, _DB_BYTE)
        except OSError:
            pass  # pipe full: wakeups already pending
        if self.evfd is not None:
            try:
                os.eventfd_write(self.evfd, 1)
            except OSError:  # pragma: no cover - counter saturated
                pass

    def drain(self, fd: int) -> None:
        try:
            if fd == self.evfd:
                os.eventfd_read(fd)
            else:
                os.read(fd, 64)
        except OSError:
            pass

    def wait(self, timeout: float) -> bool:
        hub = _HUB
        if hub is not None:
            try:
                return hub.wait(self, timeout)
            except Exception:
                pass  # hub mid-shutdown: fall through to the local poll
        # select.poll, NOT select.select: select() encodes fds in a
        # fixed FD_SETSIZE bitmap and raises ValueError for fd >= 1024,
        # so any process holding >~1000 fds (broker fan-out) crashed in
        # the old wait.  poll() takes the fd list by value, no ceiling.
        poller = select.poll()
        try:
            poller.register(self.fd, select.POLLIN)
            if self.evfd is not None:
                poller.register(self.evfd, select.POLLIN)
            ready = poller.poll(max(0.0, timeout) * 1000.0)
        except (OSError, ValueError):  # pragma: no cover - fd raced a close
            return False
        for fd, _ in ready:
            self.drain(fd)
        return bool(ready)

    def close(self) -> None:
        hub = _HUB
        if hub is not None:
            try:
                hub.discard(self)  # unregister while the fds are open
            except Exception:  # pragma: no cover - hub mid-shutdown
                pass
        try:
            os.close(self.fd)
        except OSError:  # pragma: no cover
            pass
        if self.evfd is not None:
            _evfd_release(self.path)
            self.evfd = None


def _make_fifos(name: str, readers: int) -> bool:
    """Create the per-ring doorbell fifos (one to-writer, one per reader
    slot).  Returns False — poll fallback — when the platform refuses."""
    paths = [_db_path(name, "w")] + [
        _db_path(name, f"r{i}") for i in range(max(1, readers))]
    made = []
    try:
        for p in paths:
            try:
                os.unlink(p)
            except FileNotFoundError:
                pass
            os.mkfifo(p)
            made.append(p)
        return True
    except OSError:  # pragma: no cover - exotic tmpdir
        for p in made:
            try:
                os.unlink(p)
            except OSError:
                pass
        return False


def _remove_fifos(name: str) -> None:
    for p in glob.glob(_db_path(name, "*")):
        try:
            os.unlink(p)
        except OSError:  # pragma: no cover
            pass


class ShmRing:
    """Frame ring over one shared-memory segment.

    Single-producer/single-consumer by default; with ``nreaders > 0`` the
    broadcast variant (one writer, R reader cursor slots — see module
    docstring).  The creator (reader side by default) owns the segment
    name and unlinks it on close; the attacher only closes its mapping.
    """

    def __init__(self, shm: shared_memory.SharedMemory, owner: bool,
                 capacity: int, nreaders: int = 0, slot: int = -1):
        self.shm = shm
        self.owner = owner
        self.capacity = capacity
        self.nreaders = nreaders
        self.slot = slot  # this instance's broadcast reader slot (-1: n/a)
        self._buf: memoryview = shm.buf
        data_off = HEADER_SIZE + _SLOT.size * nreaders
        self._data: memoryview = self._buf[data_off:data_off + capacity]
        self.closed = False
        self._reserved: Optional[Tuple[int, int]] = None  # (pos, need)
        self._pending_consume = 0
        # per-instance wait attribution (each side attaches its own
        # instance, so these split cleanly into reader/writer stats)
        self.wakeups = {"spin": 0, "doorbell": 0, "poll": 0}
        self.readers_evicted = 0
        self.aborted: Optional[str] = None  # set by abort(); waits raise it
        self._dbs: Dict[str, Optional[_Doorbell]] = {}
        self._epoch = self._u32(_OFF_EPOCH)  # refreshed by claim()/reset()

    # -- construction ------------------------------------------------------------
    @classmethod
    def create(cls, capacity: int = DEFAULT_RING_CAPACITY,
               name: Optional[str] = None, role: str = "reader",
               doorbell: bool = True, readers: int = 0) -> "ShmRing":
        """Create a segment.  ``readers > 0`` makes it a broadcast ring
        with that many pre-reserved cursor slots (the creator claims slot
        0 when ``role == 'reader'``)."""
        name = name or f"pgring-{secrets.token_hex(6)}"
        size = HEADER_SIZE + _SLOT.size * readers + capacity
        shm = shared_memory.SharedMemory(name=name, create=True, size=size)
        _created_here.add(shm.name)
        _HDR.pack_into(shm.buf, 0, _MAGIC, _VERSION, capacity)
        _U32.pack_into(shm.buf, _OFF_NREADERS, readers)
        kind = _DB_NONE
        if doorbell and _DOORBELL_OK and _make_fifos(name, readers):
            kind = _DB_FDS
        _U32.pack_into(shm.buf, _OFF_DOORBELL, kind)
        claim_by = time.monotonic() + _RESERVED_GRACE
        for i in range(readers):
            _SLOT.pack_into(shm.buf, HEADER_SIZE + _SLOT.size * i,
                            0, 0, _SLOT_STATE_RESERVED, 0, claim_by)
        slot = 0 if (readers and role == "reader") else -1
        ring = cls(shm, owner=True, capacity=capacity, nreaders=readers,
                   slot=slot)
        ring.claim(role)
        return ring

    @classmethod
    def attach(cls, name: str, role: str = "writer",
               slot: int = -1) -> "ShmRing":
        """Attach to an existing segment.  Broadcast readers must pass the
        ``slot`` index the directory handed them."""
        shm = shared_memory.SharedMemory(name=name, create=False)
        # Python < 3.13 registers even plain attaches with the resource
        # tracker, whose cleanup at *this* process's exit would unlink the
        # segment under the still-running creator (bpo-39959).  Skip when
        # this process is the creator: the entry belongs to the unlink.
        if shm.name not in _created_here:
            try:
                resource_tracker.unregister(shm._name, "shared_memory")
            except Exception:  # pragma: no cover - tracker API drift
                pass
        magic, version, capacity = _HDR.unpack_from(shm.buf, 0)
        if magic != _MAGIC or version != _VERSION:
            shm.close()
            raise IOError(f"{name!r} is not a PipeGen v{_VERSION} ring "
                          f"segment")
        nreaders = _U32.unpack_from(shm.buf, _OFF_NREADERS)[0]
        if nreaders and role == "reader" and not 0 <= slot < nreaders:
            shm.close()
            raise ValueError(
                f"broadcast ring {name!r} has {nreaders} reader slots; "
                f"got slot={slot}")
        ring = cls(shm, owner=False, capacity=capacity, nreaders=nreaders,
                   slot=slot if (nreaders and role == "reader") else -1)
        try:
            ring.claim(role)
        except BaseException:  # e.g. the slot was evicted: unmap cleanly
            ring.close()
            raise
        return ring

    def claim(self, role: Optional[str]) -> None:
        """Record this process as the ring's reader or writer (for the
        peer's liveness probe).  Claiming re-opens that side: a pooled ring
        may carry the previous lease's closed flag.  Broadcast readers
        claim their cursor slot instead of the SPSC header fields."""
        self._epoch = self._u32(_OFF_EPOCH)
        # a claim starts a lease: wait attribution belongs to it alone
        # (a pooled/cached instance must not leak the previous transfer's
        # counters into the next one's PipeStats)
        self.wakeups = {"spin": 0, "doorbell": 0, "poll": 0}
        self.readers_evicted = 0
        self.aborted = None
        if role == "reader":
            if self.nreaders:
                off = self._slot_off(self.slot)
                if self._u32(off + 12) == _SLOT_STATE_EVICTED:
                    raise IOError(
                        f"broadcast slot {self.slot} of {self.name!r} was "
                        f"evicted (this reader arrived after the claim "
                        f"grace expired; frames are already recycled)")
                _U32.pack_into(self._buf, off + 8, os.getpid())
                _U32.pack_into(self._buf, off + 12, _SLOT_STATE_ATTACHED)
                # re-verify: the writer's grace eviction may have raced
                # our store (check-then-act on its side); losing that
                # race must be loud here, not a silent partial import
                if self._u32(off + 12) == _SLOT_STATE_EVICTED:
                    raise IOError(
                        f"broadcast slot {self.slot} of {self.name!r} was "
                        f"evicted while attaching (claim grace expired)")
            else:
                _U32.pack_into(self._buf, _OFF_READER_PID, os.getpid())
                _U32.pack_into(self._buf, _OFF_READER_CLOSED, 0)
        elif role == "writer":
            _U32.pack_into(self._buf, _OFF_WRITER_PID, os.getpid())
            _U32.pack_into(self._buf, _OFF_WRITER_CLOSED, 0)

    def reset(self) -> None:
        """Rewind a (drained) ring for a fresh lease: cursors to zero,
        no peers, no closed flags, no waiting flags, broadcast slots back
        to freshly-reserved — and a fresh lease epoch, so the previous
        lease's commit words (which would be token-valid again at the
        rewound offsets) can never re-validate.  Owner-side only, between
        pooled reuses."""
        self._set_u64(_OFF_HEAD, 0)
        self._set_u64(_OFF_TAIL, 0)
        for off in (_OFF_WRITER_PID, _OFF_READER_PID,
                    _OFF_WRITER_CLOSED, _OFF_READER_CLOSED,
                    _OFF_READER_WAIT, _OFF_WRITER_WAIT):
            _U32.pack_into(self._buf, off, 0)
        claim_by = time.monotonic() + _RESERVED_GRACE
        for i in range(self.nreaders):
            _SLOT.pack_into(self._buf, self._slot_off(i),
                            0, 0, _SLOT_STATE_RESERVED, 0, claim_by)
        self._epoch = (self._epoch + 1) & 0xFFFFFFFF
        _U32.pack_into(self._buf, _OFF_EPOCH, self._epoch)
        self._reserved = None
        self._pending_consume = 0

    @property
    def name(self) -> str:
        return self.shm.name

    # -- header accessors --------------------------------------------------------
    def _u64(self, off: int) -> int:
        return _U64.unpack_from(self._buf, off)[0]

    def _set_u64(self, off: int, v: int) -> None:
        _U64.pack_into(self._buf, off, v)

    def _u32(self, off: int) -> int:
        return _U32.unpack_from(self._buf, off)[0]

    def _slot_off(self, i: int) -> int:
        return HEADER_SIZE + _SLOT.size * i

    def _tail_get(self) -> int:
        if self.nreaders and self.slot >= 0:
            return self._u64(self._slot_off(self.slot))
        return self._u64(_OFF_TAIL)

    def _min_tail(self) -> int:
        """Broadcast: the laggiest cursor still gating recycling (reserved
        slots count — their reader has not attached yet and must not miss
        frames; closed/evicted slots do not)."""
        head = self._u64(_OFF_HEAD)
        lo = None
        for i in range(self.nreaders):
            off = self._slot_off(i)
            state = self._u32(off + 12)
            if state in (_SLOT_STATE_RESERVED, _SLOT_STATE_ATTACHED):
                t = self._u64(off)
                lo = t if lo is None or t < lo else lo
        return head if lo is None else lo

    @property
    def writer_closed(self) -> bool:
        return bool(self._u32(_OFF_WRITER_CLOSED))

    @property
    def reader_closed(self) -> bool:
        return bool(self._u32(_OFF_READER_CLOSED))

    def reader_alive(self) -> bool:
        if self.nreaders:
            return self._readers_ok()
        return not self.reader_closed and _pid_alive(self._u32(_OFF_READER_PID))

    def writer_alive(self) -> bool:
        return not self.writer_closed and _pid_alive(self._u32(_OFF_WRITER_PID))

    def _readers_ok(self) -> bool:
        """Broadcast liveness: evict attached slots whose process died
        (pid-probe) and reserved slots whose reader never arrived within
        the claim grace (an importer that failed between the directory
        join and the ring attach must not wedge the group), then report
        whether anyone still wants data."""
        ok = False
        for i in range(self.nreaders):
            off = self._slot_off(i)
            state = self._u32(off + 12)
            if state == _SLOT_STATE_ATTACHED:
                if not _pid_alive(self._u32(off + 8)):
                    _U32.pack_into(self._buf, off + 12, _SLOT_STATE_EVICTED)
                    _U32.pack_into(self._buf, off + 16, 0)
                    self.readers_evicted += 1
                    continue
                ok = True
            elif state == _SLOT_STATE_RESERVED:
                deadline = _F64.unpack_from(
                    self._buf, off + _SLOT_OFF_DEADLINE)[0]
                if deadline and time.monotonic() > deadline:
                    _U32.pack_into(self._buf, off + 12, _SLOT_STATE_EVICTED)
                    self.readers_evicted += 1
                    continue
                ok = True  # not yet attached: still owed every frame
        return ok

    def used(self) -> int:
        if self.nreaders and self.slot < 0:  # broadcast writer view
            return self._u64(_OFF_HEAD) - self._min_tail()
        return self._u64(_OFF_HEAD) - self._tail_get()

    # -- doorbells ---------------------------------------------------------------
    def _doorbell(self, suffix: str) -> Optional[_Doorbell]:
        if faults._ACTIVE is not None:
            if faults.fire("shm.doorbell.open", suffix=suffix) == "break":
                return None  # un-ringable doorbell: degrade to polling
        if self._u32(_OFF_DOORBELL) != _DB_FDS:
            return None
        db = self._dbs.get(suffix, False)
        if db is False:
            try:
                db = _Doorbell(_db_path(self.name, suffix),
                               create_event=self.owner)
            except OSError:
                db = None  # fifo vanished (peer cleanup raced): poll
            self._dbs[suffix] = db
        return db

    def _my_wait_channel(self, side: str) -> Tuple[Optional[_Doorbell], int]:
        """(doorbell this side parks on, waiting-flag offset)."""
        if side == "writer":
            return self._doorbell("w"), _OFF_WRITER_WAIT
        if self.nreaders:
            off = self._slot_off(self.slot) + 16
            return self._doorbell(f"r{self.slot}"), off
        return self._doorbell("r0"), _OFF_READER_WAIT

    def _ring_readers(self) -> None:
        """Writer side: wake every reader that published a waiting flag."""
        if self.nreaders:
            for i in range(self.nreaders):
                if self._u32(self._slot_off(i) + 16):
                    db = self._doorbell(f"r{i}")
                    if db is not None:
                        db.ring()
        elif self._u32(_OFF_READER_WAIT):
            db = self._doorbell("r0")
            if db is not None:
                db.ring()

    def _ring_writer(self) -> None:
        if self._u32(_OFF_WRITER_WAIT):
            db = self._doorbell("w")
            if db is not None:
                db.ring()

    def abort(self, reason: str) -> None:
        """Fail this instance's blocked waits from another thread: every
        parked or polling ``_wait`` raises ``BrokenPipeError(reason)``.
        Used by the lease renewer when the directory registration was
        GC'd — the transfer can never rendezvous, so an importer parked
        in ``recv(timeout=None)`` must not wait forever."""
        self.aborted = reason
        telemetry.counter("shm.ring_aborts").inc()
        telemetry.fault_recorder.note("shm.ring_abort", name=self.name,
                                      reason=reason)
        if self.closed:
            return  # nothing is parked on a closed ring
        try:
            self._ring_readers()
            self._ring_writer()
        except (OSError, ValueError):  # doorbells/mapping raced a close
            pass

    def release_doorbells(self) -> None:
        """Close this instance's doorbell fds without closing the ring.
        Parked/cached warm segments must not hold fds (idle fd usage has
        to stay flat in pool size); the next lease reopens them lazily
        via :meth:`_doorbell` — the fifo paths outlive the fds."""
        dbs, self._dbs = self._dbs, {}
        for db in dbs.values():
            if db is not None:
                db.close()

    # -- waiting -----------------------------------------------------------------
    def _wait(self, ready, peer_ok, timeout: Optional[float], what: str,
              side: str):
        """Spin briefly, then park on this side's doorbell (waiting flag
        published first, so the peer's post-publish flag check cannot miss
        us).  Doorbell-less rings fall back to the v1 exponential-backoff
        poll.  Returns the truthy ``ready()`` value; raises
        BrokenPipeError/TimeoutError."""
        r = ready()
        if r:
            return r
        deadline = None if timeout is None else time.monotonic() + timeout
        for _ in range(_SPIN):
            r = ready()
            if r:
                self.wakeups["spin"] += 1
                return r
        db, flag_off = self._my_wait_channel(side)
        if db is not None:
            # brief escalating micro-sleeps before the park: a bursting
            # peer catches up within microseconds (the GIL hand-off the
            # spin alone cannot give), and the waiting flag stays clear,
            # so the streaming hot path never pays a doorbell syscall
            sleep = _SLEEP_MIN
            t_micro = time.monotonic()  # wall budget: a nominal 1 µs
            while time.monotonic() - t_micro < _PARK_AFTER:  # sleep really
                time.sleep(sleep)                            # costs ~60 µs
                r = ready()
                if r:
                    self.wakeups["spin"] += 1
                    return r
                sleep = min(sleep * 2, _PARK_AFTER / 4)
            slice_ = _DB_SLICE_MIN
            try:
                while True:
                    _U32.pack_into(self._buf, flag_off, 1)
                    r = ready()
                    if r:
                        self.wakeups["doorbell"] += 1
                        return r
                    if self.aborted:
                        raise BrokenPipeError(self.aborted)
                    if not peer_ok():
                        raise BrokenPipeError(
                            f"shm ring peer died while {what}")
                    if deadline is not None:
                        remaining = deadline - time.monotonic()
                        if remaining <= 0:
                            raise TimeoutError(f"shm ring timed out {what}")
                        db.wait(min(slice_, remaining))
                    else:
                        db.wait(slice_)
                    slice_ = min(slice_ * 2, _DB_SLICE)
            finally:
                _U32.pack_into(self._buf, flag_off, 0)
        sleep = _SLEEP_MIN
        sleeps = 0
        while True:
            r = ready()
            if r:
                return r
            if self.aborted:
                raise BrokenPipeError(self.aborted)
            if sleeps % _LIVENESS_EVERY == 0 and not peer_ok():
                raise BrokenPipeError(f"shm ring peer died while {what}")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(f"shm ring timed out {what}")
            time.sleep(sleep)
            self.wakeups["poll"] += 1
            sleep = min(sleep * 2, _SLEEP_MAX)
            sleeps += 1

    # -- writer side ---------------------------------------------------------------
    def _free_tail(self) -> int:
        return self._min_tail() if self.nreaders else self._u64(_OFF_TAIL)

    def begin_frame(self, kind: bytes, nbytes: int,
                    timeout: Optional[float] = None) -> memoryview:
        """Reserve a contiguous span, stamp the frame header into it
        (commit word cleared), and return the writable payload view.
        Blocks (doorbell wait) while the ring is full; fails fast when the
        reader dies — broadcast writers evict dead readers instead."""
        if self.closed:
            raise ValueError("write on closed ring")
        if self._reserved is not None:
            raise RuntimeError("begin_frame while a frame is already open")
        need = _FRAME.size + nbytes
        if need > self.capacity:
            raise IOError(
                f"frame of {nbytes} bytes exceeds ring capacity "
                f"{self.capacity}; raise shm_capacity or lower block_rows"
            )
        cap = self.capacity

        def _free_at_least(n):
            return lambda: cap - (self._u64(_OFF_HEAD) - self._free_tail()) >= n

        # phase 1: if the contiguous run at head is too short, wait until
        # the dead run fits in the free space, stamp the wrap magic, and
        # publish the skip (readers recycle it while we wait on)
        head = self._u64(_OFF_HEAD)
        pos = head % cap
        if cap - pos < need:
            pad = cap - pos
            self._wait(_free_at_least(pad), self.reader_alive, timeout,
                       "waiting for ring space (wrap)", side="writer")
            if pad >= _U32.size:
                _U32.pack_into(self._data, pos,
                               _token(head, self._epoch, wrap=True))
            # a run shorter than a u32 cannot even hold the wrap token;
            # readers infer the wrap from run < frame size once head
            # passes it
            head += pad
            self._set_u64(_OFF_HEAD, head)
            self._ring_readers()
            pos = 0
        # phase 2: wait for the frame itself to fit
        self._wait(_free_at_least(need), self.reader_alive, timeout,
                   "waiting for ring space", side="writer")
        _U32.pack_into(self._data, pos, 0)  # unpublished until commit
        _KL.pack_into(self._data, pos + _U32.size, kind, nbytes)
        self._reserved = (head, need)
        return self._data[pos + _FRAME.size: pos + _FRAME.size + nbytes]

    def commit_frame(self) -> None:
        """Publish the reserved frame: payload and header are fully
        written, so store the seqlock token *last*, then advance head and
        ring any waiting reader."""
        if self._reserved is None:
            raise RuntimeError("commit_frame without begin_frame")
        head, need = self._reserved
        self._reserved = None
        _U32.pack_into(self._data, head % self.capacity,
                       _token(head, self._epoch))
        self._set_u64(_OFF_HEAD, head + need)
        self._ring_readers()

    def mark_closed(self, role: str) -> None:
        """Publish this side's closed flag without dropping the mapping
        (the peer's liveness probe reads it; a cached attachment clears it
        again on the next :meth:`claim`).  Rings the peer's doorbell so a
        parked waiter observes the close immediately."""
        if role == "reader":
            if self.nreaders:
                if self.slot >= 0:
                    _U32.pack_into(self._buf, self._slot_off(self.slot) + 12,
                                   _SLOT_STATE_CLOSED)
            else:
                _U32.pack_into(self._buf, _OFF_READER_CLOSED, 1)
            self._ring_writer()
        else:
            _U32.pack_into(self._buf, _OFF_WRITER_CLOSED, 1)
            self._ring_readers()

    def writer_close(self) -> None:
        self.mark_closed("writer")
        self.close()

    # -- reader side ---------------------------------------------------------------
    def _advance_tail(self, n: int) -> None:
        if self.nreaders:
            off = self._slot_off(self.slot)
            self._set_u64(off, self._u64(off) + n)
        else:
            self._set_u64(_OFF_TAIL, self._u64(_OFF_TAIL) + n)
        self._ring_writer()

    def recv(self, timeout: Optional[float] = None
             ) -> Optional[Tuple[int, memoryview]]:
        """Next frame as ``(kind_byte, payload view)``, or ``None`` at end
        of stream (writer closed or died with the ring drained).  The view
        is valid until :meth:`consume` / the next :meth:`recv`.

        Readiness is judged from the frame's own seqlock word at this
        reader's cursor — never from the shared head — so a partially
        published frame reads as "not ready" and a corrupt one fails
        loudly instead of desyncing."""
        if self.closed:
            return None
        self.consume()
        cap = self.capacity

        def _readable():
            tail = self._tail_get()
            # the head gate is NECESSARY, the commit token SUFFICIENT:
            # head only advances once a frame (or wrap skip) is fully
            # published, so nothing before it is ever examined — in
            # particular not a *pooled* ring's previous-lease frames,
            # whose commit words are token-valid again after reset()
            # rewinds the monotonic cursors (tokens derive from the byte
            # offset alone).  The token then guards what head alone
            # cannot: head-before-payload visibility off x86-TSO reads
            # as "not ready", never as a frame.
            if self._u64(_OFF_HEAD) - tail <= 0:
                return None
            pos = tail % cap
            run = cap - pos
            if run < _FRAME.size:
                # run too short for any frame: an implied wrap skip
                self._advance_tail(run)
                return None
            commit = _U32.unpack_from(self._data, pos)[0]
            if commit == _token(tail, self._epoch):
                return pos + 1  # avoid falsy 0
            if commit == _token(tail, self._epoch, wrap=True):
                self._advance_tail(run)
            return None

        def _writer_ok():
            if self.nreaders and self.slot >= 0 and (
                    self._u32(self._slot_off(self.slot) + 12)
                    == _SLOT_STATE_EVICTED):
                # the writer evicted THIS slot (the claim raced the grace
                # deadline): frames have been recycled underneath us, so
                # a silent EOF here would be a silent partial import
                raise IOError(
                    f"broadcast slot {self.slot} of {self.name!r} was "
                    f"evicted mid-stream; the delivered rows are "
                    f"incomplete")
            if self.writer_alive():
                return True
            return self.used() > 0  # drain what a dead writer published

        try:
            pos = self._wait(_readable, _writer_ok, timeout,
                             "waiting for a frame", side="reader") - 1
        except BrokenPipeError:
            if self.aborted:
                raise  # an abort() is a loud failure, never a quiet EOF
            return None  # unclean writer death == end of stream (fail-fast)
        tail = self._tail_get()
        commit, kind, ln = _FRAME.unpack_from(self._data, pos)
        # seqlock re-check + bounds: the commit token must still match and
        # the length must fit the contiguous run it was committed into
        if (commit != _token(tail, self._epoch)
                or _FRAME.size + ln > cap - pos):
            raise IOError(
                f"shm ring frame header corrupt at {pos}: length {ln}")
        self._pending_consume = _FRAME.size + ln
        return kind[0], self._data[pos + _FRAME.size: pos + _FRAME.size + ln]

    def consume(self) -> None:
        """Recycle the span returned by the last :meth:`recv` (its view is
        dead afterwards)."""
        if self._pending_consume:
            n, self._pending_consume = self._pending_consume, 0
            self._advance_tail(n)

    def reader_close(self) -> None:
        self.mark_closed("reader")
        self.close()

    # -- lifecycle -----------------------------------------------------------------
    def close(self) -> None:
        """Close this side's mapping; the owner also unlinks the segment
        name (and the doorbell fifos) so an unclean peer cannot leak them.
        Outstanding payload views keep the mapping alive until they are
        garbage collected."""
        if self.closed:
            return
        self.closed = True
        self._reserved = None
        self._pending_consume = 0
        for db in self._dbs.values():
            if db is not None:
                db.close()
        self._dbs = {}
        try:
            self._data.release()
            self._buf.release()
            self.shm.close()
        except BufferError:
            # a consumer still holds a payload view; the OS frees the
            # mapping at process exit.  Neuter the SharedMemory
            # destructor's retry so GC doesn't spew 'Exception ignored'.
            self.shm.close = lambda: None  # type: ignore[method-assign]
        if self.owner:
            # balance the tracker books before unlink unregisters: an
            # attacher sharing this process tree's tracker may already have
            # unregistered the name (register is set-idempotent)
            try:
                resource_tracker.register(self.shm._name, "shared_memory")
            except Exception:  # pragma: no cover - tracker API drift
                pass
            try:
                self.shm.unlink()
            except FileNotFoundError:
                pass
            _remove_fifos(self.shm.name)
            _created_here.discard(self.shm.name)

    @staticmethod
    def cleanup(name: str) -> bool:
        """Best-effort unlink of a segment (and its doorbell fifos) left
        behind by an unclean shutdown.  Returns True when a segment was
        removed."""
        _remove_fifos(name)
        try:
            shm = shared_memory.SharedMemory(name=name, create=False)
        except FileNotFoundError:
            return False
        try:
            resource_tracker.unregister(shm._name, "shared_memory")
        except Exception:  # pragma: no cover
            pass
        shm.close()
        try:
            shm.unlink()
        except FileNotFoundError:  # pragma: no cover - raced another cleaner
            return False
        return True

    def __enter__(self) -> "ShmRing":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# -- ring pool ----------------------------------------------------------------------
#
# Creating a segment is cheap, but *first-touch* page faults on a cold
# mapping cost ~3 ms/MiB on this class of box — an order of magnitude more
# than the warm copy — and every fresh ``mmap`` of an existing segment pays
# the minor-fault setup again.  Both sides therefore recycle their
# mappings (same story as the encode BufferPool, at segment granularity):
# the reader parks cleanly drained rings for the next lease, the writer
# caches its attachment per segment name.  Unclean shutdowns still unlink
# immediately.  Broadcast rings pool too (keyed by slot count as well):
# the creator slot parks once the writer and every peer slot are done,
# and reset() re-reserves the whole slot table for the next group.

_PARK_MAX = 4
_parked: Dict[Tuple[int, bool], List[ShmRing]] = {}
_bc_parked: Dict[Tuple[int, int, bool], List[ShmRing]] = {}
_writer_cache: Dict[str, ShmRing] = {}  # segment name -> live attachment
_park_lock = threading.Lock()


def acquire_ring(capacity: int = DEFAULT_RING_CAPACITY,
                 doorbell: bool = True) -> ShmRing:
    """A reader-claimed SPSC ring of ``capacity``: a parked warm one if
    available, else freshly created."""
    want = bool(doorbell) and _DOORBELL_OK  # effective capability
    key = (capacity, want)
    with _park_lock:
        rings = _parked.get(key)
        ring = rings.pop() if rings else None
    if ring is not None:
        ring.reset()
        ring.claim("reader")
        return ring
    return ShmRing.create(capacity=capacity, role="reader", doorbell=want)


def _park_ring(ring: ShmRing) -> bool:
    """Park an owner ring after a clean EOF.  Refuses (caller unlinks) when
    the writer side might still touch the segment or the pool is full."""
    if ring.closed or not ring.owner or ring.nreaders:
        return False

    def _writer_done() -> bool:
        writer_pid = ring._u32(_OFF_WRITER_PID)
        return (ring.writer_closed or writer_pid == 0
                or not _pid_alive(writer_pid))

    # the writer publishes its closed flag with the EOF frame, so this
    # normally succeeds on the first probe; the brief poll only covers a
    # writer that died between frame and flag
    deadline = time.monotonic() + 0.005
    while not _writer_done():
        if time.monotonic() > deadline:
            return False  # writer still live and attached: do not recycle
        time.sleep(1e-4)
    key = (ring.capacity, ring._u32(_OFF_DOORBELL) == _DB_FDS)
    ring.release_doorbells()  # idle fd usage stays flat in pool size
    with _park_lock:
        if _draining:
            return False
        rings = _parked.setdefault(key, [])
        if len(rings) >= _PARK_MAX:
            return False
        rings.append(ring)
    return True


def acquire_broadcast_ring(capacity: int, readers: int,
                           doorbell: bool = True) -> ShmRing:
    """A creator-claimed (slot 0) broadcast ring: a parked warm one if
    available — its slot table re-reserved by :meth:`ShmRing.reset` —
    else freshly created."""
    want = bool(doorbell) and _DOORBELL_OK
    key = (capacity, readers, want)
    with _park_lock:
        rings = _bc_parked.get(key)
        ring = rings.pop() if rings else None
    if ring is not None:
        ring.reset()
        ring.claim("reader")
        return ring
    return ShmRing.create(capacity=capacity, role="reader", doorbell=want,
                          readers=readers)


def _bc_peers_done(ring: ShmRing) -> bool:
    """True once the writer and every *other* slot are demonstrably done
    (closed, evicted, or their process gone) — no peer can touch the
    segment again."""
    writer_pid = ring._u32(_OFF_WRITER_PID)
    if not (ring.writer_closed or writer_pid == 0
            or not _pid_alive(writer_pid)):
        return False
    for i in range(ring.nreaders):
        if i == ring.slot:
            continue
        off = ring._slot_off(i)
        state = ring._u32(off + 12)
        if state in (_SLOT_STATE_CLOSED, _SLOT_STATE_EVICTED):
            continue
        if (state == _SLOT_STATE_ATTACHED
                and not _pid_alive(ring._u32(off + 8))):
            continue  # dead reader: it will never touch the segment
        return False
    return True


def _bc_pool_insert(ring: ShmRing) -> bool:
    key = (ring.capacity, ring.nreaders, ring._u32(_OFF_DOORBELL) == _DB_FDS)
    ring.release_doorbells()
    with _park_lock:
        if _draining:
            return False
        rings = _bc_parked.setdefault(key, [])
        if len(rings) >= _PARK_MAX:
            return False
        rings.append(ring)
    return True


_BC_PARK_WAIT = 2.0  # background parker's patience for straggler readers


def _park_broadcast(ring: ShmRing) -> bool:
    """Park the creator slot's ring after a clean EOF.  Peers usually
    drain the same EOF within a millisecond, so the common case parks
    inline; a group whose readers finish far apart is handed to a
    *background* parker instead of stalling the creator's close for a
    bounded probe (the old ~20 ms inline poll).  The parker waits up to
    ``_BC_PARK_WAIT`` for the stragglers, then pools the warm segment —
    or unlinks it if a peer is still attached/live at the deadline.

    Returns True when ownership was taken (parked now or handed off);
    False means the caller must close/unlink as before."""
    if ring.closed or not ring.owner or not ring.nreaders:
        return False
    if _bc_peers_done(ring):
        return _bc_pool_insert(ring)

    def _park_later() -> None:
        deadline = time.monotonic() + _BC_PARK_WAIT
        while not _bc_peers_done(ring):
            if time.monotonic() > deadline or _draining:
                ring.close()  # straggler still live: unlink as before
                return
            time.sleep(1e-3)
        if not _bc_pool_insert(ring):
            ring.close()

    t = threading.Thread(target=_park_later, name="pgring-bc-park",
                         daemon=True)
    t.start()
    return True


def attach_ring(name: str) -> ShmRing:
    """A writer-claimed attachment to segment ``name``: the cached warm
    mapping when this process already has one, else a fresh attach.
    Segment names are never reused, so a cache hit is always the same ring
    the reader just re-registered."""
    with _park_lock:
        ring = _writer_cache.pop(name, None)
    if ring is not None and not ring.closed:
        ring.claim("writer")
        return ring
    return ShmRing.attach(name, role="writer")


def _park_writer(ring: ShmRing) -> bool:
    if ring.closed or ring.owner or ring.nreaders:
        return False
    ring.release_doorbells()
    with _park_lock:
        # a re-leased segment can briefly have two attachments in this
        # process (the next lease attached fresh before we parked); close
        # the superseded one instead of dropping it to GC
        prev = _writer_cache.pop(ring.name, None)
        while len(_writer_cache) >= _PARK_MAX:
            _, evicted = _writer_cache.popitem()
            evicted.close()  # unmap only; the reader owns the name
        _writer_cache[ring.name] = ring
    if prev is not None and prev is not ring:
        prev.close()
    return True


_draining = False


def set_pool_limits(park_max: Optional[int] = None) -> int:
    """Set (and return) the per-size-class warm-pool depth.  The broker
    raises this when it takes ownership of the pools — a resident
    control plane amortizes segments across many more plans than a
    single session does."""
    global _PARK_MAX
    if park_max is not None:
        _PARK_MAX = max(0, int(park_max))
    return _PARK_MAX


def pool_info() -> Dict[str, int]:
    """Warm-pool occupancy (broker observability / tests)."""
    with _park_lock:
        return {
            "spsc_parked": sum(len(v) for v in _parked.values()),
            "broadcast_parked": sum(len(v) for v in _bc_parked.values()),
            "writer_cached": len(_writer_cache),
            "park_max": _PARK_MAX,
        }


def drain_pools() -> int:
    """Close every parked/cached warm segment now (broker shutdown and
    tests); unlike the atexit drain, parking works again afterwards.
    Returns the number of mappings closed."""
    global _draining
    with _park_lock:
        _draining = True  # background parkers close instead of pooling
        rings = [r for lst in _parked.values() for r in lst]
        rings += [r for lst in _bc_parked.values() for r in lst]
        rings += list(_writer_cache.values())
        _parked.clear()
        _bc_parked.clear()
        _writer_cache.clear()
    for r in rings:
        r.close()
    with _park_lock:
        _draining = False
    return len(rings)


def _drain_parked() -> None:  # pragma: no cover - exercised at interpreter exit
    global _draining
    drain_pools()
    _draining = True  # interpreter exiting: stay drained for good


atexit.register(_drain_parked)


# -- crash sweep --------------------------------------------------------------------

_SHM_DIR = "/dev/shm"  # where the kernel materializes POSIX shm segments


def sweep_orphans(min_age_s: float = 30.0) -> List[str]:
    """Crash sweep for unclean shutdowns that never reached any close
    path: unlink ring segments whose every registered pid is dead, and
    doorbell fifos whose segment is already gone (a process can die
    between fifo creation and registration, or a foreign cleaner can
    remove the segment first — either way the fifos would outlive it).

    Segments with no registered pid yet (mid-creation) are only swept
    once older than ``min_age_s``.  Rings parked warm by *this* process
    are never touched.  Returns the names removed.  The directory's
    lease reaper calls this on every expiry sweep."""
    swept: List[str] = []
    if not os.path.isdir(_SHM_DIR):  # non-Linux: nothing to scan safely
        return swept
    with _park_lock:
        keep = {r.name for lst in _parked.values() for r in lst}
        keep |= {r.name for lst in _bc_parked.values() for r in lst}
        keep |= set(_writer_cache)
    now = time.time()
    for path in glob.glob(os.path.join(_SHM_DIR, "pgring-*")):
        name = os.path.basename(path)
        if name in keep:
            continue
        try:
            shm = shared_memory.SharedMemory(name=name, create=False)
        except (OSError, ValueError):
            continue  # vanished, or raced another sweeper
        orphan = False
        try:
            if name not in _created_here:
                try:
                    resource_tracker.unregister(shm._name, "shared_memory")
                except Exception:  # pragma: no cover - tracker API drift
                    pass
            try:
                magic, version, _cap = _HDR.unpack_from(shm.buf, 0)
            except struct.error:
                continue
            if magic != _MAGIC or version != _VERSION:
                continue  # not ours to judge
            pids = []
            wpid = _U32.unpack_from(shm.buf, _OFF_WRITER_PID)[0]
            if wpid:
                pids.append(wpid)
            nreaders = _U32.unpack_from(shm.buf, _OFF_NREADERS)[0]
            if nreaders:
                for i in range(nreaders):
                    off = HEADER_SIZE + _SLOT.size * i
                    state = _U32.unpack_from(shm.buf, off + 12)[0]
                    pid = _U32.unpack_from(shm.buf, off + 8)[0]
                    if state == _SLOT_STATE_ATTACHED and pid:
                        pids.append(pid)
            else:
                rpid = _U32.unpack_from(shm.buf, _OFF_READER_PID)[0]
                if rpid:
                    pids.append(rpid)
            if pids:
                orphan = all(not _pid_alive(p) for p in pids)
            else:
                try:
                    orphan = now - os.stat(path).st_mtime >= min_age_s
                except OSError:
                    orphan = False
        finally:
            shm.close()
        if orphan and ShmRing.cleanup(name):
            swept.append(name)
    for p in glob.glob(os.path.join(tempfile.gettempdir(), "*.pgdb-*")):
        seg = os.path.basename(p).split(".pgdb-")[0]
        if not os.path.exists(os.path.join(_SHM_DIR, seg)):
            try:
                os.unlink(p)
                swept.append(os.path.basename(p))
            except OSError:  # pragma: no cover - raced another cleaner
                pass
    return swept


class ShmRingTransport(Transport):
    """Framed transport over a :class:`ShmRing` (the third transport, next
    to :class:`~repro.core.transport.SocketTransport` and
    :class:`~repro.core.transport.ChannelTransport`), SPSC or broadcast.

    Send path: one reserved span per frame, segments gathered straight into
    the mapped region — no queue materialization, no join.  Receive path:
    block/parts payloads are handed out as ``memoryview`` slices of the
    mapped region (consumed in place by the decoder); control frames
    (schema, text, verify, EOF) are small and copied so downstream
    ``.decode()`` string handling keeps working.

    Header-byte accounting matches the other transports exactly: every
    frame charges ``payload + 5`` to ``bytes_sent`` and to ``LinkSim``
    (the per-frame seqlock word is ring overhead, not wire bytes), so
    `PipeStats` and the fig. 15 link emulation stay comparable across
    socket/channel/shm.
    """

    #: frame kinds whose payload views are consumed in place by a decoder
    _ZERO_COPY_KINDS = frozenset(b"BP")

    def __init__(self, ring: ShmRing, link: Optional[LinkSim] = None,
                 send_timeout: Optional[float] = 60.0):
        self.ring = ring
        self.link = link
        self.send_timeout = send_timeout
        self._link_debt = 0.0
        self.bytes_sent = 0
        self.frames_sent = 0
        self.shm_spans = 0  # frames carried via reserved in-place spans
        self._clean_eof = False  # an explicit EOF frame arrived
        self._sent_eof = False   # we published the writer-closed flag
        self._closed = False

    # wait attribution for PipeStats (this side's ring instance)
    @property
    def doorbell_waits(self) -> int:
        return self.ring.wakeups["doorbell"]

    @property
    def spin_wakeups(self) -> int:
        return self.ring.wakeups["spin"]

    @property
    def poll_sleeps(self) -> int:
        return self.ring.wakeups["poll"]

    def send_frames(self, kind: bytes, segments: Iterable[Buffer]) -> None:
        if faults._ACTIVE is not None:
            fp = faults.send_plan("shm", kind, segments)
            if fp is not None:
                with faults.suppressed():
                    for p in fp:
                        self.send_frame(kind, p)
                return
        views = []
        payload_len = 0
        for seg in segments:
            n = _seg_len(seg)
            if n == 0:
                continue
            mv = seg if isinstance(seg, memoryview) else memoryview(seg)
            if mv.format != "B" or mv.ndim != 1:
                mv = mv.cast("B")
            views.append((mv, n))
            payload_len += n
        self._charge_link(payload_len + _WIRE_HEADER)
        span = self.ring.begin_frame(kind, payload_len,
                                     timeout=self.send_timeout)
        off = 0
        for mv, n in views:
            span[off: off + n] = mv
            off += n
        self.ring.commit_frame()
        if kind == FRAME_EOF:
            # EOF promises no further writes: publish the closed flag now,
            # so the reader can park the ring warm the moment it drains
            # (instead of waiting on our transport close)
            self.ring.mark_closed("writer")
            self._sent_eof = True
        self.bytes_sent += payload_len + _WIRE_HEADER
        self.frames_sent += 1
        self.shm_spans += 1

    def recv_frame(self, timeout: Optional[float] = None
                   ) -> Tuple[bytes, bytes]:
        if faults._ACTIVE is not None:
            if faults.fire("transport.recv", transport="shm") == "drop":
                with faults.suppressed():
                    self.recv_frame()  # swallow one frame
        item = self.ring.recv(timeout=timeout)
        if item is None:
            return FRAME_EOF, b""
        kind_byte, view = item
        kind = bytes((kind_byte,))
        if kind_byte in self._ZERO_COPY_KINDS:
            self.shm_spans += 1
            return kind, view  # consumed in place; recycled on next recv
        payload = bytes(view)
        self.ring.consume()
        if kind == FRAME_EOF:
            self._clean_eof = True
        return kind, payload

    def close(self) -> None:
        if self._closed:  # a second close must not double-park the ring
            return
        self._closed = True
        if self.ring.nreaders:
            # a reader closes its slot (the creator parks the ring warm
            # when the writer and every peer slot are already done, else
            # unlinks — peers' live mappings survive the unlink); the
            # writer marks itself closed so every reader drains to EOF
            if self.ring.slot >= 0:
                if self.ring.owner and self._clean_eof:
                    self.ring.mark_closed("reader")
                    if _park_broadcast(self.ring):
                        return
                    self.ring.close()
                else:
                    self.ring.reader_close()
            else:
                if not self._sent_eof and not self.ring.closed:
                    self.ring.mark_closed("writer")
                self.ring.close()
            return
        if self.ring.owner:
            # a cleanly drained ring goes back to the pool warm (page
            # faults already paid); anything else unlinks right away
            if self._clean_eof and _park_ring(self.ring):
                return
            self.ring.reader_close()
        else:
            # publish EOF-side semantics for the peer's probe — but only
            # if the EOF frame did not already do it: after a clean EOF
            # the reader may have parked and *re-leased* this ring, and a
            # stale re-stamp here would land on the new lease and make
            # its reader see a premature writer-death EOF
            if not self._sent_eof and not self.ring.closed:
                self.ring.mark_closed("writer")
            if not _park_writer(self.ring):
                self.ring.close()
