"""Cross-process shared-memory ring transport (ROADMAP: the zero-copy
SegmentList path across process boundaries).

``ChannelTransport`` is the in-process queue analog of a colocated pipe; it
still pays one materialization per frame because the consuming thread may
run after the producer's pooled buffers are recycled.  ``ShmRing`` removes
that copy *and* the process boundary: a fixed-capacity byte ring mapped
through ``multiprocessing.shared_memory``, single-producer/single-consumer,
with a small header region holding the monotonic head/tail cursors and the
peer liveness fields.

Zero-copy contract:

* the writer **reserves a contiguous span** inside the mapped region
  (:meth:`ShmRing.begin_frame`), the transport gathers the encoded
  ``SegmentList`` views straight into it, and :meth:`ShmRing.commit_frame`
  publishes the advanced head — no intermediate ``bytes`` is ever built;
* the reader hands out a **``memoryview`` slice of the mapped region**
  (:meth:`ShmRing.recv`) that ``decode_block`` consumes in place; the span
  is recycled (:meth:`ShmRing.consume`) only after the next frame is
  requested, by which point the decoder has copied the values out into
  arena-backed columns.

Frame records never wrap: when the remaining run to the end of the data
region is too small, the writer stamps a 1-byte wrap marker (0x00) and both
sides skip to the region start.  Waiting is futex-style polling with
exponential backoff (spin first, then sleep 1 µs → 2 ms), with peer-death
detection on both sides so neither a dead importer nor a dead exporter can
hang the survivor (the socket path gets this for free from the FIN).

Layout (offsets in bytes)::

    0   u32  magic 'PGR1'
    4   u32  version
    8   u64  capacity of the data region
    16  u64  head  (monotonic bytes written, wrap padding included)
    24  u64  tail  (monotonic bytes consumed)
    32  u32  writer pid (0 = not yet attached)
    36  u32  reader pid
    40  u32  writer closed flag
    44  u32  reader closed flag
    48..64   reserved
    64..     data region (capacity bytes)

The reader side *creates* (and ultimately unlinks) the segment — it is the
rendezvous registrant, mirroring the socket path where the importer listens.
On Python < 3.13 the attaching process must be unregistered from the
``resource_tracker`` or its exit would unlink the segment under the still
running reader (bpo-39959); :meth:`ShmRing.attach` handles that.

Memory-ordering caveat: cursors are published with plain (GIL-serialized)
stores — pure Python offers no cross-process fence, so the
payload-before-head publication order relies on x86-TSO total store order.
On weakly-ordered ISAs (ARM64) a reader could in principle observe the
advanced head before the payload bytes; the reader fails loudly on a torn
header (length sanity check) rather than desyncing, but the in-place
payload contents are not similarly guarded.  Production hardening would
put a seqlock word per frame or an eventfd doorbell here (ROADMAP).
"""

from __future__ import annotations

import atexit
import errno
import os
import secrets
import struct
import threading
import time
from multiprocessing import resource_tracker, shared_memory
from typing import Dict, Iterable, List, Optional, Tuple

from .iobuf import Buffer, _seg_len
from .transport import FRAME_EOF, LinkSim, Transport

__all__ = ["ShmRing", "ShmRingTransport", "DEFAULT_RING_CAPACITY",
           "acquire_ring"]

_MAGIC = 0x50475231  # 'PGR1'
_VERSION = 1
_HDR = struct.Struct("<IIQ")      # magic, version, capacity
_U64 = struct.Struct("<Q")
_U32 = struct.Struct("<I")
_FRAME = struct.Struct("<cI")     # kind, payload length (shared with transport)

HEADER_SIZE = 64
_OFF_CAPACITY = 8
_OFF_HEAD = 16
_OFF_TAIL = 24
_OFF_WRITER_PID = 32
_OFF_READER_PID = 36
_OFF_WRITER_CLOSED = 40
_OFF_READER_CLOSED = 44

_WRAP = 0x00                      # 1-byte marker: skip to region start

DEFAULT_RING_CAPACITY = 1 << 25   # 32 MiB: several default-size blocks deep

_SPIN = 200                       # polls before the first sleep
_SLEEP_MIN = 1e-6
# Backoff restarts on every wait, so a *streaming* peer wakes within
# microseconds of the cursor moving; only a genuinely idle wait (e.g. the
# importer parked on the schema frame while the exporter is still setting
# up) escalates to the cap.  Keep the cap high enough that an idle poller
# does not churn the GIL out from under the working thread.
_SLEEP_MAX = 2e-3
_LIVENESS_EVERY = 64              # peer pid probes, once per N sleeps

# segment names created by THIS process: an in-process attach (exporter and
# importer threads of one transfer) must not unregister the creator's
# resource-tracker entry, or the eventual unlink double-unregisters
_created_here: set = set()


def _pid_alive(pid: int) -> bool:
    if pid <= 0:
        return True  # peer not attached yet: nothing to pronounce dead
    try:
        # /proc beats os.kill(pid, 0): a SIGKILLed child is a zombie until
        # reaped, and a zombie still answers signal probes
        with open(f"/proc/{pid}/stat", "rb") as f:
            stat = f.read()
        after_comm = stat[stat.rfind(b")") + 2:]
        return not after_comm.startswith(b"Z")
    except FileNotFoundError:
        return False
    except OSError:  # pragma: no cover - no procfs: fall back to a probe
        pass
    try:  # pragma: no cover
        os.kill(pid, 0)
    except ProcessLookupError:  # pragma: no cover
        return False
    except PermissionError:  # pragma: no cover - exists but not ours
        return True
    except OSError as e:  # pragma: no cover - exotic platforms
        return e.errno != errno.ESRCH
    return True  # pragma: no cover


class ShmRing:
    """SPSC frame ring over one shared-memory segment.

    The creator (reader side by default) owns the segment name and unlinks
    it on close; the attacher only closes its mapping.
    """

    def __init__(self, shm: shared_memory.SharedMemory, owner: bool,
                 capacity: int):
        self.shm = shm
        self.owner = owner
        self.capacity = capacity
        self._buf: memoryview = shm.buf
        self._data: memoryview = self._buf[HEADER_SIZE:HEADER_SIZE + capacity]
        self.closed = False
        self._reserved: Optional[Tuple[int, int]] = None  # (pos, need)
        self._pending_consume = 0

    # -- construction ------------------------------------------------------------
    @classmethod
    def create(cls, capacity: int = DEFAULT_RING_CAPACITY,
               name: Optional[str] = None, role: str = "reader") -> "ShmRing":
        name = name or f"pgring-{secrets.token_hex(6)}"
        shm = shared_memory.SharedMemory(name=name, create=True,
                                         size=HEADER_SIZE + capacity)
        _created_here.add(shm.name)
        _HDR.pack_into(shm.buf, 0, _MAGIC, _VERSION, capacity)
        ring = cls(shm, owner=True, capacity=capacity)
        ring.claim(role)
        return ring

    @classmethod
    def attach(cls, name: str, role: str = "writer") -> "ShmRing":
        shm = shared_memory.SharedMemory(name=name, create=False)
        # Python < 3.13 registers even plain attaches with the resource
        # tracker, whose cleanup at *this* process's exit would unlink the
        # segment under the still-running creator (bpo-39959).  Skip when
        # this process is the creator: the entry belongs to the unlink.
        if shm.name not in _created_here:
            try:
                resource_tracker.unregister(shm._name, "shared_memory")
            except Exception:  # pragma: no cover - tracker API drift
                pass
        magic, version, capacity = _HDR.unpack_from(shm.buf, 0)
        if magic != _MAGIC or version != _VERSION:
            shm.close()
            raise IOError(f"{name!r} is not a PipeGen ring segment")
        ring = cls(shm, owner=False, capacity=capacity)
        ring.claim(role)
        return ring

    def claim(self, role: Optional[str]) -> None:
        """Record this process as the ring's reader or writer (for the
        peer's liveness probe).  Claiming re-opens that side: a pooled ring
        may carry the previous lease's closed flag."""
        if role == "reader":
            _U32.pack_into(self._buf, _OFF_READER_PID, os.getpid())
            _U32.pack_into(self._buf, _OFF_READER_CLOSED, 0)
        elif role == "writer":
            _U32.pack_into(self._buf, _OFF_WRITER_PID, os.getpid())
            _U32.pack_into(self._buf, _OFF_WRITER_CLOSED, 0)

    def reset(self) -> None:
        """Rewind a (drained) ring for a fresh lease: cursors to zero, no
        peers, no closed flags.  Owner-side only, between pooled reuses."""
        self._set_u64(_OFF_HEAD, 0)
        self._set_u64(_OFF_TAIL, 0)
        for off in (_OFF_WRITER_PID, _OFF_READER_PID,
                    _OFF_WRITER_CLOSED, _OFF_READER_CLOSED):
            _U32.pack_into(self._buf, off, 0)
        self._reserved = None
        self._pending_consume = 0

    @property
    def name(self) -> str:
        return self.shm.name

    # -- header accessors --------------------------------------------------------
    def _u64(self, off: int) -> int:
        return _U64.unpack_from(self._buf, off)[0]

    def _set_u64(self, off: int, v: int) -> None:
        _U64.pack_into(self._buf, off, v)

    def _u32(self, off: int) -> int:
        return _U32.unpack_from(self._buf, off)[0]

    @property
    def writer_closed(self) -> bool:
        return bool(self._u32(_OFF_WRITER_CLOSED))

    @property
    def reader_closed(self) -> bool:
        return bool(self._u32(_OFF_READER_CLOSED))

    def reader_alive(self) -> bool:
        return not self.reader_closed and _pid_alive(self._u32(_OFF_READER_PID))

    def writer_alive(self) -> bool:
        return not self.writer_closed and _pid_alive(self._u32(_OFF_WRITER_PID))

    def used(self) -> int:
        return self._u64(_OFF_HEAD) - self._u64(_OFF_TAIL)

    # -- waiting -----------------------------------------------------------------
    def _wait(self, ready, peer_ok, timeout: Optional[float], what: str):
        """Futex-style poll: spin, then sleep with exponential backoff,
        probing peer liveness as we go.  Returns the truthy ``ready()``
        value; raises BrokenPipeError/TimeoutError."""
        deadline = None if timeout is None else time.monotonic() + timeout
        sleep = _SLEEP_MIN
        sleeps = 0
        for _ in range(_SPIN):
            r = ready()
            if r:
                return r
        while True:
            r = ready()
            if r:
                return r
            if sleeps % _LIVENESS_EVERY == 0 and not peer_ok():
                raise BrokenPipeError(f"shm ring peer died while {what}")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(f"shm ring timed out {what}")
            time.sleep(sleep)
            sleep = min(sleep * 2, _SLEEP_MAX)
            sleeps += 1

    # -- writer side ---------------------------------------------------------------
    def begin_frame(self, kind: bytes, nbytes: int,
                    timeout: Optional[float] = None) -> memoryview:
        """Reserve a contiguous span, stamp the frame header into it, and
        return the writable payload view.  Blocks (with backoff) while the
        ring is full; fails fast when the reader dies."""
        if self.closed:
            raise ValueError("write on closed ring")
        if self._reserved is not None:
            raise RuntimeError("begin_frame while a frame is already open")
        need = _FRAME.size + nbytes
        if need > self.capacity:
            raise IOError(
                f"frame of {nbytes} bytes exceeds ring capacity "
                f"{self.capacity}; raise shm_capacity or lower block_rows"
            )
        cap = self.capacity

        def _free_at_least(n):
            return lambda: cap - (self._u64(_OFF_HEAD) - self._u64(_OFF_TAIL)) >= n

        # phase 1: if the contiguous run at head is too short, wait until
        # the dead run fits in the free space, stamp the wrap marker, and
        # publish the skip (the reader recycles it while we wait on)
        head = self._u64(_OFF_HEAD)
        pos = head % cap
        if cap - pos < need:
            pad = cap - pos
            self._wait(_free_at_least(pad), self.reader_alive, timeout,
                       "waiting for ring space (wrap)")
            self._data[pos] = _WRAP
            head += pad
            self._set_u64(_OFF_HEAD, head)
            pos = 0
        # phase 2: wait for the frame itself to fit
        self._wait(_free_at_least(need), self.reader_alive, timeout,
                   "waiting for ring space")
        _FRAME.pack_into(self._data, pos, kind, nbytes)
        self._reserved = (head, need)
        return self._data[pos + _FRAME.size: pos + _FRAME.size + nbytes]

    def commit_frame(self) -> None:
        """Publish the reserved frame (payload must be fully written)."""
        if self._reserved is None:
            raise RuntimeError("commit_frame without begin_frame")
        head, need = self._reserved
        self._reserved = None
        self._set_u64(_OFF_HEAD, head + need)

    def mark_closed(self, role: str) -> None:
        """Publish this side's closed flag without dropping the mapping
        (the peer's liveness probe reads it; a cached attachment clears it
        again on the next :meth:`claim`)."""
        off = _OFF_READER_CLOSED if role == "reader" else _OFF_WRITER_CLOSED
        _U32.pack_into(self._buf, off, 1)

    def writer_close(self) -> None:
        self.mark_closed("writer")
        self.close()

    # -- reader side ---------------------------------------------------------------
    def recv(self, timeout: Optional[float] = None
             ) -> Optional[Tuple[int, memoryview]]:
        """Next frame as ``(kind_byte, payload view)``, or ``None`` at end
        of stream (writer closed or died with the ring drained).  The view
        is valid until :meth:`consume` / the next :meth:`recv`."""
        if self.closed:
            return None
        self.consume()
        cap = self.capacity

        def _readable():
            avail = self.used()
            if not avail:
                return None
            pos = self._u64(_OFF_TAIL) % cap
            if self._data[pos] == _WRAP:
                # recycle the dead run at the region end and re-poll
                self._set_u64(_OFF_TAIL, self._u64(_OFF_TAIL) + (cap - pos))
                return None
            if avail < _FRAME.size:  # header partially published: re-poll
                return None
            return pos + 1  # avoid falsy 0

        def _writer_ok():
            if self.writer_alive():
                return True
            return self.used() > 0  # drain what a dead writer published

        try:
            pos = self._wait(_readable, _writer_ok, timeout,
                             "waiting for a frame") - 1
        except BrokenPipeError:
            return None  # unclean writer death == end of stream (fail-fast)
        kind, ln = _FRAME.unpack_from(self._data, pos)
        if _FRAME.size + ln > cap - pos:
            # a length that overruns the contiguous run means the header
            # bytes were torn or trampled; fail loudly over desyncing
            raise IOError(
                f"shm ring frame header corrupt at {pos}: length {ln}")
        self._pending_consume = _FRAME.size + ln
        return kind[0], self._data[pos + _FRAME.size: pos + _FRAME.size + ln]

    def consume(self) -> None:
        """Recycle the span returned by the last :meth:`recv` (its view is
        dead afterwards)."""
        if self._pending_consume:
            self._set_u64(_OFF_TAIL,
                          self._u64(_OFF_TAIL) + self._pending_consume)
            self._pending_consume = 0

    def reader_close(self) -> None:
        self.mark_closed("reader")
        self.close()

    # -- lifecycle -----------------------------------------------------------------
    def close(self) -> None:
        """Close this side's mapping; the owner also unlinks the segment
        name so an unclean peer cannot leak it (test: unclean-shutdown
        cleanup).  Outstanding payload views keep the mapping alive until
        they are garbage collected."""
        if self.closed:
            return
        self.closed = True
        self._reserved = None
        self._pending_consume = 0
        try:
            self._data.release()
            self._buf.release()
            self.shm.close()
        except BufferError:
            # a consumer still holds a payload view; the OS frees the
            # mapping at process exit.  Neuter the SharedMemory
            # destructor's retry so GC doesn't spew 'Exception ignored'.
            self.shm.close = lambda: None  # type: ignore[method-assign]
        if self.owner:
            # balance the tracker books before unlink unregisters: an
            # attacher sharing this process tree's tracker may already have
            # unregistered the name (register is set-idempotent)
            try:
                resource_tracker.register(self.shm._name, "shared_memory")
            except Exception:  # pragma: no cover - tracker API drift
                pass
            try:
                self.shm.unlink()
            except FileNotFoundError:
                pass
            _created_here.discard(self.shm.name)

    @staticmethod
    def cleanup(name: str) -> bool:
        """Best-effort unlink of a segment left behind by an unclean
        shutdown.  Returns True when a segment was removed."""
        try:
            shm = shared_memory.SharedMemory(name=name, create=False)
        except FileNotFoundError:
            return False
        try:
            resource_tracker.unregister(shm._name, "shared_memory")
        except Exception:  # pragma: no cover
            pass
        shm.close()
        try:
            shm.unlink()
        except FileNotFoundError:  # pragma: no cover - raced another cleaner
            return False
        return True

    def __enter__(self) -> "ShmRing":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# -- ring pool ----------------------------------------------------------------------
#
# Creating a segment is cheap, but *first-touch* page faults on a cold
# mapping cost ~3 ms/MiB on this class of box — an order of magnitude more
# than the warm copy — and every fresh ``mmap`` of an existing segment pays
# the minor-fault setup again.  Both sides therefore recycle their
# mappings (same story as the encode BufferPool, at segment granularity):
# the reader parks cleanly drained rings for the next lease, the writer
# caches its attachment per segment name.  Unclean shutdowns still unlink
# immediately.

_PARK_MAX = 4
_parked: Dict[int, List[ShmRing]] = {}
_writer_cache: Dict[str, ShmRing] = {}  # segment name -> live attachment
_park_lock = threading.Lock()


def acquire_ring(capacity: int = DEFAULT_RING_CAPACITY) -> ShmRing:
    """A reader-claimed ring of ``capacity``: a parked warm one if
    available, else freshly created."""
    with _park_lock:
        rings = _parked.get(capacity)
        ring = rings.pop() if rings else None
    if ring is not None:
        ring.reset()
        ring.claim("reader")
        return ring
    return ShmRing.create(capacity=capacity, role="reader")


def _park_ring(ring: ShmRing) -> bool:
    """Park an owner ring after a clean EOF.  Refuses (caller unlinks) when
    the writer side might still touch the segment or the pool is full."""
    if ring.closed or not ring.owner:
        return False

    def _writer_done() -> bool:
        writer_pid = ring._u32(_OFF_WRITER_PID)
        return (ring.writer_closed or writer_pid == 0
                or not _pid_alive(writer_pid))

    # the writer publishes its closed flag with the EOF frame, so this
    # normally succeeds on the first probe; the brief poll only covers a
    # writer that died between frame and flag
    deadline = time.monotonic() + 0.005
    while not _writer_done():
        if time.monotonic() > deadline:
            return False  # writer still live and attached: do not recycle
        time.sleep(1e-4)
    with _park_lock:
        rings = _parked.setdefault(ring.capacity, [])
        if len(rings) >= _PARK_MAX:
            return False
        rings.append(ring)
    return True


def attach_ring(name: str) -> ShmRing:
    """A writer-claimed attachment to segment ``name``: the cached warm
    mapping when this process already has one, else a fresh attach.
    Segment names are never reused, so a cache hit is always the same ring
    the reader just re-registered."""
    with _park_lock:
        ring = _writer_cache.pop(name, None)
    if ring is not None and not ring.closed:
        ring.claim("writer")
        return ring
    return ShmRing.attach(name, role="writer")


def _park_writer(ring: ShmRing) -> bool:
    if ring.closed or ring.owner:
        return False
    with _park_lock:
        # a re-leased segment can briefly have two attachments in this
        # process (the next lease attached fresh before we parked); close
        # the superseded one instead of dropping it to GC
        prev = _writer_cache.pop(ring.name, None)
        while len(_writer_cache) >= _PARK_MAX:
            _, evicted = _writer_cache.popitem()
            evicted.close()  # unmap only; the reader owns the name
        _writer_cache[ring.name] = ring
    if prev is not None and prev is not ring:
        prev.close()
    return True


def _drain_parked() -> None:  # pragma: no cover - exercised at interpreter exit
    with _park_lock:
        rings = [r for lst in _parked.values() for r in lst]
        rings += list(_writer_cache.values())
        _parked.clear()
        _writer_cache.clear()
    for r in rings:
        r.close()


atexit.register(_drain_parked)


class ShmRingTransport(Transport):
    """Framed transport over a :class:`ShmRing` (the third transport, next
    to :class:`~repro.core.transport.SocketTransport` and
    :class:`~repro.core.transport.ChannelTransport`).

    Send path: one reserved span per frame, segments gathered straight into
    the mapped region — no queue materialization, no join.  Receive path:
    block/parts payloads are handed out as ``memoryview`` slices of the
    mapped region (consumed in place by the decoder); control frames
    (schema, text, verify, EOF) are small and copied so downstream
    ``.decode()`` string handling keeps working.

    Header-byte accounting matches the other transports exactly: every
    frame charges ``payload + 5`` to ``bytes_sent`` and to ``LinkSim``, so
    `PipeStats` and the fig. 15 link emulation stay comparable across
    socket/channel/shm.
    """

    #: frame kinds whose payload views are consumed in place by a decoder
    _ZERO_COPY_KINDS = frozenset(b"BP")

    def __init__(self, ring: ShmRing, link: Optional[LinkSim] = None,
                 send_timeout: Optional[float] = 60.0):
        self.ring = ring
        self.link = link
        self.send_timeout = send_timeout
        self._link_debt = 0.0
        self.bytes_sent = 0
        self.frames_sent = 0
        self.shm_spans = 0  # frames carried via reserved in-place spans
        self._clean_eof = False  # an explicit EOF frame arrived
        self._sent_eof = False   # we published the writer-closed flag
        self._closed = False

    def send_frames(self, kind: bytes, segments: Iterable[Buffer]) -> None:
        views = []
        payload_len = 0
        for seg in segments:
            n = _seg_len(seg)
            if n == 0:
                continue
            mv = seg if isinstance(seg, memoryview) else memoryview(seg)
            if mv.format != "B" or mv.ndim != 1:
                mv = mv.cast("B")
            views.append((mv, n))
            payload_len += n
        self._charge_link(payload_len + _FRAME.size)
        span = self.ring.begin_frame(kind, payload_len,
                                     timeout=self.send_timeout)
        off = 0
        for mv, n in views:
            span[off: off + n] = mv
            off += n
        self.ring.commit_frame()
        if kind == FRAME_EOF:
            # EOF promises no further writes: publish the closed flag now,
            # so the reader can park the ring warm the moment it drains
            # (instead of waiting on our transport close)
            self.ring.mark_closed("writer")
            self._sent_eof = True
        self.bytes_sent += payload_len + _FRAME.size
        self.frames_sent += 1
        self.shm_spans += 1

    def recv_frame(self) -> Tuple[bytes, bytes]:
        item = self.ring.recv()
        if item is None:
            return FRAME_EOF, b""
        kind_byte, view = item
        kind = bytes((kind_byte,))
        if kind_byte in self._ZERO_COPY_KINDS:
            self.shm_spans += 1
            return kind, view  # consumed in place; recycled on next recv
        payload = bytes(view)
        self.ring.consume()
        if kind == FRAME_EOF:
            self._clean_eof = True
        return kind, payload

    def close(self) -> None:
        if self._closed:  # a second close must not double-park the ring
            return
        self._closed = True
        if self.ring.owner:
            # a cleanly drained ring goes back to the pool warm (page
            # faults already paid); anything else unlinks right away
            if self._clean_eof and _park_ring(self.ring):
                return
            self.ring.reader_close()
        else:
            # publish EOF-side semantics for the peer's probe — but only
            # if the EOF frame did not already do it: after a clean EOF
            # the reader may have parked and *re-leased* this ring, and a
            # stale re-stamp here would land on the new lease and make
            # its reader see a premature writer-death EOF
            if not self._sent_eof and not self.ring.closed:
                self.ring.mark_closed("writer")
            if not _park_writer(self.ring):
                self.ring.close()
