"""Serving launcher: batched decode with the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --requests 16 [--reduced]
"""

from __future__ import annotations

import argparse
import time

import jax

from ..models import build_model, get_config
from ..serve import ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-context", type=int, default=256)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_size=args.batch,
                      max_context=args.max_context, eos_token=-1,
                      temperature=args.temperature)
    rng = jax.random.PRNGKey(7)
    t0 = time.time()
    for i in range(args.requests):
        prompt = [int(x) for x in jax.random.randint(
            jax.random.fold_in(rng, i), (4,), 0, cfg.vocab)]
        eng.submit(prompt, max_new_tokens=args.max_new)
    results = eng.run(max_steps=100_000)
    dt = time.time() - t0
    toks = sum(len(r.tokens) for r in results)
    print(f"[launch.serve] {len(results)} requests, {toks} tokens, "
          f"{dt:.2f}s, {toks / max(dt, 1e-9):.1f} tok/s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
