import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, print memory/cost analysis, and dump the
roofline raw terms to JSON artifacts.

MUST be run as its own process (the XLA_FLAGS line above executes before
any other import so the 512 placeholder host devices exist before jax
initializes).  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/]
"""

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.common import cache_specs_struct, input_specs, skip_reason
from repro.distrib.sharding import (
    batch_spec, cache_specs, named_sharding, param_specs,
)
from repro.launch.hlo_stats import HW, parse_collectives, roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.models import build_model, get_config, get_shape
from repro.models.config import ARCHS, SHAPES
from repro.train.optimizer import adamw_init
from repro.train.step import TrainState, make_train_step

from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def _batch_shardings(mesh, batch_tree):
    def leaf(x):
        nd = len(x.shape)
        if nd >= 2 and x.shape[0] == 3:  # [3,B,S] M-RoPE ids
            inner = batch_spec(mesh, nd - 1, batch_dim=0,
                               batch_size=x.shape[1])
            spec = P(None, *tuple(inner))
        else:
            spec = batch_spec(mesh, nd, batch_size=x.shape[0])
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(leaf, batch_tree)


def lower_cell(arch: str, shape_name: str, mesh, *, zero1: bool = False,
               fsdp: bool = False, microbatches: int = 1, cfg_override=None):
    """Lower one cell; returns (lowered, aux) without compiling."""
    cfg = cfg_override or get_config(arch)
    shape = get_shape(shape_name)
    model = build_model(cfg)
    batch = input_specs(cfg, shape)
    bshard = _batch_shardings(mesh, batch)

    if shape.kind == "train":
        step = make_train_step(model, mesh, zero1=zero1, fsdp=fsdp,
                               microbatches=microbatches)
        state_shape = jax.eval_shape(
            lambda rng: TrainState(p := model.init(rng), adamw_init(p)),
            jax.random.PRNGKey(0),
        )
        jitted = jax.jit(
            step.step_fn,
            in_shardings=(step.state_shardings, bshard),
            out_shardings=(step.state_shardings, None),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(state_shape, batch)
        return lowered, {"kind": "train"}

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pshard = named_sharding(mesh, param_specs(params_shape, mesh, cfg))

    if shape.kind == "prefill":
        jitted = jax.jit(
            lambda p, b: model.forward(p, b, mesh),
            in_shardings=(pshard, bshard),
        )
        lowered = jitted.lower(params_shape, batch)
        return lowered, {"kind": "prefill"}

    # decode
    cache_shape = cache_specs_struct(cfg, shape)
    cshard = named_sharding(mesh, cache_specs(cache_shape, mesh, cfg))
    jitted = jax.jit(
        lambda p, c, b: model.decode_step(p, c, b, mesh),
        in_shardings=(pshard, cshard, bshard),
        out_shardings=(None, cshard),
        donate_argnums=(1,),
    )
    lowered = jitted.lower(params_shape, cache_shape, batch)
    return lowered, {"kind": "decode"}


def _cell_metrics(arch, shape_name, mesh, cfg, *, zero1, microbatches,
                  fsdp=False):
    """Compile one (possibly layer-reduced) variant; return raw metrics."""
    lowered, aux = lower_cell(arch, shape_name, mesh, zero1=zero1, fsdp=fsdp,
                              microbatches=microbatches, cfg_override=cfg)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = parse_collectives(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_hbm": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": float(coll.total_bytes),
    }, compiled, aux, coll


_PROBE_KEYS = ("flops", "bytes_hbm", "collective_bytes")


def probe_trip_corrected(arch: str, shape_name: str, mesh, *,
                         zero1: bool = False, fsdp: bool = False,
                         microbatches: int = 1):
    """XLA's cost_analysis counts loop bodies once regardless of trip count.

    Probe compiles run with the *layer scan unrolled* at small L so every
    layer is counted, then a linear model in L extrapolates to the full
    depth.  SSM/hybrid families additionally carry an inner *time* scan
    (counted once per layer instance); for their train/prefill cells we also
    probe at two sequence lengths and solve the analytic model

        m(L, S) = e*S  +  apps(L)*(q*S^2 + c*S)  +  L*(p*S + tb)

    (q=c=0 for attention-free rwkv6), then evaluate at the full (L, S).
    """
    from dataclasses import replace as _rep

    cfg = get_config(arch)
    shape = get_shape(shape_name)

    def compile_probe(L, S=None):
        c = _rep(cfg, n_layers=L, scan_unroll=True,
                 **({"encoder_layers": L} if cfg.is_encdec else {}))
        sspec = shape if S is None else _rep(shape, seq_len=S)
        # lower with a possibly-reduced sequence
        lowered, _ = _lower_with(arch, sspec, mesh, c, zero1, microbatches,
                                 fsdp=fsdp)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
        return {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_hbm": float(cost.get("bytes accessed", 0.0)),
            "collective_bytes": float(parse_collectives(hlo).total_bytes),
        }

    def apps(L):
        k = cfg.shared_attn_every
        return (L + k - 1) // k if k else 0

    needs_time_probe = (cfg.family in ("ssm", "hybrid")
                        and shape.kind in ("train", "prefill"))
    out = {}
    points = {}
    if not needs_time_probe:
        m2, m4 = compile_probe(2), compile_probe(4)
        points = {"L2": m2, "L4": m4}
        if cfg.family == "hybrid":
            # decode: attention term is linear in L via apps(); use L=8 too
            m8 = compile_probe(8)
            points["L8"] = m8
            for k in _PROBE_KEYS:
                mamba = (m4[k] - m2[k]) / 2.0
                attn = (m8[k] - m4[k]) - 4.0 * mamba
                base = m2[k] - 2.0 * mamba - apps(2) * attn
                out[k] = max(base + cfg.n_layers * mamba
                             + apps(cfg.n_layers) * attn, 0.0)
        else:
            for k in _PROBE_KEYS:
                unit = (m4[k] - m2[k]) / 2.0
                out[k] = max(m2[k] + (cfg.n_layers - 2) * unit, 0.0)
    else:
        S0 = 512
        S1 = 1024
        Sf = shape.seq_len
        Lf = cfg.n_layers
        if cfg.family == "ssm":
            mA, mB, mC, mD = (compile_probe(2, S0), compile_probe(4, S0),
                              compile_probe(4, S1), compile_probe(2, S1))
            points = {"L2S512": mA, "L4S512": mB, "L4S1024": mC,
                      "L2S1024": mD}
            for k in _PROBE_KEYS:
                # m(L, S) = base + e*S + L*(p*S + tb)
                u0 = (mB[k] - mA[k]) / 2.0          # p*S0 + tb
                u1 = (mC[k] - mD[k]) / 2.0          # p*S1 + tb
                p = (u1 - u0) / (S1 - S0)
                tb = u0 - p * S0
                e = (mD[k] - mA[k]) / (S1 - S0) - 2.0 * p
                base0 = mA[k] - e * S0 - 2.0 * (p * S0 + tb)
                out[k] = max(base0 + e * Sf + Lf * (p * Sf + tb), 0.0)
        else:  # hybrid: + apps(L)*(q*S^2 + c*S)
            pts = {(L, S): compile_probe(L, S)
                   for L in (2, 4, 8) for S in (S0, S1)}
            points = {f"L{L}S{S}": v for (L, S), v in pts.items()}
            for k in _PROBE_KEYS:
                def attn_term(S):
                    return (pts[(8, S)][k] - pts[(4, S)][k]
                            - 2.0 * (pts[(4, S)][k] - pts[(2, S)][k]))
                u0 = (pts[(4, S0)][k] - pts[(2, S0)][k]) / 2.0  # p*S0+tb
                u1 = (pts[(4, S1)][k] - pts[(2, S1)][k]) / 2.0
                p = (u1 - u0) / (S1 - S0)
                tb = u0 - p * S0
                a0, a1 = attn_term(S0), attn_term(S1)           # q*S^2+c*S
                q = (a1 / S1 - a0 / S0) / (S1 - S0)
                ccoef = a0 / S0 - q * S0
                e_base0 = pts[(2, S0)][k] - apps(2) * a0 - 2.0 * (p * S0 + tb)
                e_base1 = pts[(2, S1)][k] - apps(2) * a1 - 2.0 * (p * S1 + tb)
                e = (e_base1 - e_base0) / (S1 - S0)
                base = e_base0 - e * S0
                out[k] = max(
                    base + e * Sf
                    + apps(Lf) * (q * Sf * Sf + ccoef * Sf)
                    + Lf * (p * Sf + tb), 0.0)
    out["probe_points"] = points
    return out


def _lower_with(arch, shape_spec, mesh, cfg, zero1, microbatches, fsdp=False):
    """lower_cell against an explicit ShapeSpec (possibly reduced seq)."""
    from repro.models.config import SHAPES
    key = "__probe__"
    SHAPES[key] = shape_spec
    try:
        return lower_cell(arch, key, mesh, zero1=zero1, fsdp=fsdp,
                          microbatches=microbatches, cfg_override=cfg)
    finally:
        SHAPES.pop(key, None)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             out_dir: str = "artifacts/dryrun", zero1: bool = False,
             fsdp: bool = False, microbatches: int = 1, verbose: bool = True):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    reason = skip_reason(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "zero1": zero1, "fsdp": fsdp, "microbatches": microbatches,
    }
    if reason:
        rec.update(status="SKIP", reason=reason)
        _write(out_dir, rec)
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: SKIP ({reason})")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    try:
        with mesh:
            lowered, aux = lower_cell(arch, shape_name, mesh, zero1=zero1,
                                      fsdp=fsdp, microbatches=microbatches)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            try:
                hlo = compiled.as_text()
            except Exception:
                hlo = lowered.as_text()
            coll = parse_collectives(hlo)
            # XLA cost analysis counts loop bodies once; reconstruct the
            # whole-step numbers from layer-reduced probe compiles
            probe = probe_trip_corrected(arch, shape_name, mesh,
                                         zero1=zero1, fsdp=fsdp,
                                         microbatches=microbatches)
            t_probe = time.time() - t0 - t_lower - t_compile

        # All numbers describe the per-device SPMD module, so the roofline
        # terms are per-device numerators over per-chip peaks (n_chips=1);
        # cluster totals are per-device x n_chips.
        flops = probe["flops"]
        bytes_hbm = probe["bytes_hbm"]
        coll_bytes = probe["collective_bytes"]
        terms = roofline_terms(flops, bytes_hbm, coll_bytes, 1)
        mf = _model_flops(cfg, shape)
        rec.update(
            status="OK", kind=aux["kind"],
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            probe_s=round(t_probe, 1),
            flops=flops, bytes_hbm=bytes_hbm,
            collective_bytes=coll_bytes,
            raw_full_compile={
                "flops": float(cost.get("flops", 0.0)),
                "bytes_hbm": float(cost.get("bytes accessed", 0.0)),
                "collective_bytes": coll.total_bytes,
                "collective_counts": coll.count_by_kind,
                "collective_bytes_by_kind": coll.bytes_by_kind,
            },
            probe_points=probe["probe_points"],
            roofline=terms,
            memory={
                "argument_size": getattr(mem, "argument_size_in_bytes", None),
                "output_size": getattr(mem, "output_size_in_bytes", None),
                "temp_size": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_size": getattr(mem, "generated_code_size_in_bytes", None),
            },
            n_chips=n_chips,
            cluster_flops=flops * n_chips,
            model_flops_6nd=mf,
            useful_ratio=(mf / (flops * n_chips) if flops else None),
        )
        if verbose:
            dom = max(terms, key=terms.get)
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
                  f"kind={aux['kind']} compile={t_compile:.0f}s "
                  f"flops/dev={flops:.3e} hbmB={bytes_hbm:.3e} "
                  f"collB={coll_bytes:.3e} dominant={dom} "
                  f"useful={rec['useful_ratio']:.2f}")
    except Exception as e:  # noqa: BLE001 - recorded as FAIL
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: FAIL {e}")
    _write(out_dir, rec)
    return rec


def _model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE); decode D=B tokens."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks  # forward only
    return 2.0 * n * shape.global_batch  # one token per sequence


def _write(out_dir: str, rec: dict) -> None:
    d = Path(out_dir)
    d.mkdir(parents=True, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
    if rec.get("zero1"):
        name += "__zero1"
    if rec.get("fsdp"):
        name += "__fsdp"
    if rec.get("microbatches", 1) > 1:
        name += f"__mb{rec['microbatches']}"
    (d / f"{name}.json").write_text(json.dumps(rec, indent=1))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                           zero1=args.zero1, fsdp=args.fsdp,
                           microbatches=args.microbatches)
            failures += rec["status"] == "FAIL"
    print(f"[dryrun] done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
