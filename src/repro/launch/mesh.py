"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types`` only where the installed jax has it (>= 0.5.x);
    older versions default every axis to Auto anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips).

    Axis order is DCN-outermost: the `pod` axis varies slowest so that
    cross-pod collectives (gradient all-reduce over `pod`+`data`) decompose
    into intra-pod ICI reductions plus one DCN exchange.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_local_mesh():
    """Whatever devices exist locally, as a (data, model) mesh with
    model=1 — used by smoke tests and the CPU examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"), **_axis_type_kwargs(2))
