"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips).

    Axis order is DCN-outermost: the `pod` axis varies slowest so that
    cross-pod collectives (gradient all-reduce over `pod`+`data`) decompose
    into intra-pod ICI reductions plus one DCN exchange.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=types)


def make_local_mesh():
    """Whatever devices exist locally, as a (data, model) mesh with
    model=1 — used by smoke tests and the CPU examples."""
    n = len(jax.devices())
    types = (jax.sharding.AxisType.Auto, jax.sharding.AxisType.Auto)
    return jax.make_mesh((n, 1), ("data", "model"), axis_types=types)
