"""Roofline-term extraction from lowered/compiled artifacts.

``collective_bytes`` is NOT in cost_analysis: we parse the (optimized when
available) HLO text and sum operand bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op.

Hardware model (TPU v5e-class, per chip):
    peak bf16   197e12 FLOP/s
    HBM bw      819e9  B/s
    ICI link    50e9   B/s
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

__all__ = ["HW", "CollectiveStats", "parse_collectives", "roofline_terms"]


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12
    hbm_bw: float = 819e9
    link_bw: float = 50e9


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> int:
    """bytes of one 'dtype[dims]' shape string."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _result_bytes(line: str) -> int:
    """Sum the bytes of the result shape(s) on an HLO op line."""
    eq = line.find("=")
    if eq < 0:
        return 0
    lhs_end = eq
    # result shape appears between '=' and the op name:  %x = f32[...]{...} op-name(
    rhs = line[eq + 1:]
    total = 0
    for m in _SHAPE_RE.finditer(rhs):
        # stop at the op name: shapes before the first alpha token that is
        # the op; simpler: take shapes up to the collective kind keyword
        break
    # robust approach: shapes in the segment before the op keyword
    for kind in _COLLECTIVE_KINDS:
        k = rhs.find(kind)
        if k >= 0:
            seg = rhs[:k]
            for m in _SHAPE_RE.finditer(seg):
                total += _shape_bytes(m.group(0))
            return total
    return 0


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective op in an HLO dump.

    Uses the *result* shape (for all-gather that is the gathered size, for
    reduce-scatter the scattered size) as the per-device traffic proxy.
    `-start` variants are counted; their `-done` halves are skipped so
    nothing is double-counted.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "-done" in ls:
            continue
        for kind in _COLLECTIVE_KINDS:
            tok = f" {kind}" if not ls.startswith(kind) else kind
            if f"{kind}(" in ls or f"{kind}-start(" in ls:
                b = _result_bytes(ls)
                stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b
                stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
                break
    return stats


def roofline_terms(flops: float, bytes_hbm: float, coll_bytes: float,
                   n_chips: int, hw: HW = HW()) -> Dict[str, float]:
    """The three §Roofline terms, in seconds (whole-step, cluster-wide
    numerator over cluster-wide denominator)."""
    return {
        "compute_s": flops / (n_chips * hw.peak_flops),
        "memory_s": bytes_hbm / (n_chips * hw.hbm_bw),
        "collective_s": coll_bytes / (n_chips * hw.link_bw),
    }
