"""Production training launcher.

On a real pod this runs under one process per host with
``jax.distributed.initialize`` (args --coordinator/--num-processes); on CPU
it degrades to the local mesh.  The step itself is the same
``make_train_step`` the dry-run lowers for the 512-chip mesh.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 100 --batch 8 --seq 64 [--reduced] [--zero1] [--microbatches 2]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..models import build_model, get_config
from ..pipeline import PipeFeeder, SyntheticSource
from ..train import CheckpointManager, TrainState, adamw_init, make_train_step
from .mesh import make_local_mesh, make_production_mesh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU)")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (requires 256 devices)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())

    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(params, adamw_init(params))
    start = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr and args.resume:
        try:
            restored, start = mgr.restore(jax.eval_shape(lambda: state))
            state = jax.tree_util.tree_map(jnp.asarray, restored)
            print(f"[launch.train] resumed at step {start}")
        except FileNotFoundError:
            pass

    step_mod = make_train_step(model, mesh, zero1=args.zero1,
                               microbatches=args.microbatches,
                               lr_total=max(args.steps, 100))
    jitted = jax.jit(step_mod.step_fn)

    import threading

    pipe_name = "db://launch-train?query=t0"
    n_rows = (args.steps - start + 1) * args.batch
    feeder = PipeFeeder([pipe_name], batch_size=args.batch,
                        seq_len=args.seq).start()
    threading.Thread(
        target=SyntheticSource(cfg.vocab, args.seq, seed=1).serve,
        args=(pipe_name, n_rows), daemon=True).start()

    step = start
    t0 = time.time()
    with mesh:
        for batch in feeder.batches():
            if step >= args.steps:
                break
            jb = {k: jnp.asarray(v) for k, v in batch.data.items()}
            state, metrics = jitted(state, jb)
            step += 1
            if step % 10 == 0:
                print(f"[launch.train] step {step} "
                      f"loss={float(metrics['loss']):.4f}")
            if mgr and step % args.ckpt_every == 0:
                mgr.save(step, state, blocking=False)
    if mgr:
        mgr.wait()
        mgr.save(step, state)
    dt = time.time() - t0
    print(f"[launch.train] {step - start} steps in {dt:.1f}s "
          f"({(step - start) / max(dt, 1e-9):.2f} steps/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
