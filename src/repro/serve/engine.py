"""Batched serving engine: continuous-batching decode over a shared KV/state
cache, with PipeGen pipes as the request/response transport option.

Small but real: requests are queued, packed into the fixed batch, decoded
step-by-step with the model's ``decode_step`` (greedy or temperature
sampling), and finished sequences are swapped out for queued requests
between steps (continuous batching).  On CPU this serves the reduced
configs; the same code lowers for the production mesh.
"""

from __future__ import annotations

import queue
import threading
import time
import weakref
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import Model

__all__ = ["FeatureView", "ServeEngine", "GenerationResult"]


class FeatureView:
    """A continuously-fresh relation the serving path reads without ever
    reloading it: epochs arrive through a :class:`repro.core.subscribe.
    Subscription` and fold into one ColumnBlock on ``refresh()``.

    The serving loop calls ``refresh()`` between decode steps (cheap:
    non-blocking poll, usually empty), so feature freshness is bounded by
    the publisher's commit cadence, not by any re-export schedule.  When
    the publisher dies the view keeps serving its last image and flags
    ``ended`` — the owner resubscribes at ``watermark`` once the
    publisher is back (the crash-heal path the fault tests exercise).
    """

    def __init__(self, subscription: Any):
        self._sub = subscription
        self.block: Optional[Any] = None    # latest folded ColumnBlock
        self.epoch = 0                      # epoch of that image
        self.refreshes = 0                  # polls that brought new epochs
        self.ended = False

    @property
    def watermark(self) -> int:
        return self._sub.watermark

    def refresh(self) -> int:
        """Drain pending epochs into the view; returns how many applied."""
        if self.ended:
            return 0
        try:
            deltas = self._sub.poll(timeout=0.0)
        except BrokenPipeError:
            self.ended = True
            return 0
        for delta in deltas:
            if delta.kind == "snapshot" or self.block is None:
                self.block = delta.block
            else:
                from ..core.types import ColumnBlock
                self.block = ColumnBlock.concat([self.block, delta.block])
            self.epoch = delta.epoch
        if deltas:
            self.refreshes += 1
        return len(deltas)

    def close(self) -> None:
        self._sub.close()


# One jitted decode step per (model, mesh): engines over the same model reuse
# one compiled executable instead of re-jitting a fresh lambda each time.
# Besides skipping the recompile, this pins determinism — two executables
# compiled from identical HLO may still autotune differently, and a
# low-order-bit logit difference is enough to flip a greedy argmax tie
# (the test_serve_engine_greedy_deterministic flake).
_STEP_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
_STEP_LOCK = threading.Lock()


def _shared_decode_step(model: Model, mesh):
    with _STEP_LOCK:
        per_model = _STEP_CACHE.setdefault(model, {})
        key = id(mesh)  # mesh stays alive via the jitted closure below
        fn = per_model.get(key)
        if fn is None:
            # close over a weakref, not the model: a strong ref from the
            # cached value would pin the weak key forever and leak every
            # model/executable pair for the process lifetime.  Callers of
            # fn (engines) hold the model, so the deref cannot dangle.
            model_ref = weakref.ref(model)
            fn = jax.jit(
                lambda p, c, b: model_ref().decode_step(p, c, b, mesh))
            per_model[key] = fn
        return fn


@dataclass
class GenerationResult:
    request_id: int
    prompt: List[int]
    tokens: List[int] = field(default_factory=list)
    finished: bool = False
    latency_s: float = 0.0


@dataclass
class _Slot:
    request: Optional[GenerationResult] = None
    remaining: int = 0
    t0: float = 0.0


class ServeEngine:
    """Continuous-batching greedy/sampled decoding."""

    def __init__(self, model: Model, params: Any, *, batch_size: int = 4,
                 max_context: int = 256, eos_token: int = 0,
                 temperature: float = 0.0, seed: int = 0, mesh=None):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_context = max_context
        self.eos = eos_token
        self.temperature = temperature
        self.mesh = mesh
        self._rng = jax.random.PRNGKey(seed)
        self._queue: "queue.Queue[GenerationResult]" = queue.Queue()
        self._next_id = 0
        self._slots = [_Slot() for _ in range(batch_size)]
        self.cache = model.init_cache(batch_size, max_context)
        self._tokens = np.zeros((batch_size, 1), np.int32)
        self._step = _shared_decode_step(model, mesh)
        self.steps_run = 0
        self.features: Optional[FeatureView] = None

    def attach_feature_source(self, subscription: Any) -> FeatureView:
        """Serve against a continuously-updated feature relation: wrap the
        subscription in a :class:`FeatureView` refreshed at the top of
        every :meth:`run` iteration (instead of reloading the relation
        per batch).  Returns the view; ``self.features.block`` is the
        current image."""
        self.features = FeatureView(subscription)
        self.features.refresh()
        return self.features

    # -- client API -------------------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int = 16) -> int:
        rid = self._next_id
        self._next_id += 1
        req = GenerationResult(rid, list(prompt))
        req._max_new = max_new_tokens  # type: ignore[attr-defined]
        self._queue.put(req)
        return rid

    def run(self, max_steps: int = 512) -> List[GenerationResult]:
        """Decode until queue + slots drain (or max_steps)."""
        done: List[GenerationResult] = []
        for _ in range(max_steps):
            if self.features is not None:
                self.features.refresh()
            self._fill_slots()
            if not any(s.request for s in self._slots):
                break
            self._decode_one_step(done)
            self.steps_run += 1
        # flush still-running sequences
        for slot in self._slots:
            if slot.request:
                slot.request.finished = False
                done.append(slot.request)
                slot.request = None
        return done

    # -- internals -----------------------------------------------------------------
    def _fill_slots(self) -> None:
        for i, slot in enumerate(self._slots):
            if slot.request is None and not self._queue.empty():
                req = self._queue.get()
                slot.request = req
                slot.remaining = req._max_new  # type: ignore[attr-defined]
                slot.t0 = time.perf_counter()
                # prefill-by-decode: feed prompt tokens one by one (simple,
                # exercises the cache path; production would batch-prefill)
                self._prefill(i, req.prompt)

    def _prefill(self, slot_idx: int, prompt: List[int]) -> None:
        for t in prompt[:-1]:
            self._tokens[slot_idx, 0] = t
            # jnp.array, not asarray: on CPU asarray can alias the numpy
            # buffer zero-copy, and we mutate _tokens again while the
            # async dispatch may still be reading it (a real race --
            # the source of the greedy-determinism flake)
            batch = {"token": jnp.array(self._tokens)}
            _, self.cache = self._step(self.params, self.cache, batch)
        self._tokens[slot_idx, 0] = prompt[-1] if prompt else self.eos

    def _decode_one_step(self, done: List[GenerationResult]) -> None:
        batch = {"token": jnp.array(self._tokens)}
        logits, self.cache = self._step(self.params, self.cache, batch)
        logits = np.asarray(logits[:, 0, :], np.float32)
        if self.temperature > 0:
            self._rng, sub = jax.random.split(self._rng)
            noise = np.asarray(jax.random.gumbel(sub, logits.shape))
            nxt = np.argmax(logits / self.temperature + noise, axis=-1)
        else:
            nxt = np.argmax(logits, axis=-1)
        for i, slot in enumerate(self._slots):
            if slot.request is None:
                continue
            tok = int(nxt[i])
            slot.request.tokens.append(tok)
            slot.remaining -= 1
            self._tokens[i, 0] = tok
            if tok == self.eos or slot.remaining <= 0:
                slot.request.finished = True
                slot.request.latency_s = time.perf_counter() - slot.t0
                done.append(slot.request)
                slot.request = None
