"""Serving: batched KV-cache decode loop."""

from .engine import ServeEngine, GenerationResult

__all__ = ["ServeEngine", "GenerationResult"]
