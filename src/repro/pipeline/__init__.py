"""Input pipeline: PipeGen-fed, double-buffered, straggler-tolerant."""

from .feeder import PipeFeeder, SyntheticSource, EngineSource, BatchQueue

__all__ = ["PipeFeeder", "SyntheticSource", "EngineSource", "BatchQueue"]
