"""Training input pipeline fed through PipeGen data pipes.

The paper's scenario — engine A computes something, engine B consumes it,
no file-system materialization in between — is exactly the
tokenizer/feature-store -> trainer hand-off.  Here the *source* side is a
data engine (or synthetic generator) exporting token blocks, the *consumer*
side is the JAX training loop importing them through a pipe:

    source engine --[DataPipe, arrowcol]--> PipeFeeder --> BatchQueue --> step

Properties the 1000-node posture needs:

* pull-based with a bounded queue: a slow feeder degrades to backpressure,
  never unbounded memory;
* double-buffering: the queue depth (>=2) lets host->device transfer of
  batch N+1 overlap step N;
* straggler hedging: with several sources, a stalled source is dropped
  after ``hedge_timeout`` and its share re-requested from the others;
* deterministic restart: batches carry a monotonically increasing id, and
  ``skip_until`` fast-forwards a restarted trainer to the checkpointed step.
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, List, Optional

import numpy as np

from ..core.datapipe import DataPipeInput, DataPipeOutput, PipeConfig
from ..core.types import ColType, ColumnBlock, Field, Schema

__all__ = ["SyntheticSource", "EngineSource", "PipeFeeder", "BatchQueue"]


@dataclass
class Batch:
    batch_id: int
    data: Dict[str, np.ndarray]


class SyntheticSource:
    """Deterministic token stream (seeded); stands in for the tokenizer."""

    def __init__(self, vocab: int, seq_len: int, seed: int = 0):
        self.vocab = vocab
        self.seq_len = seq_len
        self.seed = seed

    def serve(self, pipe_name: str, n_rows: int,
              config: Optional[PipeConfig] = None) -> None:
        """Export ``n_rows`` sequences through a data pipe (blocking)."""
        rng = np.random.default_rng(self.seed)
        out = DataPipeOutput(pipe_name, config=config or PipeConfig())
        schema = Schema([Field(f"t{i}", ColType.INT64)
                         for i in range(self.seq_len)])
        # feed the pipe the way a decorated engine would: typed rows
        from ..core.astring import AString

        for r in range(n_rows):
            toks = rng.integers(0, self.vocab, self.seq_len)
            parts: List[Any] = []
            for j, t in enumerate(toks):
                if j:
                    parts.append(",")
                parts.append(int(t))
            parts.append("\n")
            out.write(AString(parts))
        out.close()


class EngineSource:
    """Serve batches from a table in one of the mini-DBMS engines."""

    def __init__(self, engine: Any, table: str):
        self.engine = engine
        self.table = table

    def serve(self, pipe_name: str, config: Optional[PipeConfig] = None) -> None:
        from ..core import PipeEnabledEngine, adapter_for
        from ..core.ioredirect import PipeOpenContext

        gp = adapter_for(self.engine)
        with PipeEnabledEngine(gp), PipeOpenContext(config or PipeConfig()):
            self.engine.export_csv(self.table, pipe_name)


class BatchQueue:
    """Bounded prefetch queue (double buffering + backpressure)."""

    def __init__(self, depth: int = 2):
        self._q: "queue.Queue[Optional[Batch]]" = queue.Queue(maxsize=depth)
        self.stalls = 0

    def put(self, b: Optional[Batch]) -> None:
        self._q.put(b)

    def get(self, timeout: float = 60.0) -> Optional[Batch]:
        t0 = time.perf_counter()
        b = self._q.get(timeout=timeout)
        if time.perf_counter() - t0 > 0.05:
            self.stalls += 1
        return b


class PipeFeeder:
    """Consume token rows from one or more data pipes into batches.

    ``sources`` are pipe names to read from; each is drained on its own
    thread.  Rows are assembled into [batch, seq] int32 batches.  A source
    that produces nothing for ``hedge_timeout`` seconds is abandoned
    (straggler mitigation) and the remaining sources cover the demand.
    """

    def __init__(self, pipe_names: List[str], batch_size: int,
                 seq_len: int, *, queue_depth: int = 2,
                 hedge_timeout: float = 30.0, skip_until: int = 0):
        self.pipe_names = pipe_names
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.queue = BatchQueue(queue_depth)
        self.hedge_timeout = hedge_timeout
        self.skip_until = skip_until
        self.rows_dropped = 0
        self.sources_abandoned = 0
        self._row_q: "queue.Queue[Optional[np.ndarray]]" = queue.Queue(
            maxsize=batch_size * max(2, queue_depth) * 4)
        self._threads: List[threading.Thread] = []

    # -- source side ------------------------------------------------------------
    def _drain(self, pipe_name: str) -> None:
        try:
            pipe = DataPipeInput(pipe_name)
            last = time.perf_counter()
            for block in pipe.blocks():
                now = time.perf_counter()
                if now - last > self.hedge_timeout:
                    self.sources_abandoned += 1
                    break
                last = now
                rows = np.asarray(
                    [np.asarray(c) for c in block.columns], dtype=np.int64
                ).T  # [rows, seq]
                for r in rows:
                    self._row_q.put(r.astype(np.int32))
            pipe.close()
        except Exception:
            self.sources_abandoned += 1
        finally:
            self._row_q.put(None)  # source-finished marker

    def start(self) -> "PipeFeeder":
        for name in self.pipe_names:
            t = threading.Thread(target=self._drain, args=(name,), daemon=True)
            t.start()
            self._threads.append(t)
        t = threading.Thread(target=self._assemble, daemon=True)
        t.start()
        self._threads.append(t)
        return self

    def _assemble(self) -> None:
        finished = 0
        batch_id = 0
        rows: List[np.ndarray] = []
        while finished < len(self.pipe_names):
            item = self._row_q.get()
            if item is None:
                finished += 1
                continue
            if len(item) < self.seq_len:
                self.rows_dropped += 1
                continue
            rows.append(item[: self.seq_len])
            if len(rows) == self.batch_size:
                if batch_id >= self.skip_until:
                    tokens = np.stack(rows)
                    labels = np.roll(tokens, -1, axis=1)
                    self.queue.put(Batch(batch_id, {
                        "tokens": tokens, "labels": labels}))
                batch_id += 1
                rows = []
        self.queue.put(None)  # end of stream

    # -- consumer side -------------------------------------------------------------
    def batches(self) -> Iterator[Batch]:
        while True:
            b = self.queue.get()
            if b is None:
                return
            yield b
