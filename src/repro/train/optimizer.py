"""AdamW with decoupled weight decay, built directly on jnp (no optax dep).

Moments are f32 regardless of param dtype (mixed-precision convention).
``zero1`` support lives in the sharding of the moment pytrees (see
``train.step.train_state_specs``), not here — the update is elementwise and
works on whatever shards XLA hands it.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["OptState", "adamw_init", "adamw_update", "lr_schedule"]


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params: Any) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def adamw_update(params: Any, grads: Any, state: OptState, lr: jnp.ndarray,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip: Optional[float] = 1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm_sq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(grads)
    )
    gnorm = jnp.sqrt(gnorm_sq)
    scale = jnp.ones((), jnp.float32)
    if grad_clip is not None:
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))

    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree_util.tree_map(upd, params, grads, state.m, state.v)
    new_params = jax.tree_util.tree_map(
        lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(
        lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(
        lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step, new_m, new_v), {"grad_norm": gnorm}


def lr_schedule(step: jnp.ndarray, *, peak: float = 3e-4, warmup: int = 100,
                total: int = 10_000, floor: float = 3e-5) -> jnp.ndarray:
    """Linear warmup + cosine decay."""
    s = step.astype(jnp.float32)
    warm = peak * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, cos)
