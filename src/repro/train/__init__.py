"""Training substrate: optimizer, schedules, steps, checkpointing, elastic."""

from .optimizer import adamw_init, adamw_update, OptState, lr_schedule
from .step import make_train_step, make_eval_step, TrainState, train_state_specs
from .checkpoint import CheckpointManager

__all__ = [
    "adamw_init", "adamw_update", "OptState", "lr_schedule",
    "make_train_step", "make_eval_step", "TrainState", "train_state_specs",
    "CheckpointManager",
]
