"""Checkpoint/restart over PipeGen data pipes.

Fault-tolerance contract:

* ``save`` snapshots device arrays to host asynchronously (background
  thread), writes one shard file per host plus a step-tagged JSON manifest,
  and only marks the manifest COMPLETE after every shard fsyncs — a restart
  never sees a torn checkpoint.
* ``restore`` picks the newest COMPLETE manifest, tolerating missing/corrupt
  newer ones (crash-mid-save).
* Shard payloads ride the paper's transport: frames are written through the
  same zstd codec the data pipes use, and ``stream_to``/``stream_from`` move
  a whole checkpoint between hosts through a PipeGen socket pipe instead of
  a shared filesystem (the paper's no-materialization idea applied to
  checkpoint migration).
* ``elastic_reshard``: a checkpoint saved on one mesh restores onto another
  (device count change) — arrays are saved unsharded per-leaf and resharded
  on load by the rule engine.
"""

from __future__ import annotations

import io
import json
import os
import struct
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..core.compression import get_codec

__all__ = ["CheckpointManager"]

_MAGIC = b"PGCK1\n"


def _leaf_names(tree: Any) -> Tuple[List[Tuple[str, Any]], Any]:
    """(name, leaf) pairs + treedef; leaves returned as-is (may be shape
    structs on the restore side)."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree_util.tree_structure(tree)
    out = []
    for keypath, leaf in flat:
        name = "/".join(
            str(k.key) if hasattr(k, "key") else str(getattr(k, "idx", k))
            for k in keypath
        )
        out.append((name, leaf))
    return out, treedef


def _flatten(tree: Any) -> Tuple[List[Tuple[str, np.ndarray]], Any]:
    pairs, treedef = _leaf_names(tree)
    return [(n, np.asarray(l)) for n, l in pairs], treedef


class CheckpointManager:
    def __init__(self, directory: str, codec: str = "zstd",
                 keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        if codec == "zstd":
            # zstandard is an optional dependency; fall back to the stdlib
            # dictionary codec on boxes without it
            from ..core.compression import CODECS

            if "zstd" not in CODECS:
                codec = "zip"
        self.codec = codec
        self.keep = keep
        self._pending: Optional[threading.Thread] = None

    # -- write path ----------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True) -> Path:
        """Snapshot to host, then write (optionally async)."""
        leaves, _ = _flatten(tree)  # device->host copy happens here
        if self._pending is not None:
            self._pending.join()  # one in-flight save at a time

        def write():
            self._write(step, leaves)

        if blocking:
            write()
        else:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        return self.dir / f"step_{step:08d}"

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, leaves: List[Tuple[str, np.ndarray]]) -> None:
        codec = get_codec(self.codec)
        d = self.dir / f"step_{step:08d}"
        d.mkdir(parents=True, exist_ok=True)
        shard = d / "shard_0.pgck"
        with open(shard, "wb") as f:
            f.write(_MAGIC)
            for name, arr in leaves:
                payload = codec.compress(arr.tobytes())
                head = json.dumps({
                    "name": name, "dtype": str(arr.dtype),
                    "shape": list(arr.shape), "bytes": len(payload),
                }).encode()
                f.write(struct.pack("<I", len(head)))
                f.write(head)
                f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        manifest = {
            "step": step, "status": "COMPLETE", "codec": self.codec,
            "shards": ["shard_0.pgck"], "time": time.time(),
            "n_leaves": len(leaves),
        }
        mpath = d / "manifest.json"
        tmp = d / "manifest.json.tmp"
        tmp.write_text(json.dumps(manifest))
        os.replace(tmp, mpath)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self._complete_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            d = self.dir / f"step_{s:08d}"
            for fn in d.iterdir():
                fn.unlink()
            d.rmdir()

    # -- read path ------------------------------------------------------------------
    def _complete_steps(self) -> List[int]:
        out = []
        for d in self.dir.glob("step_*"):
            m = d / "manifest.json"
            try:
                doc = json.loads(m.read_text())
                if doc.get("status") == "COMPLETE":
                    out.append(int(doc["step"]))
            except Exception:
                continue  # torn manifest: crash mid-save; skip
        return out

    def latest_step(self) -> Optional[int]:
        steps = self._complete_steps()
        return max(steps) if steps else None

    def restore(self, like: Any, step: Optional[int] = None) -> Tuple[Any, int]:
        """Restore into the structure of ``like`` (reshard-on-load: pass
        sharded shape structs / arrays from any mesh size)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no COMPLETE checkpoint under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        codec = get_codec(manifest["codec"])
        arrays: Dict[str, np.ndarray] = {}
        with open(d / manifest["shards"][0], "rb") as f:
            assert f.read(len(_MAGIC)) == _MAGIC, "bad checkpoint magic"
            while True:
                lenb = f.read(4)
                if not lenb:
                    break
                (hlen,) = struct.unpack("<I", lenb)
                head = json.loads(f.read(hlen))
                payload = f.read(head["bytes"])
                arr = np.frombuffer(
                    codec.decompress(payload), dtype=head["dtype"]
                ).reshape(head["shape"])
                arrays[head["name"]] = arr
        names, treedef = _leaf_names(like)
        leaves = []
        for name, ref in names:
            if name not in arrays:
                raise KeyError(f"checkpoint missing leaf {name!r}")
            arr = arrays[name]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"leaf {name!r} shape {arr.shape} != expected {ref.shape}")
            leaves.append(arr.astype(ref.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves), step

    # -- pipe streaming (checkpoint migration without shared FS) ---------------------
    def stream_to(self, step: int, pipe_name: str) -> None:
        """Send a checkpoint through a PipeGen data pipe (bytes mode)."""
        from ..core.datapipe import DataPipeOutput, PipeConfig

        d = self.dir / f"step_{step:08d}"
        out = DataPipeOutput(pipe_name, config=PipeConfig(mode="bytes", codec="none"))
        manifest = (d / "manifest.json").read_bytes()
        out.write(struct.pack("<I", len(manifest)))
        out.write(manifest)
        payload = (d / "shard_0.pgck").read_bytes()
        out.write(struct.pack("<Q", len(payload)))
        out.write(payload)
        out.close()

    def stream_from(self, pipe_name: str) -> int:
        """Receive a checkpoint from a pipe into this manager's directory."""
        from ..core.datapipe import DataPipeInput

        pipe = DataPipeInput(pipe_name)
        raw = pipe.read_bytes()
        pipe.close()
        (mlen,) = struct.unpack_from("<I", raw, 0)
        manifest = json.loads(raw[4: 4 + mlen])
        off = 4 + mlen
        (plen,) = struct.unpack_from("<Q", raw, off)
        payload = raw[off + 8: off + 8 + plen]
        d = self.dir / f"step_{manifest['step']:08d}"
        d.mkdir(parents=True, exist_ok=True)
        (d / "shard_0.pgck").write_bytes(payload)
        tmp = d / "manifest.json.tmp"
        tmp.write_text(json.dumps(manifest))
        os.replace(tmp, d / "manifest.json")
        return int(manifest["step"])
