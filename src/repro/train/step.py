"""Train / eval step factories.

``make_train_step`` builds the jitted step with explicit in/out shardings:

* params sharded by the rule engine (TP/EP on `model`);
* batch sharded over (`pod`, `data`);
* optimizer moments optionally further sharded over `data` (ZeRO-1) —
  enabled by ``zero1=True``, one of the §Perf memory-term optimizations;
* gradient accumulation via ``lax.scan`` over microbatches;
* optional uint8-compressed cross-pod gradient reduction with error
  feedback (the paper's section 7.4 compression, applied to gradients).

The returned function has signature ``step(state, batch) -> (state, metrics)``.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..distrib.sharding import batch_spec, named_sharding, param_specs
from ..models import Model
from .optimizer import OptState, adamw_init, adamw_update, lr_schedule

__all__ = ["TrainState", "make_train_step", "make_eval_step", "train_state_specs"]


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def _zero1_extend(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Extend a param spec with the batch axes (`pod`+`data` when present)
    on the largest still-unsharded divisible dim (ZeRO moment/param
    sharding).  Falls back to `data` alone if the joint size doesn't
    divide."""
    baxes = tuple(a for a in mesh.axis_names if a != "model")
    if not baxes:
        return spec
    for axes in (baxes, baxes[1:]):
        if not axes:
            continue
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        dims = list(spec) + [None] * (len(shape) - len(spec))
        best, best_size = None, 0
        for i, (dim, cur) in enumerate(zip(shape, dims)):
            if cur is None and dim % size == 0 and dim > best_size:
                best, best_size = i, dim
        if best is not None:
            dims[best] = axes if len(axes) > 1 else axes[0]
            return P(*dims)
    return spec


def train_state_specs(state: TrainState, mesh: Mesh, cfg,
                      zero1: bool = False, fsdp: bool = False) -> TrainState:
    """zero1: optimizer moments additionally sharded over `data`.
    fsdp: parameters too (ZeRO-3) — weights are all-gathered per layer on
    use inside the scan, which is what makes 400B-class training states fit
    16 GB chips (SS:Perf llama4 iterations)."""
    pspecs = param_specs(state.params, mesh, cfg)

    def extended(specs):
        flat_p = jax.tree_util.tree_leaves(state.params)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(state.params),
            [_zero1_extend(s, tuple(p.shape), mesh)
             for p, s in zip(flat_p, flat_s)],
        )

    mspecs = extended(pspecs) if (zero1 or fsdp) else pspecs
    out_pspecs = extended(pspecs) if fsdp else pspecs
    return TrainState(
        params=out_pspecs,
        opt=OptState(step=P(), m=mspecs, v=mspecs),
    )


def make_train_step(
    model: Model,
    mesh: Mesh,
    *,
    microbatches: int = 1,
    zero1: bool = False,
    fsdp: bool = False,
    remat: bool = False,
    lr_peak: float = 3e-4,
    lr_total: int = 10_000,
    donate: bool = True,
) -> Callable:
    """Build the jitted train step (call with a TrainState and a batch)."""
    cfg = model.cfg

    def loss_of(params, mb):
        return model.loss_fn(params, mb, mesh)

    loss_fn = jax.checkpoint(loss_of) if remat else loss_of

    def step_fn(state: TrainState, batch: Dict[str, jnp.ndarray]):
        params = state.params

        if microbatches > 1:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mbs = jax.tree_util.tree_map(split, batch)

            def accum(carry, mb):
                g_acc, loss_acc = carry
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + loss), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(accum, (g0, 0.0), mbs,
                                                unroll=cfg.scan_unroll)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics: Dict[str, jnp.ndarray] = {"loss": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        lr = lr_schedule(state.opt.step, peak=lr_peak, total=lr_total)
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, state.opt, lr)
        metrics = dict(metrics, **opt_metrics, lr=lr)
        return TrainState(new_params, new_opt), metrics

    # shardings
    state_shape = jax.eval_shape(
        lambda rng: TrainState(p := model.init(rng), adamw_init(p)),
        jax.random.PRNGKey(0),
    )
    sspecs = train_state_specs(state_shape, mesh, cfg, zero1=zero1, fsdp=fsdp)
    state_shardings = named_sharding(mesh, sspecs)
    batch_shardings = None  # inferred per-input below at lower time

    def batch_sharding_of(batch_tree):
        def leaf(x):
            nd = len(x.shape)
            if nd >= 2 and x.shape[0] == 3:  # [3,B,S] M-RoPE positions
                inner = batch_spec(mesh, nd - 1, batch_dim=0,
                                   batch_size=x.shape[1])
                bspec = P(None, *tuple(inner))
            else:
                bspec = batch_spec(mesh, nd, batch_size=x.shape[0])
            return NamedSharding(mesh, bspec)
        return jax.tree_util.tree_map(leaf, batch_tree)

    def jitted(batch_shape_tree):
        return jax.jit(
            step_fn,
            in_shardings=(state_shardings, batch_sharding_of(batch_shape_tree)),
            out_shardings=(state_shardings, None),
            donate_argnums=(0,) if donate else (),
        )

    jitted.state_shardings = state_shardings
    jitted.state_specs = sspecs
    jitted.step_fn = step_fn
    return jitted


def make_eval_step(model: Model, mesh: Mesh) -> Callable:
    def eval_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch, mesh)
        return metrics

    return jax.jit(eval_fn)
