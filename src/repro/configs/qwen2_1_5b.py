"""Architecture config: qwen2-1.5b [dense].


Source: arXiv:2407.10671; hf
"""

from ..models.config import get_config
from .common import input_specs as _input_specs, supported_cells, cache_specs_struct
from ..models.config import get_shape

CONFIG = get_config("qwen2-1.5b")
REDUCED = CONFIG.reduced()


def input_specs(shape_name: str):
    return _input_specs(CONFIG, get_shape(shape_name))


def cache_specs(shape_name: str):
    return cache_specs_struct(CONFIG, get_shape(shape_name))


def cells():
    return supported_cells(CONFIG)
