"""Architecture config: llama4-maverick-400b-a17b [moe].

MoE 128e top-1; early-fusion frontend out of scope (text backbone)
Source: hf:meta-llama/Llama-4-Scout-17B-16E (unverified)
"""

from ..models.config import get_config
from .common import input_specs as _input_specs, supported_cells, cache_specs_struct
from ..models.config import get_shape

CONFIG = get_config("llama4-maverick-400b-a17b")
REDUCED = CONFIG.reduced()


def input_specs(shape_name: str):
    return _input_specs(CONFIG, get_shape(shape_name))


def cache_specs(shape_name: str):
    return cache_specs_struct(CONFIG, get_shape(shape_name))


def cells():
    return supported_cells(CONFIG)
