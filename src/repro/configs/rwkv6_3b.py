"""Architecture config: rwkv6-3b [ssm].

Finch: attention-free, data-dependent decay
Source: arXiv:2404.05892; hf
"""

from ..models.config import get_config
from .common import input_specs as _input_specs, supported_cells, cache_specs_struct
from ..models.config import get_shape

CONFIG = get_config("rwkv6-3b")
REDUCED = CONFIG.reduced()


def input_specs(shape_name: str):
    return _input_specs(CONFIG, get_shape(shape_name))


def cache_specs(shape_name: str):
    return cache_specs_struct(CONFIG, get_shape(shape_name))


def cells():
    return supported_cells(CONFIG)
