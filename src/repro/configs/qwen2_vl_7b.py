"""Architecture config: qwen2-vl-7b [vlm].

M-RoPE backbone; vision frontend stubbed (patch embeddings input)
Source: arXiv:2409.12191; hf
"""

from ..models.config import get_config
from .common import input_specs as _input_specs, supported_cells, cache_specs_struct
from ..models.config import get_shape

CONFIG = get_config("qwen2-vl-7b")
REDUCED = CONFIG.reduced()


def input_specs(shape_name: str):
    return _input_specs(CONFIG, get_shape(shape_name))


def cache_specs(shape_name: str):
    return cache_specs_struct(CONFIG, get_shape(shape_name))


def cells():
    return supported_cells(CONFIG)
