"""Per-architecture configs (one module per assigned arch)."""

from ..models.config import ARCHS, SHAPES, get_config, get_shape
from .common import input_specs, cache_specs_struct, supported_cells, skip_reason

ARCH_MODULES = {
    'llama4-maverick-400b-a17b': 'repro.configs.llama4_maverick_400b_a17b',
    'grok-1-314b': 'repro.configs.grok_1_314b',
    'rwkv6-3b': 'repro.configs.rwkv6_3b',
    'qwen2-vl-7b': 'repro.configs.qwen2_vl_7b',
    'stablelm-12b': 'repro.configs.stablelm_12b',
    'smollm-360m': 'repro.configs.smollm_360m',
    'qwen2.5-14b': 'repro.configs.qwen2_5_14b',
    'qwen2-1.5b': 'repro.configs.qwen2_1_5b',
    'whisper-large-v3': 'repro.configs.whisper_large_v3',
    'zamba2-7b': 'repro.configs.zamba2_7b',
}
