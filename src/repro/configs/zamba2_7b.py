"""Architecture config: zamba2-7b [hybrid].

Mamba2 backbone + one shared attention block applied every 6 layers
Source: arXiv:2411.15242 (unverified)
"""

from ..models.config import get_config
from .common import input_specs as _input_specs, supported_cells, cache_specs_struct
from ..models.config import get_shape

CONFIG = get_config("zamba2-7b")
REDUCED = CONFIG.reduced()


def input_specs(shape_name: str):
    return _input_specs(CONFIG, get_shape(shape_name))


def cache_specs(shape_name: str):
    return cache_specs_struct(CONFIG, get_shape(shape_name))


def cells():
    return supported_cells(CONFIG)
