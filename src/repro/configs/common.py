"""Shared input-spec machinery for the per-architecture config modules.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of that (architecture x shape) cell — weak-type-correct,
shardable, zero allocation — exactly what ``launch.dryrun`` lowers against.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..models import build_model
from ..models.config import ModelConfig, ShapeSpec, get_shape

__all__ = ["input_specs", "cache_specs_struct", "supported_cells", "skip_reason"]

S = jax.ShapeDtypeStruct


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the cell runs; otherwise why it is skipped by design."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full quadratic attention at 524288 context; no sub-quadratic "
                "variant claimed for this architecture (DESIGN.md "
                "SS:Arch-applicability)")
    return None


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Batch input ShapeDtypeStructs for one cell."""
    B, Sq = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            return {
                "embeds": S((B, Sq, cfg.d_model), jnp.bfloat16),
                "positions": S((3, B, Sq), jnp.int32),
                "labels": S((B, Sq), jnp.int32),
            }
        if cfg.is_encdec:
            return {
                "frames": S((B, Sq, cfg.d_model), jnp.bfloat16),
                "tokens": S((B, Sq), jnp.int32),
                "labels": S((B, Sq), jnp.int32),
            }
        return {
            "tokens": S((B, Sq), jnp.int32),
            "labels": S((B, Sq), jnp.int32),
        }
    # decode: one new token against a cache of Sq
    if cfg.family == "vlm":
        return {"embed": S((B, 1, cfg.d_model), jnp.bfloat16)}
    return {"token": S((B, 1), jnp.int32)}


def cache_specs_struct(cfg: ModelConfig, shape: ShapeSpec) -> Any:
    """ShapeDtypeStructs of the decode cache for one cell."""
    model = build_model(cfg)
    if cfg.is_encdec:
        return jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                     enc_len=1500))
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))


def supported_cells(cfg: ModelConfig) -> Dict[str, Optional[str]]:
    """shape-name -> skip reason (None = runs)."""
    from ..models.config import SHAPES

    return {name: skip_reason(cfg, spec) for name, spec in SHAPES.items()}
