"""Architecture config: whisper-large-v3 [audio].

enc-dec; conv frontend stubbed (precomputed frame embeddings)
Source: arXiv:2212.04356 (unverified)
"""

from ..models.config import get_config
from .common import input_specs as _input_specs, supported_cells, cache_specs_struct
from ..models.config import get_shape

CONFIG = get_config("whisper-large-v3")
REDUCED = CONFIG.reduced()


def input_specs(shape_name: str):
    return _input_specs(CONFIG, get_shape(shape_name))


def cache_specs(shape_name: str):
    return cache_specs_struct(CONFIG, get_shape(shape_name))


def cells():
    return supported_cells(CONFIG)
