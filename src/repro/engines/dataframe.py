"""dataframe — the Spark analog: distributed collection of row dicts.

Internal representation: a list of dicts (an RDD of Rows).  CSV without
header; JSON is document-per-line produced through the external
:mod:`repro.engines.jsonlib` streaming library (the Jackson stand-in), which
makes this engine the library-extension example (section 5.2): FormOpt swaps
``JsonGenerator``/``JsonParser`` for their PipeGen-aware ``A*`` subtypes via
the ``json_generator_cls``/``json_parser_cls`` hooks — the Python rendering
of replacing the library instantiation call site.

Also carries the ``map``/``group_by``/PIC-clustering surface used by the
astronomy example (section 2).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Type

import numpy as np

from ..core.types import ColType, ColumnBlock, Field, RowBlock, Schema
from .base import Engine, EngineWriter
from .jsonlib import AJsonGenerator, AJsonParser, JsonGenerator, JsonParser

__all__ = ["DataFrame"]


class DataFrame(Engine):
    name = "dataframe"
    csv_delimiter = ","
    writes_header = False
    supports_json = True
    json_flavor = "per-line"

    # library-extension hooks: codegen swaps these for the A* subtypes
    json_generator_cls: Type[JsonGenerator] = JsonGenerator
    json_parser_cls: Type[JsonParser] = JsonParser

    def __init__(self, workers: int = 4, decorated: bool = True):
        super().__init__(workers=workers, decorated=decorated)
        self._rdds: Dict[str, List[dict]] = {}

    # -- rdd <-> block ----------------------------------------------------------
    def put_block(self, table: str, block: ColumnBlock) -> None:
        super().put_block(table, block)
        rb = block.to_rows()
        names = rb.schema.names
        self._rdds[table] = [dict(zip(names, r)) for r in rb.rows]

    def rdd(self, table: str) -> List[dict]:
        return self._rdds.get(table, [])

    # -- JSON via the external library (section 5.2) ------------------------------
    def export_json(self, table: str, filename: str) -> None:
        block = self.get_block(table)
        rb = block.to_rows()
        names = rb.schema.names
        w = EngineWriter(open(filename, "w"))  # IORedirect call site
        g = self.json_generator_cls(w)
        try:
            for row in rb.rows:
                g.start_object()
                for nm, v in zip(names, row):
                    g.field(nm, v)
                g.end_object()
        finally:
            w.close()

    def import_json(self, table: str, filename: str) -> None:
        stream = open(filename, "r")  # IORedirect call site
        p = self.json_parser_cls()
        try:
            docs = list(p.parse_lines(stream))
        finally:
            stream.close()
        if not docs:
            self.put_block(table, ColumnBlock(Schema([]), []))
            return
        names = list(docs[0].keys())
        rows = [tuple(d.get(n) for n in names) for d in docs]
        from ..core.types import infer_schema

        self._store_imported(table, rows, names, infer_schema(rows[0], names))

    # -- RDD surface for the examples ----------------------------------------------
    def map_rows(self, table: str, out: str, fn: Callable[[dict], dict]) -> None:
        rows = [fn(dict(r)) for r in self.rdd(table)]
        if not rows:
            return
        names = list(rows[0].keys())
        tuples = [tuple(r[n] for n in names) for r in rows]
        from ..core.types import infer_schema

        self.put_block(out, RowBlock(infer_schema(tuples[0], names), tuples).to_columns())

    def power_iteration_clustering(
        self, table: str, src: str, dst: str, weight: str,
        k: int = 2, iters: int = 20, seed: int = 0,
    ) -> Dict[int, int]:
        """PIC [Lin & Cohen, ICML'10] over an affinity edge list — the
        algorithm the astronomer borrows Spark for (sections 1-2)."""
        block = self.get_block(table)
        s = np.asarray(block.column(src), dtype=np.int64)
        d = np.asarray(block.column(dst), dtype=np.int64)
        w = np.asarray(block.column(weight), dtype=np.float64)
        ids = np.unique(np.concatenate([s, d]))
        idx = {v: i for i, v in enumerate(ids.tolist())}
        n = len(ids)
        si = np.array([idx[v] for v in s.tolist()])
        di = np.array([idx[v] for v in d.tolist()])
        # symmetric affinity, row-normalized power iteration
        deg = np.zeros(n)
        np.add.at(deg, si, w)
        np.add.at(deg, di, w)
        deg[deg == 0] = 1.0
        rng = np.random.default_rng(seed)
        v = rng.random(n)
        v /= np.abs(v).sum()
        for _ in range(iters):
            nv = np.zeros(n)
            np.add.at(nv, si, w * v[di])
            np.add.at(nv, di, w * v[si])
            nv /= deg
            norm = np.abs(nv).sum()
            if norm == 0:
                break
            v = nv / norm
        # k-means (1-D) on the embedding
        cents = np.quantile(v, np.linspace(0, 1, k + 2)[1:-1])
        for _ in range(10):
            assign = np.argmin(np.abs(v[:, None] - cents[None, :]), axis=1)
            for c in range(k):
                sel = v[assign == c]
                if len(sel):
                    cents[c] = sel.mean()
        assign = np.argmin(np.abs(v[:, None] - cents[None, :]), axis=1)
        return {int(ids[i]): int(assign[i]) for i in range(n)}

    def unit_json_roundtrip_test(self, export_path: str, import_path: str) -> None:
        from .base import assert_blocks_equal, make_paper_block

        block = make_paper_block(64, seed=13)
        self.put_block("jrt", block)
        self.export_json("jrt", export_path)
        self.import_json("jrt_in", import_path)
        assert_blocks_equal(block, self.get_block("jrt_in"))
