"""Five mini-DBMS analogs (paper section 7's evaluation set).

Each engine has a real file import/export path and its own unit tests; the
PipeGen compile loop (capture → codegen → verify) turns those paths into
data pipes without the engines knowing about sockets.
"""

from typing import Dict, Type

from .base import Engine, EngineWriter, assert_blocks_equal, make_paper_block
from .colstore import ColStore
from .dataframe import DataFrame
from .graphstore import GraphStore
from .mapreduce import MapReduce
from .rowstore import RowStore

ENGINES: Dict[str, Type[Engine]] = {
    "rowstore": RowStore,
    "colstore": ColStore,
    "graphstore": GraphStore,
    "mapreduce": MapReduce,
    "dataframe": DataFrame,
}


def make_engine(name: str, **kw) -> Engine:
    try:
        return ENGINES[name](**kw)
    except KeyError:
        raise ValueError(f"unknown engine {name!r}; have {sorted(ENGINES)}") from None


__all__ = [
    "Engine",
    "EngineWriter",
    "ENGINES",
    "make_engine",
    "make_paper_block",
    "assert_blocks_equal",
    "RowStore",
    "ColStore",
    "GraphStore",
    "MapReduce",
    "DataFrame",
]
