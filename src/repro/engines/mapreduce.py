"""mapreduce — the Hadoop analog: tab-delimited key/value engine.

Quirks reproduced from the paper:

* tab is the default delimiter (section 5.3.1's non-comma example);
* the import path *sniffs* the file head for sequence-file magic before
  deciding how to parse — the read/rewind probe HDFS clients perform, which
  is why ``DataPipeInput`` supports a bounded peek (section 6.1);
* a simple binary "seqfile" surface stands in for Hadoop sequence files,
  the shared-binary-format case of section 5 (Spark↔Giraph via sequence
  files) exercised by tests.
"""

from __future__ import annotations

import struct
from typing import List, Optional

import numpy as np

from ..core.types import ColType, ColumnBlock, Field, RowBlock, Schema
from .base import Engine, EngineWriter

__all__ = ["MapReduce", "SEQ_MAGIC"]

SEQ_MAGIC = "SEQ6"


class MapReduce(Engine):
    name = "mapreduce"
    csv_delimiter = "\t"
    writes_header = False
    supports_json = True
    json_flavor = "per-line"

    def __init__(self, workers: int = 4, decorated: bool = True):
        super().__init__(workers=workers, decorated=decorated)

    # -- sniffing import (read/rewind probe) -------------------------------------
    def import_csv(self, table: str, filename: str,
                   schema: Optional[Schema] = None) -> None:
        stream = open(filename, "r")  # IORedirect call site
        try:
            head = _peek(stream, len(SEQ_MAGIC))
            if head == SEQ_MAGIC:
                if hasattr(stream, "read_bytes"):
                    # a data pipe cannot be reopened; drain it as bytes
                    return self._parse_seqfile(table, stream.read_bytes())
                stream.close()
                return self.import_seqfile(table, filename)
            rows, names = self._read_delimited(stream, self.csv_delimiter, schema)
        finally:
            try:
                stream.close()
            except Exception:
                pass
        self._store_imported(table, rows, names, schema)

    # -- sequence-file analog (shared binary format) --------------------------------
    def export_seqfile(self, table: str, filename: str) -> None:
        block = self.get_block(table)
        rb = block.to_rows()
        f = open(filename, "wb")  # IORedirect call site (binary)
        try:
            f.write(SEQ_MAGIC.encode())
            import json as _json

            sdoc = _json.dumps(block.schema.to_dict()).encode()
            f.write(struct.pack("<I", len(sdoc)))
            f.write(sdoc)
            f.write(struct.pack("<I", len(rb.rows)))
            for row in rb.rows:
                for v, fld in zip(row, block.schema):
                    if fld.type is ColType.STRING:
                        b = str(v).encode()
                        f.write(struct.pack("<I", len(b)))
                        f.write(b)
                    elif fld.type in (ColType.FLOAT32, ColType.FLOAT64):
                        f.write(struct.pack("<d", float(v)))
                    else:
                        f.write(struct.pack("<q", int(v)))
        finally:
            f.close()

    # -- unit tests: the capture phase must see EVERY surface a user wants
    #    piped (paper section 3.2's "tests fully exercise the code"), so the
    #    Hadoop analog's tests cover the seqfile path too -------------------------
    def unit_export_test(self, path: str) -> None:
        super().unit_export_test(path)
        from ..core.datapipe import is_reserved

        if not is_reserved(path):
            self.export_seqfile("unit", path + ".seq")

    def unit_import_test(self, path: str) -> None:
        super().unit_import_test(path)
        from ..core.datapipe import is_reserved

        if not is_reserved(path):
            self.import_seqfile("unit_seq", path + ".seq")
            assert len(self.get_block("unit_seq")) == 64

    def import_seqfile(self, table: str, filename: str) -> None:
        f = open(filename, "rb")  # IORedirect call site (binary)
        try:
            data = f.read()
        finally:
            f.close()
        self._parse_seqfile(table, data)

    def _parse_seqfile(self, table: str, data: bytes) -> None:
        assert data[: len(SEQ_MAGIC)].decode() == SEQ_MAGIC, "bad seqfile magic"
        off = len(SEQ_MAGIC)
        import json as _json

        (slen,) = struct.unpack_from("<I", data, off)
        off += 4
        schema = Schema.from_dict(_json.loads(data[off : off + slen]))
        off += slen
        (nrows,) = struct.unpack_from("<I", data, off)
        off += 4
        rows: List[tuple] = []
        for _ in range(nrows):
            row = []
            for fld in schema:
                if fld.type is ColType.STRING:
                    (ln,) = struct.unpack_from("<I", data, off)
                    off += 4
                    row.append(data[off : off + ln].decode())
                    off += ln
                elif fld.type in (ColType.FLOAT32, ColType.FLOAT64):
                    (v,) = struct.unpack_from("<d", data, off)
                    off += 8
                    row.append(v)
                else:
                    (v,) = struct.unpack_from("<q", data, off)
                    off += 8
                    row.append(v)
            rows.append(tuple(row))
        self.put_block(table, RowBlock(schema, rows).to_columns())


def _peek(stream, n: int) -> str:
    """Read ``n`` chars then push them back — works on real files (seek) and
    on data pipes (bounded unread buffer)."""
    if hasattr(stream, "unread"):
        head = stream.read(n)
        stream.unread(head)
        return head
    pos = stream.tell()
    head = stream.read(n)
    stream.seek(pos)
    return head
