"""Minimal streaming JSON library — the Jackson analog (paper section 5.2).

``JsonGenerator`` is the external library an engine (dataframe/Spark) uses
to serialize JSON: it builds *character* output through a writer.
``AJsonGenerator`` is the PipeGen-aware subtype FormOpt substitutes in
library-extension mode: same API, but it emits AStrings whose parts keep
keys and primitive values un-stringified, so the data pipe receives typed
values and the JsonAssembler can strip structural text and redundant keys
(sections 5.2/5.3.2).

``JsonParser`` is the import-side counterpart; its PipeGen-aware subtype
``AJsonParser`` consumes AString lines from a pipe and yields dicts without
character parsing when typed parts are available.
"""

from __future__ import annotations

import json
from typing import Any, Dict, Iterator, List, Optional

from ..core.astring import AString

__all__ = ["JsonGenerator", "AJsonGenerator", "JsonParser", "AJsonParser"]


class JsonGenerator:
    """Document-per-line streaming generator (Spark-flavored)."""

    def __init__(self, writer: Any):
        self.writer = writer
        self._first_field = True

    # -- structural -------------------------------------------------------------
    def start_object(self) -> None:
        self.writer.write("{")
        self._first_field = True

    def end_object(self) -> None:
        self.writer.write("}\n")

    # -- fields -----------------------------------------------------------------
    def field(self, name: str, value: Any) -> None:
        if not self._first_field:
            self.writer.write(", ")
        self._first_field = False
        self.writer.write('"' + name + '": ')
        self.write_value(value)

    def write_value(self, value: Any) -> None:
        if isinstance(value, bool):
            self.writer.write("true" if value else "false")
        elif isinstance(value, (int, float)):
            self.writer.write(repr(value) if isinstance(value, float) else str(value))
        elif value is None:
            self.writer.write("null")
        else:
            self.writer.write(json.dumps(str(value)))

    def flush(self) -> None:
        if hasattr(self.writer, "flush"):
            self.writer.flush()


class AJsonGenerator(JsonGenerator):
    """PipeGen-aware subtype: identical call surface, AString output."""

    def start_object(self) -> None:
        self.writer.write(AString.literal("{"))
        self._first_field = True

    def end_object(self) -> None:
        self.writer.write(AString.literal("}\n"))

    def field(self, name: str, value: Any) -> None:
        if not self._first_field:
            self.writer.write(AString.literal(", "))
        self._first_field = False
        self.writer.write(AString.literal('"') + AString.of(name) + AString.literal('": '))
        self.write_value(value)

    def write_value(self, value: Any) -> None:
        if isinstance(value, (bool, int, float)):
            self.writer.write(AString.of(value))  # typed part: FormOpt's win
        elif value is None:
            self.writer.write(AString.literal("null"))
        else:
            self.writer.write(
                AString.literal('"') + AString.of(str(value)) + AString.literal('"')
            )


class JsonParser:
    """Import side: parse document-per-line JSON text into dicts."""

    def parse_lines(self, stream: Any) -> Iterator[Dict[str, Any]]:
        for line in stream:
            line = str(line).strip()
            if line:
                yield json.loads(line)


class AJsonParser(JsonParser):
    """PipeGen-aware subtype: prefers the pipe's typed AString lines."""

    def parse_lines(self, stream: Any) -> Iterator[Dict[str, Any]]:
        astr_iter = getattr(stream, "astring_lines", None)
        if astr_iter is None:
            yield from super().parse_lines(stream)
            return
        for astr in astr_iter():
            d: Dict[str, Any] = {}
            # typed fast path: reconstruct the dict from parts if each cell is
            # a sole typed value; otherwise fall back to character parsing
            if _parts_are_typed_row(astr):
                names = getattr(stream, "schema", None)
                cells = astr.split(str(stream.meta.get("delimiter") or ","))
                for f, c in zip(names, cells):
                    d[f.name] = c.sole_value
                yield d
            else:
                s = str(astr).strip()
                if s:
                    yield json.loads(s)


def _parts_are_typed_row(astr: AString) -> bool:
    return any(not isinstance(p, str) for p in astr.parts)
