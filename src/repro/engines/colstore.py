"""colstore — the Myria analog: parallel shared-nothing columnar engine.

Internal representation: one numpy array (or string list) per column.  CSV
without header; JSON export is a *single document* (array of objects) that
the engine serializes directly via string concatenation — no external
library — making it the paper's string-decoration example (section 5.1;
"the Myria DBMS directly implements its JSON export functionality").
"""

from __future__ import annotations

import json
from typing import Optional

from ..core.astring import AString
from ..core.types import ColType, ColumnBlock, RowBlock, Schema
from .base import Engine, EngineWriter

__all__ = ["ColStore"]


class ColStore(Engine):
    name = "colstore"
    csv_delimiter = ","
    writes_header = False
    supports_json = True
    json_flavor = "single-document"

    def __init__(self, workers: int = 4, decorated: bool = True):
        super().__init__(workers=workers, decorated=decorated)

    # -- directly-implemented JSON serialization (string decoration target) -----
    def export_json(self, table: str, filename: str) -> None:
        block = self.get_block(table)
        rb = block.to_rows()
        names = rb.schema.names
        stream = EngineWriter(open(filename, "w"))  # IORedirect call site
        try:
            stream.write(self._lit("["))
            for i, row in enumerate(rb.rows):
                if i:
                    stream.write(self._lit(", "))
                doc = self._lit("{")
                for j, (nm, v) in enumerate(zip(names, row)):
                    if j:
                        doc = doc + self._lit(", ")
                    doc = doc + self._lit('"') + self._s(nm) + self._lit('": ')
                    if isinstance(v, str):
                        doc = doc + self._lit('"') + self._s(v) + self._lit('"')
                    else:
                        doc = doc + self._s(v)
                doc = doc + self._lit("}")
                stream.write(doc)
            stream.write(self._lit("]"))
        finally:
            stream.close()

    def import_json(self, table: str, filename: str) -> None:
        stream = open(filename, "r")  # IORedirect call site
        try:
            blocks_iter = getattr(stream, "blocks", None)
            if (self.decorated and blocks_iter is not None
                    and getattr(stream, "mode", "text") not in ("text", "parts")):
                # typed fast path: consume pipe blocks directly
                blocks = list(blocks_iter())
                if blocks:
                    self.put_block(table, ColumnBlock.concat(blocks))
                else:
                    self.put_block(table, ColumnBlock(Schema([]), []))
                return
            docs = json.loads(stream.read() or "[]")
        finally:
            stream.close()
        if not docs:
            self.put_block(table, ColumnBlock(Schema([]), []))
            return
        names = list(docs[0].keys())
        rows = [tuple(d.get(n) for n in names) for d in docs]
        from ..core.types import infer_schema

        schema = infer_schema(rows[0], names)
        self._store_imported(table, rows, names, schema)

    # -- columnar niceties for the examples ---------------------------------------
    def column(self, table: str, name: str):
        return self.get_block(table).column(name)

    def unit_json_roundtrip_test(self, export_path: str, import_path: str) -> None:
        from .base import assert_blocks_equal, make_paper_block

        block = make_paper_block(64, seed=11)
        self.put_block("jrt", block)
        self.export_json("jrt", export_path)
        self.import_json("jrt_in", import_path)
        assert_blocks_equal(block, self.get_block("jrt_in"))
