"""Engine base class: a mini shared-nothing DBMS analog with real file
import/export, the substrate PipeGen operates on.

Five engines subclass this (paper section 7's evaluation set):

    rowstore   Derby analog   single-node relational, CSV only, header row
    colstore   Myria analog   parallel columnar, CSV + single-doc JSON
    graphstore Giraph analog  vertex/edge store, CSV + JSON adjacency
    mapreduce  Hadoop analog  tab-delimited KV, header-probing import
    dataframe  Spark analog   row dicts, CSV + JSON-lines via jsonlib

Decoration contract (FormOpt, Algorithm 1): every text serializer builds
its output through ``self._s(value)`` (stringify), string ``+`` and
``self._sep()`` / ``self._nl()`` literals, and parses through
``self._parse_int/float/bool``.  With ``decorated=False`` these are the
plain ``str``/``int``/``float`` expressions an unmodified engine would
contain; the generated adapter flips ``decorated=True``, which substitutes
``AString`` expressions at exactly those sites — the Python rendering of
the paper's bytecode rewrite.  The serializer control flow is identical in
both modes, so unit tests validate the decorated path against the plain
one byte-for-byte.
"""

from __future__ import annotations

import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from ..core.astring import AString
from ..core.datapipe import is_reserved
from ..core.types import ColType, ColumnBlock, Field, RowBlock, Schema

__all__ = ["Engine", "EngineWriter", "make_paper_block", "assert_blocks_equal"]


class EngineWriter:
    """Stream adapter (paper section 6's Output/InputStreamWriter overloads):
    forwards AStrings intact when the underlying stream is a data pipe,
    materializes them for real files."""

    def __init__(self, f: Any):
        self.f = f
        self._pipe_aware = hasattr(f, "pipe") or hasattr(f, "astring_lines")

    def write(self, s: Any) -> int:
        if self._pipe_aware or isinstance(s, str):
            return self.f.write(s)
        return self.f.write(str(s))

    def flush(self) -> None:
        self.f.flush()

    def close(self) -> None:
        self.f.close()


def make_paper_block(n: int = 1000, seed: int = 0, strings: bool = False) -> ColumnBlock:
    """The paper's benchmark schema (section 7): a unique int key in [0, n)
    followed by three (int in [0, n), double ~ N(0,1)) pairs.  With
    ``strings=True`` the doubles become short strings (fig. 10's string
    datatype row)."""
    rng = np.random.default_rng(seed)
    cols: List[Any] = [np.arange(n, dtype=np.int64)]
    fields = [Field("key", ColType.INT64)]
    for i in range(3):
        fields.append(Field(f"ref{i}", ColType.INT64))
        cols.append(rng.integers(0, max(n, 1), n, dtype=np.int64))
        if strings:
            fields.append(Field(f"val{i}", ColType.STRING))
            cols.append([f"v{x:016d}" for x in rng.integers(0, 1 << 40, n)])
        else:
            fields.append(Field(f"val{i}", ColType.FLOAT64))
            cols.append(rng.standard_normal(n))
    return ColumnBlock(Schema(fields), cols)


def assert_blocks_equal(a: ColumnBlock, b: ColumnBlock, float_text: bool = True,
                        check_names: bool = True) -> None:
    if check_names:
        assert a.schema.names == b.schema.names, (a.schema, b.schema)
    assert len(a) == len(b), (len(a), len(b))
    for f, ca, cb in zip(a.schema, a.columns, b.columns):
        if f.type is ColType.STRING:
            assert list(ca) == list(cb), f"column {f.name} mismatch"
        elif f.type in (ColType.FLOAT32, ColType.FLOAT64):
            np.testing.assert_allclose(np.asarray(ca, float), np.asarray(cb, float),
                                       rtol=0, atol=0)
        else:
            np.testing.assert_array_equal(np.asarray(ca), np.asarray(cb))


class Engine:
    """Base mini-DBMS.  Subclasses override the internal representation and
    the file format surface; the decoration hooks live here."""

    name = "engine"
    csv_delimiter = ","
    writes_header = False
    supports_json = False

    def __init__(self, workers: int = 1, decorated: bool = True):
        self.workers = workers
        self.decorated = decorated
        self._tables: Dict[str, ColumnBlock] = {}
        self._lock = threading.Lock()
        self._append_hooks: Dict[str, List[Any]] = {}

    # -- storage API (engine-internal representation is subclass business) ----
    def put_block(self, table: str, block: ColumnBlock) -> None:
        with self._lock:
            self._tables[table] = block

    def get_block(self, table: str) -> ColumnBlock:
        return self._tables[table]

    def drop(self, table: str) -> None:
        self._tables.pop(table, None)

    @property
    def tables(self) -> List[str]:
        return sorted(self._tables)

    # -- delta capture (continuous pipes, repro.core.subscribe) ----------------
    def append(self, table: str, block: ColumnBlock) -> ColumnBlock:
        """Extend ``table`` with ``block`` and hand the delta to every
        :meth:`on_append` listener — the change-capture source a
        :class:`repro.core.subscribe.Publication` commits epochs from.
        Listeners run *after* the table lock is released (a listener is
        free to read the engine or commit to a publication)."""
        with self._lock:
            cur = self._tables.get(table)
            if cur is None or not len(cur):
                self._tables[table] = block
            else:
                if cur.schema.names != block.schema.names:
                    raise ValueError(
                        f"append to {table!r}: schema mismatch "
                        f"({cur.schema.names} vs {block.schema.names})")
                self._tables[table] = ColumnBlock.concat([cur, block])
            hooks = list(self._append_hooks.get(table, ()))
        for fn in hooks:
            fn(table, block)
        return block

    def on_append(self, table: str, fn: Any) -> Any:
        """Register ``fn(table, delta_block)`` to observe appends; returns
        an unsubscribe callable."""
        with self._lock:
            self._append_hooks.setdefault(table, []).append(fn)

        def _unhook() -> None:
            with self._lock:
                hooks = self._append_hooks.get(table)
                if hooks and fn in hooks:
                    hooks.remove(fn)

        return _unhook

    # -- decoration hooks (Algorithm 1 substitution points) --------------------
    def _s(self, v: Any):
        """Stringify-for-output; the decorated form defers (AString)."""
        return AString.of(v) if self.decorated else _plain_str(v)

    def _lit(self, s: str):
        return AString.literal(s) if self.decorated else s

    def _sep(self):
        return self._lit(self.csv_delimiter)

    def _nl(self):
        return self._lit("\n")

    def _parse_int(self, v: Any) -> int:
        return AString.parse_int(v) if self.decorated else int(str(v))

    def _parse_float(self, v: Any) -> float:
        return AString.parse_float(v) if self.decorated else float(str(v))

    def _parse_bool(self, v: Any) -> bool:
        return AString.parse_bool(v) if self.decorated else str(v).lower() == "true"

    def _parse_cell(self, v: Any, t: ColType) -> Any:
        if t is ColType.STRING:
            return str(v)
        if t is ColType.BOOL:
            return self._parse_bool(v)
        if t in (ColType.FLOAT32, ColType.FLOAT64):
            return self._parse_float(v)
        return self._parse_int(v)

    # -- CSV surface (every engine has one; delimiter varies) -------------------
    def export_csv(self, table: str, filename: str,
                   header: Optional[bool] = None,
                   delimiter: Optional[str] = None) -> None:
        """Serialize ``table`` to ``filename`` one line at a time through
        string concatenation — the paper's fig. 8(a) shape.  ``header`` and
        ``delimiter`` override the engine convention (a cross-engine transfer
        matches the destination's dialect, the way a user would configure the
        export — e.g. TSV when the destination is the Hadoop analog)."""
        block = self.get_block(table)
        write_header = self.writes_header if header is None else header
        sep = self._lit(delimiter) if delimiter is not None else self._sep()
        raw = open(filename, "w")  # IORedirect target call site
        pipe = getattr(raw, "pipe", None)
        if (
            self.decorated
            and pipe is not None
            and getattr(pipe, "accepts_blocks", None)
            and pipe.accepts_blocks()
        ):
            # exporter-side typed fast path (the twin of
            # _import_typed_blocks): hand the pipe whole ColumnBlocks --
            # no per-row text serialization, no AString assembly
            try:
                pipe.write_block(
                    block,
                    header=list(block.schema.names) if write_header else None,
                    delimiter=str(sep),
                )
            finally:
                raw.close()
            return
        rb = block.to_rows()
        stream = EngineWriter(raw)
        try:
            if write_header:
                line = self._lit("")
                for j, f in enumerate(rb.schema):
                    if j:
                        line = line + sep
                    line = line + self._s(f.name)
                stream.write(line + self._nl())
            for row in rb.rows:
                line = self._lit("")
                for j, v in enumerate(row):
                    if j:
                        line = line + sep
                    line = line + self._s(v)
                stream.write(line + self._nl())
        finally:
            stream.close()

    def import_csv(self, table: str, filename: str,
                   schema: Optional[Schema] = None) -> None:
        stream = open(filename, "r")  # IORedirect target call site
        try:
            if schema is None and self._import_typed_blocks(table, stream):
                return
            rows, names = self._read_delimited(stream, self.csv_delimiter, schema)
        finally:
            stream.close()
        self._store_imported(table, rows, names, schema)

    def _import_typed_blocks(self, table: str, stream) -> bool:
        """PipeGen fast path: when the stream is a data pipe carrying typed
        blocks, consume ColumnBlocks wholesale — zero per-row text work
        (the paper's 'directly consumes the intermediate binary
        representation').  Returns False for real files / text-rung pipes."""
        blocks_iter = getattr(stream, "blocks", None)
        if not self.decorated or blocks_iter is None:
            return False
        if getattr(stream, "mode", "text") in ("text", "parts"):
            return False  # character/parts rungs keep the parsing semantics
        blocks = list(blocks_iter())
        if len(blocks) == 1:
            # zero-copy retention: arena-backed columns stay leased for as
            # long as the table holds them (the pool recycles a store only
            # when its array is collected), so no defensive copy is needed
            merged = blocks[0]
        elif blocks:
            merged = ColumnBlock.concat(blocks)
        else:
            merged = ColumnBlock(Schema([]), [])
        hdr = stream.meta.get("header") if getattr(stream, "meta", None) else None
        if self.writes_header and hdr and len(hdr) == len(merged.schema):
            names = list(hdr)
        else:
            names = [f"column{i + 1}" for i in range(len(merged.schema))]
        schema = Schema([Field(nm, f.type)
                         for nm, f in zip(names, merged.schema)])
        self.put_block(table, ColumnBlock(schema, merged.columns))
        return True

    # The typed fast path a decorated importer takes when the stream is a
    # data pipe: consume AString lines, split on the delimiter without
    # materializing characters, parse via AString.parse_* (section 5.1).
    def _read_delimited(self, stream, delim: str, schema: Optional[Schema]):
        names: Optional[List[str]] = None
        rows: List[tuple] = []
        astr_iter = getattr(stream, "astring_lines", None)
        if self.decorated and astr_iter is not None:
            lines: Any = astr_iter()
        else:
            lines = (AString((l.rstrip("\n"),)) for l in stream)
        for astr in lines:
            cells = astr.split(delim)
            if names is None and self.writes_header:
                names = [str(c) for c in cells]
                continue
            rows.append(tuple(c.sole_value for c in cells))
        return rows, names

    def _store_imported(self, table: str, rows: List[tuple],
                        names: Optional[List[str]], schema: Optional[Schema]) -> None:
        if schema is None:
            if not rows:
                self.put_block(table, ColumnBlock(Schema([]), []))
                return
            fields = []
            for i, v in enumerate(rows[0]):
                nm = names[i] if names else f"column{i + 1}"
                if isinstance(v, bool):
                    t = ColType.BOOL
                elif isinstance(v, int):
                    t = ColType.INT64
                elif isinstance(v, float):
                    t = ColType.FLOAT64
                else:
                    t = _sniff_type(str(v))
                fields.append(Field(nm, t))
            schema = Schema(fields)
        coerced = [
            tuple(self._parse_cell(v, f.type) for v, f in zip(r, schema))
            for r in rows
        ]
        self.put_block(table, RowBlock(schema, coerced).to_columns())

    # -- parallel surface (section 4.2) ------------------------------------------
    def export_csv_parallel(self, table: str, filename: str,
                            workers: Optional[int] = None,
                            header: Optional[bool] = None,
                            delimiter: Optional[str] = None) -> None:
        workers = workers or self.workers
        if workers <= 1:
            return self.export_csv(table, filename, header=header,
                                   delimiter=delimiter)
        # the negotiated pipe config is thread-local (PipeOpenContext);
        # worker threads must inherit this thread's, or every parallel
        # export silently reopens its pipes with the defaults (wrong wire
        # format, no shuffle partition, no striping)
        from ..core.ioredirect import PipeOpenContext, active_pipe_config

        pipe_cfg = active_pipe_config()
        block = self.get_block(table)
        n = len(block)
        bounds = [n * i // workers for i in range(workers + 1)]
        errs: List[BaseException] = []

        def run(i: int) -> None:
            lo, hi = bounds[i], bounds[i + 1]
            part = ColumnBlock(
                block.schema,
                [c[lo:hi] for c in block.columns],
            )
            shadow = f"{self.name}-part{i}"
            self.put_block(shadow, part)
            try:
                target = filename if is_reserved(filename) else f"{filename}.part{i}"
                with PipeOpenContext(pipe_cfg):
                    self.export_csv(shadow, target, header=header,
                                    delimiter=delimiter)
            except BaseException as e:  # noqa: BLE001 - rethrown below
                errs.append(e)
            finally:
                self.drop(shadow)

        threads = [threading.Thread(target=run, args=(i,)) for i in range(workers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errs:
            raise errs[0]

    def import_csv_parallel(self, table: str, filename: str,
                            workers: Optional[int] = None,
                            schema: Optional[Schema] = None) -> None:
        workers = workers or self.workers
        if workers <= 1:
            return self.import_csv(table, filename, schema)
        from ..core.ioredirect import PipeOpenContext, active_pipe_config

        pipe_cfg = active_pipe_config()  # see export_csv_parallel
        parts: List[Optional[ColumnBlock]] = [None] * workers
        errs: List[BaseException] = []

        def run(i: int) -> None:
            shadow = f"{self.name}-imp{i}"
            try:
                target = filename if is_reserved(filename) else f"{filename}.part{i}"
                with PipeOpenContext(pipe_cfg):
                    self.import_csv(shadow, target, schema)
                parts[i] = self.get_block(shadow)
            except BaseException as e:  # noqa: BLE001
                errs.append(e)
            finally:
                self.drop(shadow)

        threads = [threading.Thread(target=run, args=(i,)) for i in range(workers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errs:
            raise errs[0]
        nonempty = [p for p in parts if p is not None and len(p)]
        if nonempty:
            self.put_block(table, ColumnBlock.concat(nonempty))
        elif parts[0] is not None:
            self.put_block(table, parts[0])

    # -- per-line JSON surface (engines may override with their own flavor) -----
    def export_json(self, table: str, filename: str) -> None:
        """Document-per-line JSON via string concatenation (the directly-
        implemented serializer shape FormOpt's string decoration targets)."""
        if not self.supports_json:
            raise NotImplementedError(f"{self.name} has no JSON bulk surface")
        block = self.get_block(table)
        rb = block.to_rows()
        names = rb.schema.names
        stream = EngineWriter(open(filename, "w"))  # IORedirect call site
        try:
            for row in rb.rows:
                doc = self._lit("{")
                for j, (nm, v) in enumerate(zip(names, row)):
                    if j:
                        doc = doc + self._lit(", ")
                    doc = doc + self._lit('"') + self._s(nm) + self._lit('": ')
                    if isinstance(v, str):
                        doc = doc + self._lit('"') + self._s(v) + self._lit('"')
                    else:
                        doc = doc + self._s(v)
                doc = doc + self._lit("}")
                stream.write(doc + self._nl())
        finally:
            stream.close()

    def import_json(self, table: str, filename: str) -> None:
        if not self.supports_json:
            raise NotImplementedError(f"{self.name} has no JSON bulk surface")
        stream = open(filename, "r")  # IORedirect call site
        try:
            blocks_iter = getattr(stream, "blocks", None)
            if (self.decorated and blocks_iter is not None
                    and getattr(stream, "mode", "text") not in ("text", "parts")):
                blocks = list(blocks_iter())
                if blocks:
                    self.put_block(table, ColumnBlock.concat(blocks))
                else:
                    self.put_block(table, ColumnBlock(Schema([]), []))
                return
            import json as _json

            docs = [_json.loads(l) for l in stream if l.strip()]
        finally:
            stream.close()
        if not docs:
            self.put_block(table, ColumnBlock(Schema([]), []))
            return
        names = list(docs[0].keys())
        rows = [tuple(d.get(n) for n in names) for d in docs]
        from ..core.types import infer_schema

        schema = infer_schema(rows[0], names)
        self._store_imported(table, rows, names, schema)

    # -- the engine's own unit tests (what PipeGen's capture executes) ------------
    def unit_export_test(self, path: str) -> None:
        block = make_paper_block(64, seed=7)
        self.put_block("unit", block)
        self.export_csv("unit", path)
        if self.supports_json and not is_reserved(path):
            # sibling file keeps the CSV intact; still substring-matches the
            # capture target so the JSON call sites are discovered too
            self.export_json("unit", path + ".json")

    def unit_import_test(self, path: str) -> None:
        self.import_csv("unit_in", path)
        got = self.get_block("unit_in")
        assert len(got) == 64, f"expected 64 rows, got {len(got)}"
        if self.supports_json and not is_reserved(path):
            self.import_json("unit_jin", path + ".json")
            assert len(self.get_block("unit_jin")) == 64

    def unit_roundtrip_test(self, export_path: str, import_path: str) -> None:
        block = make_paper_block(64, seed=7)
        self.put_block("rt", block)
        self.export_csv("rt", export_path)
        self.import_csv("rt_in", import_path)
        # headerless CSV cannot carry column names (true of the file path too)
        assert_blocks_equal(block, self.get_block("rt_in"),
                            check_names=self.writes_header)


def _plain_str(v: Any) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, float):
        return repr(v)
    return str(v)


def _sniff_type(s: str) -> ColType:
    try:
        int(s)
        return ColType.INT64
    except ValueError:
        pass
    try:
        float(s)
        return ColType.FLOAT64
    except ValueError:
        pass
    if s.lower() in ("true", "false"):
        return ColType.BOOL
    return ColType.STRING
