"""graphstore — the Giraph analog: vertex/edge property graph engine.

Internal representation: adjacency dict ``{vertex_id: [(dst, weight), ...]}``.
The paper's benchmark interprets the standard 7-column schema as ``n``
weighted vertices with three random directed edges each; the CSV surface is
exactly that tabular layout, and the JSON surface is a *flat*
document-per-line adjacency record (nested arrays are out of scope for
FormOpt's top-level-dictionary optimization, section 5.3.2).

Import materializes AStrings into character strings before un-escaping —
the slow path the paper observes for Myria→Giraph (section 7.2.1) — unless
``fast_import`` is set (our manually-optimized comparison point, fig. 11).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.astring import AString
from ..core.types import ColType, ColumnBlock, Field, RowBlock, Schema
from .base import Engine, EngineWriter

__all__ = ["GraphStore", "GRAPH_SCHEMA"]

GRAPH_SCHEMA = Schema(
    [Field("key", ColType.INT64)]
    + [
        f
        for i in range(3)
        for f in (Field(f"ref{i}", ColType.INT64), Field(f"val{i}", ColType.FLOAT64))
    ]
)


class GraphStore(Engine):
    name = "graphstore"
    csv_delimiter = ","
    writes_header = False
    supports_json = True
    json_flavor = "per-line"

    def __init__(self, workers: int = 4, decorated: bool = True,
                 fast_import: bool = False):
        super().__init__(workers=workers, decorated=decorated)
        self.fast_import = fast_import
        self._graphs: Dict[str, Dict[int, List[Tuple[int, float]]]] = {}

    # -- graph <-> block conversions ------------------------------------------------
    def put_block(self, table: str, block: ColumnBlock) -> None:
        super().put_block(table, block)
        adj: Dict[int, List[Tuple[int, float]]] = {}
        if len(block.schema) >= 7:
            keys = block.columns[0]
            for r in range(len(block)):
                edges = [
                    (int(block.columns[1 + 2 * i][r]), float(block.columns[2 + 2 * i][r]))
                    for i in range(3)
                ]
                adj[int(keys[r])] = edges
        self._graphs[table] = adj

    def vertices(self, table: str) -> Dict[int, List[Tuple[int, float]]]:
        return self._graphs.get(table, {})

    # -- decorated CSV import with Giraph's escape pass ------------------------------
    def _read_delimited(self, stream, delim: str, schema):
        if self.fast_import:
            return super()._read_delimited(stream, delim, schema)
        # Giraph materializes the AString and re-scans characters to unescape;
        # this is the per-character overhead the paper measures (section 7.2.1)
        names = None
        rows: List[tuple] = []
        astr_iter = getattr(stream, "astring_lines", None)
        lines = astr_iter() if (self.decorated and astr_iter is not None) else (
            AString((l.rstrip("\n"),)) for l in stream
        )
        for astr in lines:
            text = str(astr)  # forced materialization
            unescaped = text.replace("\\,", ",")  # escape scan
            cells = unescaped.split(delim)
            rows.append(tuple(self._sniff(c) for c in cells))
        return rows, names

    @staticmethod
    def _sniff(c: str):
        try:
            return int(c)
        except ValueError:
            pass
        try:
            return float(c)
        except ValueError:
            return c

    # -- flat JSON adjacency (per-line) -----------------------------------------------
    def export_json(self, table: str, filename: str) -> None:
        block = self.get_block(table)
        rb = block.to_rows()
        names = rb.schema.names
        stream = EngineWriter(open(filename, "w"))  # IORedirect call site
        try:
            for row in rb.rows:
                doc = self._lit("{")
                for j, (nm, v) in enumerate(zip(names, row)):
                    if j:
                        doc = doc + self._lit(", ")
                    doc = doc + self._lit('"') + self._s(nm) + self._lit('": ')
                    if isinstance(v, str):
                        doc = doc + self._lit('"') + self._s(v) + self._lit('"')
                    else:
                        doc = doc + self._s(v)
                doc = doc + self._lit("}") + self._nl()
                stream.write(doc)
        finally:
            stream.close()

    def import_json(self, table: str, filename: str) -> None:
        import json as _json

        stream = open(filename, "r")  # IORedirect call site
        try:
            blocks_iter = getattr(stream, "blocks", None)
            if (self.decorated and blocks_iter is not None
                    and getattr(stream, "mode", "text") not in ("text", "parts")):
                blocks = list(blocks_iter())
                if blocks:
                    self.put_block(table, ColumnBlock.concat(blocks))
                return
            docs = [_json.loads(l) for l in stream if str(l).strip()]
        finally:
            stream.close()
        if not docs:
            return
        names = list(docs[0].keys())
        rows = [tuple(d.get(n) for n in names) for d in docs]
        from ..core.types import infer_schema

        self._store_imported(table, rows, names, infer_schema(rows[0], names))
