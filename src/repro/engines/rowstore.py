"""rowstore — the Derby analog: single-node relational engine.

Internal representation: row tuples + schema.  CSV with a header row is the
only bulk format (Derby supports no binary or JSON bulk path, section 5).
Like Derby it rejects custom URI schemes, so its reserved filename uses the
``/tmp/__reserved__<name>`` template and it checks file existence before
importing — PipeGen's stub files satisfy that check (section 6.1).
"""

from __future__ import annotations

from pathlib import Path
from typing import List, Optional

from ..core.datapipe import RESERVED_TEMPLATE, is_reserved
from ..core.types import ColumnBlock, RowBlock, Schema
from .base import Engine

__all__ = ["RowStore"]


class RowStore(Engine):
    name = "rowstore"
    csv_delimiter = ","
    writes_header = True
    supports_json = False

    def __init__(self, workers: int = 1, decorated: bool = True):
        super().__init__(workers=1, decorated=decorated)  # single-node engine

    @staticmethod
    def reserved_name(dataset: str, query_id: str = "0") -> str:
        """Derby-style reserved filename (template form, section 6.1)."""
        return f"{RESERVED_TEMPLATE}{dataset}?query={query_id}"

    def import_csv(self, table: str, filename: str,
                   schema: Optional[Schema] = None) -> None:
        # Derby checks that the import file exists before starting; PipeGen
        # creates a stub so reserved names pass (section 6.1).
        if not is_reserved(filename) and not Path(filename).exists():
            raise FileNotFoundError(filename)
        super().import_csv(table, filename, schema)

    # -- a sliver of relational surface for the examples -------------------------
    def select(self, table: str, columns: List[str]) -> ColumnBlock:
        block = self.get_block(table)
        idx = [block.schema.index_of(c) for c in columns]
        return ColumnBlock(
            Schema([block.schema[i] for i in idx]),
            [block.columns[i] for i in idx],
        )

    def row_count(self, table: str) -> int:
        return len(self.get_block(table))
