"""Operational command-line tools for the pipe fabric.

``python -m repro.tools.pipetop`` — live broker/fabric introspection
against a running :class:`~repro.core.broker.PipeBroker` (its directory
server answers the ``stats`` RPC).
"""
