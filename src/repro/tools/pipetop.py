"""pipetop: a ``top``-style live view of a running PipeBroker.

Polls the broker's directory server over its ``stats`` RPC (see
:meth:`repro.core.broker.PipeBroker.stats`) and renders admission
pressure, per-tenant/QoS grants and rejects, live resource use, pool
occupancy and doorbell-hub activity as a plain-terminal dashboard::

    python -m repro.tools.pipetop --host 127.0.0.1 --port 7070

``--once`` prints a single frame (scriptable; used by tests), otherwise
the screen refreshes every ``--interval`` seconds until Ctrl-C.  Stdlib
only — the tool must work on a bare operator box.
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Any, Dict, List

__all__ = ["render", "main"]


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024.0 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024.0
    return f"{n:.1f}GiB"


def _fmt_s(v: Any) -> str:
    if not isinstance(v, (int, float)):
        return "-"
    if v < 1e-3:
        return f"{v * 1e6:.0f}us"
    if v < 1.0:
        return f"{v * 1e3:.1f}ms"
    return f"{v:.2f}s"


def _tenant_rows(stats: Dict[str, Any]) -> List[str]:
    """Per-tenant/QoS table: live use joined with grant/reject counters."""
    by_tenant = stats.get("active_by_tenant") or {}
    grants = stats.get("grants_by") or {}
    rejects = stats.get("rejects_by") or {}
    tenants = sorted(set(by_tenant)
                     | {k.split("/", 1)[0] for k in grants}
                     | {k.split("/", 1)[0] for k in rejects})
    rows = [f"  {'tenant':<14} {'rings':>6} {'segs':>6} {'bytes':>10} "
            f"{'grants':>14} {'rejects':>14}"]
    for t in tenants:
        use = by_tenant.get(t, [0, 0, 0])
        gr = ", ".join(f"{k.split('/', 1)[1]}={v}"
                       for k, v in sorted(grants.items())
                       if k.split("/", 1)[0] == t) or "0"
        rj = ", ".join(f"{k.split('/', 1)[1]}={v}"
                       for k, v in sorted(rejects.items())
                       if k.split("/", 1)[0] == t) or "0"
        rows.append(f"  {t:<14} {use[0]:>6} {use[1]:>6} "
                    f"{_fmt_bytes(use[2]):>10} {gr:>14} {rj:>14}")
    if len(rows) == 1:
        rows.append("  (no tenants yet)")
    return rows


def _subscription_rows(stats: Dict[str, Any]) -> List[str]:
    """Continuous-pipe publications table: one row per live publication
    (see :func:`repro.core.subscribe.publications_snapshot`)."""
    subs = stats.get("subscriptions") or []
    rows = [f"  {'name':<18} {'subs':>5} {'head':>8} {'min wm':>8} "
            f"{'lag':>6} {'log':>10} {'fallbacks':>10}"]
    for s in subs:
        head = s.get("head_epoch", 0)
        wm = s.get("min_watermark", 0)
        rows.append(
            f"  {str(s.get('name', '?')):<18} {s.get('subscribers', 0):>5} "
            f"{head:>8} {wm:>8} {max(0, head - wm):>6} "
            f"{_fmt_bytes(s.get('retained_bytes', 0)):>10} "
            f"{s.get('snapshot_fallbacks', 0):>10}")
    if len(rows) == 1:
        rows.append("  (no publications)")
    return rows


def _broker_health(stats: Dict[str, Any]) -> List[str]:
    """Crash-tolerance row: incarnation epoch, journal footprint, and
    the fencing/degraded-mode counters an operator checks first after a
    control-plane flap.  Hidden on pre-journal snapshots."""
    if "epoch" not in stats:
        return []
    j = stats.get("journal") or {}
    jtxt = (f"journal={_fmt_bytes(j.get('bytes', 0))}"
            f"/{j.get('records', 0)}rec"
            f" ckpts={j.get('checkpoints', 0)}" if j else "journal=off")
    rec = stats.get("recovered") or {}
    rtxt = (f" recovered(leases={rec.get('entries', 0)} "
            f"names={rec.get('names', 0)} "
            f"expired_tickets={rec.get('expired_tickets', 0)})"
            if rec else "")
    return [
        f"broker      epoch={stats.get('epoch', 0)} {jtxt} "
        f"stale_rejects={stats.get('stale_releases', 0)} "
        f"remote_tickets={stats.get('remote_tickets', 0)}" + rtxt,
    ]


def render(stats: Dict[str, Any], now: float = 0.0) -> str:
    """One dashboard frame from a broker ``stats`` snapshot.  Pure —
    takes the dict, returns the text — so tests can feed it canned or
    live snapshots without a terminal."""
    gw = stats.get("grant_wait") or {}
    lines = [
        f"pipetop — broker snapshot"
        + (f" @ {time.strftime('%H:%M:%S', time.localtime(now))}"
           if now else ""),
        "",
        f"admission   admitted={stats.get('admitted', 0)} "
        f"queued={stats.get('queued', 0)} "
        f"rejected={stats.get('rejected', 0)} "
        f"queue_depth={stats.get('waiting', 0)}",
        f"grant wait  n={gw.get('total', 0)} "
        f"p50={_fmt_s(gw.get('p50_s'))} p95={_fmt_s(gw.get('p95_s'))} "
        f"p99={_fmt_s(gw.get('p99_s'))}",
        f"live use    rings={stats.get('active_rings', 0)} "
        f"segments={stats.get('active_segments', 0)} "
        f"bytes={_fmt_bytes(stats.get('active_bytes', 0))} "
        f"fds={stats.get('fds', -1)}",
        *_broker_health(stats),
        "",
        "tenants",
        *_tenant_rows(stats),
        "",
        "subscriptions",
        *_subscription_rows(stats),
    ]
    qos = stats.get("active_by_qos") or {}
    if qos:
        lines.append("")
        lines.append("qos         " + "  ".join(
            f"{k}={v}" for k, v in sorted(qos.items())))
    if "hub_registered" in stats:
        lines.append(
            f"doorbells   registered={stats.get('hub_registered', 0)} "
            f"wakeups={stats.get('hub_wakeups', 0)} "
            f"waits={stats.get('hub_waits', 0)}")
    pool = stats.get("pool") or {}
    bpool = stats.get("buffer_pool") or {}
    if pool or bpool:
        lines.append(
            f"pools       shm_parked={pool.get('spsc_parked', 0)}"
            f"+{pool.get('broadcast_parked', 0)}bcast "
            f"bufs hit/miss={bpool.get('hits', 0)}/{bpool.get('misses', 0)} "
            f"retained={_fmt_bytes(bpool.get('bytes_retained', 0))}")
    return "\n".join(lines)


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="pipetop", description="live PipeBroker dashboard")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True,
                    help="broker directory-server port")
    ap.add_argument("--interval", type=float, default=1.0)
    ap.add_argument("--once", action="store_true",
                    help="print one frame and exit")
    args = ap.parse_args(argv)

    from repro.core.directory import DirectoryClient

    client = DirectoryClient(args.host, args.port)
    try:
        while True:
            try:
                stats = client.stats()
            except (OSError, IOError, ValueError) as e:
                print(f"pipetop: stats RPC failed: {e}", file=sys.stderr)
                return 1
            frame = render(stats, now=time.time())
            if args.once:
                print(frame)
                return 0
            # clear + home, like top(1); plain prints under a dumb term
            if sys.stdout.isatty():
                sys.stdout.write("\x1b[2J\x1b[H")
            print(frame, flush=True)
            time.sleep(args.interval)
    except KeyboardInterrupt:
        return 0


if __name__ == "__main__":
    sys.exit(main())
