"""Quickstart: auto-generate data pipes for two engines and move a table
between them — no file-system materialization.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

from repro.core import PipeConfig, adapter_for, transfer, transfer_via_files
from repro.engines import make_engine, make_paper_block


def main() -> None:
    # 1. two engines (the Myria / Spark analogs), some data in the source
    src = make_engine("colstore", workers=2)
    dst = make_engine("dataframe", workers=2)
    src.put_block("particles", make_paper_block(50_000, seed=42))

    # 2. PipeGen compile loop: run each engine's own unit tests, find the
    #    file-IO call sites, splice in pipe-aware open (fig. 5)
    gp = adapter_for(src)
    print(f"[pipegen] {gp.report.summary()}")
    print(f"[pipegen] adapter stats: {gp.stats.row()}")

    # 3. baseline: export/import via the file system (CSV)
    r_file = transfer_via_files(src, "particles", dst, "p_file", workers=2)
    print(f"[file]  {r_file.rows} rows in {r_file.seconds:.2f}s "
          f"({r_file.bytes_moved} bytes materialized)")

    # 4. the same transfer over a generated binary data pipe
    r_pipe = transfer(src, "particles", dst, "p_pipe",
                      config=PipeConfig(mode="arrowcol"), workers=2)
    print(f"[pipe]  {r_pipe.rows} rows in {r_pipe.seconds:.2f}s "
          f"(zero bytes on disk)")
    print(f"[pipe]  speedup: {r_file.seconds / r_pipe.seconds:.2f}x "
          f"(paper: up to 3.8x at 1e9 rows)")

    assert r_pipe.rows == r_file.rows == 50_000


if __name__ == "__main__":
    main()
