"""Quickstart: auto-generate data pipes for two engines and move a table
between them — no file-system materialization — through the plan API
(plan → compile → explain → execute).

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

from repro.core import PipeConfig, adapter_for, plan
from repro.engines import make_engine, make_paper_block


def main() -> None:
    # 1. two engines (the Myria / Spark analogs), some data in the source
    src = make_engine("colstore", workers=2)
    dst = make_engine("dataframe", workers=2)
    src.put_block("particles", make_paper_block(50_000, seed=42))

    # 2. PipeGen compile loop: run each engine's own unit tests, find the
    #    file-IO call sites, splice in pipe-aware open (fig. 5)
    gp = adapter_for(src)
    print(f"[pipegen] {gp.report.summary()}")
    print(f"[pipegen] adapter stats: {gp.stats.row()}")

    # 3. baseline: export/import via the file system (a via="files" edge)
    r_file = (plan(negotiate=False)
              .move(src, "particles", dst, "p_file", via="files", workers=2)
              .execute().single())
    print(f"[file]  {r_file.rows} rows in {r_file.seconds:.2f}s "
          f"({r_file.bytes_moved} bytes materialized)")

    # 4. the same transfer over a generated binary data pipe: build the
    #    one-edge plan, inspect the compiled decisions, then execute
    p = (plan(negotiate=False)
         .move(src, "particles", dst, "p_pipe", workers=2,
               config=PipeConfig(mode="arrowcol")))
    compiled = p.compile()
    print("[plan]")
    for line in compiled.explain().splitlines():
        print(f"[plan]  {line}")
    r_pipe = compiled.execute().single()
    print(f"[pipe]  {r_pipe.rows} rows in {r_pipe.seconds:.2f}s "
          f"(zero bytes on disk)")
    print(f"[pipe]  speedup: {r_file.seconds / r_pipe.seconds:.2f}x "
          f"(paper: up to 3.8x at 1e9 rows)")

    # 5. composition is a planner rule, not a kwarg contract: fan the same
    #    relation out to two destinations in one plan (edges with no data
    #    dependency run concurrently)
    third = make_engine("rowstore")
    fan = (plan(negotiate=False)
           .move(src, "particles", dst, "p_fan",
                 config=PipeConfig(mode="arrowcol"))
           .move(src, "particles", third, "p_fan",
                 config=PipeConfig(mode="arrowcol"))
           .execute())
    print(f"[fanout] {fan.rows} rows across {len(fan.results)} edges "
          f"in {fan.seconds:.2f}s (one stage, concurrent)")

    assert r_pipe.rows == r_file.rows == 50_000
    assert fan.rows == 100_000


if __name__ == "__main__":
    main()
