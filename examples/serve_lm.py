"""Batched serving example: continuous-batching decode over a KV cache.

    PYTHONPATH=src python examples/serve_lm.py --requests 8
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.models import build_model, get_config
from repro.serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_size=args.batch,
                      max_context=128, eos_token=-1)

    rng = jax.random.PRNGKey(1)
    t0 = time.perf_counter()
    for i in range(args.requests):
        prompt = [int(x) for x in
                  jax.random.randint(jax.random.fold_in(rng, i), (3,),
                                     0, cfg.vocab)]
        eng.submit(prompt, max_new_tokens=args.max_new)
    results = eng.run(max_steps=1000)
    dt = time.perf_counter() - t0

    toks = sum(len(r.tokens) for r in results)
    print(f"[serve] {len(results)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, batch={args.batch}, "
          f"decode steps={eng.steps_run})")
    for r in results[:4]:
        print(f"  req {r.request_id}: prompt={r.prompt} -> {r.tokens} "
              f"({r.latency_s:.2f}s)")


if __name__ == "__main__":
    main()
