"""The paper's motivating workflow (section 2, fig. 1), scaled down.

An astronomer holds an N-body snapshot in the Myria analog (colstore),
needs power-iteration clustering (PIC) that only the Spark analog
(dataframe) provides:

  1. compute pairwise distances under a threshold in colstore,
  2. move the pair list to dataframe  (file system vs data pipe),
  3. run PIC there,
  4. move cluster assignments back,
  5. compare against the existing clustering.

    PYTHONPATH=src python examples/hybrid_astronomy.py
"""

import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import PipeConfig, plan
from repro.core.types import ColType, ColumnBlock, Field, Schema
from repro.engines import make_engine

N_PARTICLES = 600
EPS = 0.35
N_CLUSTERS = 4


def make_snapshot(n: int, seed: int = 0):
    """Particles in 3D around N_CLUSTERS centers + an initial clustering."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-2, 2, (N_CLUSTERS, 3))
    labels = rng.integers(0, N_CLUSTERS, n)
    pos = centers[labels] + rng.normal(0, 0.15, (n, 3))
    return pos, labels


def pairwise_pairs(pos: np.ndarray, eps: float) -> ColumnBlock:
    """The colstore-side query: particle pairs closer than eps."""
    d2 = ((pos[:, None, :] - pos[None, :, :]) ** 2).sum(-1)
    i, j = np.where((d2 < eps * eps) & (np.arange(len(pos))[:, None]
                                        < np.arange(len(pos))[None, :]))
    w = np.exp(-d2[i, j])
    return ColumnBlock(
        Schema([Field("i", ColType.INT64), Field("j", ColType.INT64),
                Field("w", ColType.FLOAT64)]),
        [i.astype(np.int64), j.astype(np.int64), w],
    )


def pic_cluster(block: ColumnBlock, n: int, k: int) -> np.ndarray:
    """Power-iteration clustering on the transferred affinity pairs
    (the dataframe/Spark-side computation)."""
    i = np.asarray(block.columns[0], np.int64)  # headerless CSV: positional
    j = np.asarray(block.columns[1], np.int64)
    w = np.asarray(block.columns[2], np.float64)
    A = np.zeros((n, n))
    A[i, j] = w
    A[j, i] = w
    deg = A.sum(1) + 1e-12
    W = A / deg[:, None]
    v = np.ones(n) / n + np.random.default_rng(1).normal(0, 1e-4, n)
    for _ in range(60):
        v = W @ v
        v /= np.abs(v).max()
    order = np.argsort(v)
    bounds = [n * c // k for c in range(1, k)]
    labels = np.zeros(n, np.int64)
    for c, start in enumerate(np.split(order, bounds)):
        labels[start] = c
    return labels


def run(transfer_fn, tag: str) -> float:
    pos, existing = make_snapshot(N_PARTICLES)
    myria = make_engine("colstore")
    spark = make_engine("dataframe")

    t0 = time.perf_counter()
    pairs = pairwise_pairs(pos, EPS)               # step 1 (in "Myria")
    myria.put_block("pairs", pairs)
    transfer_fn(myria, "pairs", spark, "pairs")    # step 2 (the transfer)
    got = spark.get_block("pairs")
    labels = pic_cluster(got, N_PARTICLES, N_CLUSTERS)  # step 3 (in "Spark")
    spark.put_block("assign", ColumnBlock(
        Schema([Field("id", ColType.INT64), Field("c", ColType.INT64)]),
        [np.arange(N_PARTICLES, dtype=np.int64), labels],
    ))
    transfer_fn(spark, "assign", myria, "assign")  # step 4 (back)
    back = myria.get_block("assign")
    new_labels = np.asarray(back.columns[1], np.int64)
    elapsed = time.perf_counter() - t0

    # step 5: compare clusterings (pair-agreement rate)
    rng = np.random.default_rng(2)
    a = rng.integers(0, N_PARTICLES, 4000)
    b = rng.integers(0, N_PARTICLES, 4000)
    agree = np.mean((existing[a] == existing[b])
                    == (new_labels[a] == new_labels[b]))
    print(f"[{tag}] workflow in {elapsed:.2f}s; {len(got)} pairs moved; "
          f"pair-agreement with existing clustering: {agree:.1%}")
    return elapsed


def _move_via_files(s, t, d, t2):
    """One-edge file-baseline plan (what transfer_via_files shims)."""
    plan(negotiate=False).move(s, t, d, t2, via="files").execute()


_printed_plan = False


def _move_via_pipe(s, t, d, t2):
    """One-edge pipe plan; the compiled decisions print once."""
    global _printed_plan
    compiled = (plan(negotiate=False)
                .move(s, t, d, t2, timeout=120,
                      config=PipeConfig(mode="arrowcol"))
                .compile())
    if not _printed_plan:
        _printed_plan = True
        for line in compiled.explain().splitlines():
            print(f"[plan] {line}")
    compiled.execute()


def main() -> None:
    t_file = run(_move_via_files, "file")
    t_pipe = run(_move_via_pipe, "pipe")
    print(f"[summary] transfer-inclusive speedup: {t_file / t_pipe:.2f}x "
          f"(paper fig. 1: 66 -> 28 minutes on 100 GB)")


if __name__ == "__main__":
    main()
