"""End-to-end training driver: pipe-fed input pipeline -> jitted train step
-> checkpoint/restart.

The token stream arrives through a PipeGen data pipe (the paper's transport
feeding the trainer — no file materialization between the "tokenizer" and
the training loop).  Defaults to a reduced config that trains in seconds on
CPU; ``--arch smollm-360m --full`` selects the real 360M config (sized for
accelerators).

    PYTHONPATH=src python examples/train_lm.py --steps 60
    PYTHONPATH=src python examples/train_lm.py --steps 60 --resume  # restart
"""

import argparse
import sys
import threading

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.datapipe import PipeConfig
from repro.launch.mesh import make_local_mesh
from repro.models import build_model, get_config
from repro.pipeline import PipeFeeder, SyntheticSource
from repro.train import (
    CheckpointManager,
    TrainState,
    adamw_init,
    make_train_step,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (accelerator-scale)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/pipegen-train-ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    mesh = make_local_mesh()
    print(f"[train] arch={cfg.name} params~{cfg.param_count() if args.full else 'reduced'} "
          f"mesh={dict(mesh.shape)}")

    mgr = CheckpointManager(args.ckpt_dir)
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(params, adamw_init(params))
    start_step = 0
    if args.resume:
        try:
            restored, start_step = mgr.restore(jax.eval_shape(lambda: state))
            state = jax.tree_util.tree_map(jnp.asarray, restored)
            print(f"[train] resumed from checkpoint step {start_step}")
        except FileNotFoundError:
            print("[train] no checkpoint; cold start")

    # the data plane: a synthetic "tokenizer" exports through a data pipe
    n_rows = (args.steps - start_step) * args.batch + args.batch
    pipe_name = "db://tokens?query=train"
    feeder = PipeFeeder([pipe_name], batch_size=args.batch, seq_len=args.seq,
                        skip_until=0).start()
    src = SyntheticSource(cfg.vocab, args.seq, seed=7)
    feed_thread = threading.Thread(
        target=src.serve, args=(pipe_name, n_rows),
        kwargs={"config": PipeConfig(mode="arrowcol", block_rows=256)},
        daemon=True)
    feed_thread.start()

    step_mod = make_train_step(model, mesh, lr_peak=3e-3,
                               lr_total=max(args.steps, 100))
    jitted = jax.jit(step_mod.step_fn)

    step = start_step
    with mesh:
        for batch in feeder.batches():
            if step >= args.steps:
                break
            jb = {k: jnp.asarray(v) for k, v in batch.data.items()}
            state, metrics = jitted(state, jb)
            step += 1
            if step % 10 == 0 or step == args.steps:
                print(f"[train] step {step:4d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"queue_stalls={feeder.queue.stalls}")
            if step % args.ckpt_every == 0:
                mgr.save(step, state, blocking=False)
    mgr.wait()
    mgr.save(step, state)
    print(f"[train] done at step {step}; checkpoint in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
