"""Roofline machinery: collective parsing + term arithmetic."""

import pytest

from repro.launch.hlo_stats import HW, parse_collectives, roofline_terms

HLO = """
HloModule test
ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[2048,256]{1,0} all-gather(%p0), replica_groups={}
  %ar = bf16[1024]{0} all-reduce(%x), to_apply=%add
  %rs = f32[64,256]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = f32[16,16]{1,0} all-to-all(%z), dimensions={0}
  %cp = u8[4096]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %ag2 = f32[10]{0} all-gather-start(%q)
  %agd = f32[10]{0} all-gather-done(%ag2)
  %dot = f32[128,128]{1,0} dot(%a, %b)
}
"""


def test_parse_collectives_counts_and_bytes():
    stats = parse_collectives(HLO)
    assert stats.count_by_kind["all-gather"] == 2  # incl. -start, not -done
    assert stats.count_by_kind["all-reduce"] == 1
    assert stats.count_by_kind["reduce-scatter"] == 1
    assert stats.count_by_kind["all-to-all"] == 1
    assert stats.count_by_kind["collective-permute"] == 1
    assert stats.bytes_by_kind["all-gather"] == 2048 * 256 * 4 + 10 * 4
    assert stats.bytes_by_kind["all-reduce"] == 1024 * 2
    assert stats.bytes_by_kind["collective-permute"] == 4096
    assert stats.total_count == 6


def test_parse_ignores_non_collectives():
    stats = parse_collectives("%dot = f32[8,8]{1,0} dot(%a, %b)")
    assert stats.total_bytes == 0


def test_roofline_terms_math():
    t = roofline_terms(197e12, 819e9, 50e9, 1)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)
    t = roofline_terms(197e12, 0, 0, 2)
    assert t["compute_s"] == pytest.approx(0.5)
